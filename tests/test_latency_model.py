"""Reproduction of the paper's analytical results (Tables 1-3)."""
import pytest

from repro.config import get_config
from repro.core.balancing import balance_model
from repro.core.latency import (
    PAPER_RH_M,
    energy_per_timestep_mj,
    fpga_latency_ms,
    speedup_table,
)

# FPGA column of paper Table 2 (ms): (T=1, T=64)
PAPER_TABLE2_FPGA = {
    "lstm-ae-f32-d2": (0.033, 0.086),
    "lstm-ae-f64-d2": (0.038, 0.350),
    "lstm-ae-f32-d6": (0.038, 0.089),
    "lstm-ae-f64-d6": (0.060, 0.474),
}

# FPGA column of paper Table 3 (mJ/timestep): (T=1, T=64)
PAPER_TABLE3_FPGA = {
    "lstm-ae-f32-d2": (0.362, 0.016),
    "lstm-ae-f64-d2": (0.435, 0.067),
    "lstm-ae-f32-d6": (0.426, 0.016),
    "lstm-ae-f64-d6": (0.677, 0.087),
}


@pytest.mark.parametrize("name", sorted(PAPER_RH_M))
def test_latency_model_matches_paper_table2(name):
    """Calibrated Eq-1 model within 40% of every paper Table-2 FPGA number
    (both T=1 and T=64; most are within ~15%, F64-D6 worst ~30%)."""
    cfg = get_config(name).lstm_ae
    rh_m = PAPER_RH_M[name]
    for t, expected in zip((1, 64), PAPER_TABLE2_FPGA[name]):
        got = fpga_latency_ms(cfg, t, rh_m).ms
        assert abs(got - expected) / expected < 0.40, (
            f"{name} T={t}: model {got:.3f}ms vs paper {expected:.3f}ms"
        )


@pytest.mark.parametrize("name", sorted(PAPER_RH_M))
def test_energy_model_matches_paper_table3(name):
    cfg = get_config(name).lstm_ae
    rh_m = PAPER_RH_M[name]
    for t, expected in zip((1, 64), PAPER_TABLE3_FPGA[name]):
        lat = fpga_latency_ms(cfg, t, rh_m).ms
        got = energy_per_timestep_mj(lat, t, "fpga")
        assert abs(got - expected) / expected < 0.45, (
            f"{name} T={t}: model {got:.3f}mJ vs paper {expected:.3f}mJ"
        )


def test_pure_eq1_uncalibrated_is_lower_bound():
    """The uncalibrated Eq-1 cycles are an optimistic lower bound on the
    measured silicon (calibration factor > 1)."""
    for name, rh_m in PAPER_RH_M.items():
        cfg = get_config(name).lstm_ae
        raw = fpga_latency_ms(cfg, 64, rh_m, cycle_factor=1.0, overhead_us=0.0).ms
        assert raw < PAPER_TABLE2_FPGA[name][1]


def test_depth_scaling_claim():
    """Paper Section 4.2: tripling depth costs the FPGA only ~1.4x latency
    at T=64 (temporal parallelism hides added depth)."""
    d2 = fpga_latency_ms(get_config("lstm-ae-f64-d2").lstm_ae, 64, 4).ms
    d6 = fpga_latency_ms(get_config("lstm-ae-f64-d6").lstm_ae, 64, 8).ms
    ratio = d6 / d2
    assert ratio < 2.0, f"depth scaling ratio {ratio:.2f}"


def test_dataflow_speedup_grows_with_depth():
    """The temporal-parallel schedule's win over layer-by-layer approaches
    the layer count for long sequences."""
    rows = speedup_table(get_config("lstm-ae-f32-d6").lstm_ae, 1)
    by_t = {r["timesteps"]: r["speedup"] for r in rows}
    assert by_t[64] > by_t[1]
    assert by_t[64] > 4.5  # 6 layers -> near-6x at T=64


def test_rh_m_resource_scaling():
    """Table-1 story (paper §4.1): doubling widths at minimal reuse doubles
    the concurrent multipliers (M = 4·LH/R; work ×4 but cycles/timestep ×2)
    and doubles the per-port BRAM width on top — why F64 models needed
    RH_m=4/8; raising RH_m divides the demand back down."""
    f32 = balance_model(get_config("lstm-ae-f32-d2").lstm_ae, 1)
    f64_rh1 = balance_model(get_config("lstm-ae-f64-d2").lstm_ae, 1)
    f64_rh4 = balance_model(get_config("lstm-ae-f64-d2").lstm_ae, 4)
    mults = lambda bs: sum(b.mx + b.mh for b in bs)
    assert mults(f64_rh1) == pytest.approx(2 * mults(f32), rel=0.05)
    assert mults(f64_rh4) < 0.4 * mults(f64_rh1)

"""Per-assigned-architecture smoke tests (reduced configs, CPU): one
forward/train step, output shapes, no NaNs — deliverable (f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import list_archs, reduced_config
from repro.models import build_model

ARCHS = list_archs()


def _batch_for(cfg, b=2, s=16, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.family == "lstm_ae":
        return {"series": jax.random.normal(key, (b, s, cfg.lstm_ae.input_features))}
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size, jnp.int32),
    }
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.family == "whisper":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16
        )
    if cfg.frontend == "vision_stub":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.vision_patches, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    """One jitted loss+grad step on the reduced config: finite, nonzero."""
    cfg = reduced_config(arch)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    batch = _batch_for(cfg)

    @jax.jit
    def loss_and_grad(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: api.loss(q, b), has_aux=True
        )(p)
        gnorm = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        return loss, jnp.sqrt(gnorm)

    loss, gnorm = loss_and_grad(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert jnp.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_smoke(arch):
    """Prefill path: correct output shapes, no NaNs."""
    cfg = reduced_config(arch)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(2))
    b, s = 2, 16
    batch = _batch_for(cfg, b, s)
    batch.pop("labels", None)
    out, cache = jax.jit(lambda p, bt: api.prefill(p, bt))(params, batch)
    if cfg.family == "lstm_ae":
        assert out.shape == (b,)  # per-sequence anomaly scores
    else:
        assert out.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all()), f"{arch}: NaN output"


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if not a.startswith("lstm-ae")]
)
def test_decode_step_smoke(arch):
    cfg = reduced_config(arch)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(3))
    b, max_len = 2, 32
    cache = api.init_cache(b, max_len)
    token = jnp.ones((b, 1), jnp.int32)
    logits, new_cache = jax.jit(lambda p, t, c, n: api.decode(p, t, c, n))(
        params, token, cache, jnp.int32(4)
    )
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(new_cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_match_params(arch):
    """Spec trees must mirror the param trees exactly (drift guard for the
    sharding deliverable)."""
    from repro.distributed.sharding import is_spec_leaf

    cfg = reduced_config(arch)
    api = build_model(cfg)
    params = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    specs = api.param_specs()
    p_paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(params)[0]]
    s_flat = jax.tree_util.tree_flatten_with_path(specs, is_leaf=is_spec_leaf)[0]
    s_paths = [p for p, _ in s_flat]
    assert p_paths == s_paths, f"{arch}: spec tree != param tree"
    # every spec leaf rank matches its param rank
    p_leaves = [l for _, l in jax.tree_util.tree_flatten_with_path(params)[0]]
    for (path, spec), leaf in zip(s_flat, p_leaves):
        assert len(spec) == len(leaf.shape), f"{arch} {path}: {spec} vs {leaf.shape}"


def test_exact_assigned_dims():
    """The full configs carry the exact published dims from the assignment."""
    from repro.config import get_config

    c = get_config("moonshot-v1-16b-a3b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.moe.num_experts, c.moe.top_k) == (48, 2048, 16, 16, 1408, 163840, 64, 6)
    c = get_config("dbrx-132b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.moe.num_experts, c.moe.top_k) == (40, 6144, 48, 8, 10752, 100352, 16, 4)
    c = get_config("olmo-1b")
    assert (c.num_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size, c.norm) == (
        16, 2048, 16, 8192, 50304, "nonparametric_ln")
    c = get_config("phi4-mini-3.8b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        32, 3072, 24, 8, 8192, 200064)
    c = get_config("tinyllama-1.1b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        22, 2048, 32, 4, 5632, 32000)
    c = get_config("internlm2-20b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        48, 6144, 48, 8, 16384, 92544)
    c = get_config("rwkv6-7b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size, c.family) == (
        32, 4096, 14336, 65536, "rwkv6")
    c = get_config("whisper-large-v3")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.num_heads, c.d_ff, c.vocab_size) == (
        32, 32, 1280, 20, 5120, 51866)
    c = get_config("jamba-v0.1-52b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size,
            c.moe.num_experts, c.moe.top_k, c.attn_every) == (
        32, 4096, 32, 8, 14336, 65536, 16, 2, 8)
    c = get_config("phi-3-vision-4.2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff, c.vocab_size) == (
        32, 3072, 32, 32, 8192, 32064)
    # the paper's own models
    from repro.config import get_config as gc
    assert gc("lstm-ae-f32-d6").lstm_ae.layer_sizes() == (16, 8, 4, 8, 16, 32)
    assert gc("lstm-ae-f64-d6").lstm_ae.layer_sizes() == (32, 16, 8, 16, 32, 64)

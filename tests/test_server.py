"""Async gateway transport: real-socket round-trips must be value-
identical to the in-process serving paths, the background pump must
complete one-shot tickets with no caller pumping, backpressure must
surface as protocol errors, and drain must leave nothing unanswered."""
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import (
    GATEWAY_ARCH as ARCH,
    GATEWAY_FEATS as FEATS,
    breaking_score_masked,
    gateway_series as _series,
    solo_stream_errors as _solo_errors,
)
from repro.engine import AnomalyService
from repro.gateway.client import GatewayClient, GatewayClientError
from repro.gateway.server import GatewayServer


@pytest.fixture(scope="module")
def svc():
    return AnomalyService(ARCH, schedule="wavefront")


@pytest.fixture
def served(svc):
    """A gateway served over a real socket on a private event-loop thread."""
    gw = svc.open_gateway(capacity=4, max_batch=4, max_wait_ms=10.0)
    server = GatewayServer(gw, port=0, pump_interval_ms=2.0)
    host, port = server.start_in_thread()
    yield host, port, gw
    server.stop_in_thread()


# -- streaming sessions ----------------------------------------------------


def test_stream_session_matches_solo_over_socket(served, svc):
    """Acceptance: a socket streaming session's running errors and final
    score equal solo ``stream_step`` — the transport adds no semantics."""
    host, port, _ = served
    data = _series(0, 12)
    solo = _solo_errors(svc, data)
    with GatewayClient(host, port) as client:
        for t in range(len(data)):
            resp = client.step(data[t])
            np.testing.assert_allclose(resp["running_error"], solo[t],
                                       rtol=1e-5, atol=1e-5)
        final = client.end_session()["final"]
    np.testing.assert_allclose(final, solo[-1], rtol=1e-5, atol=1e-5)


def test_connection_drop_evicts_session(served):
    host, port, gw = served
    client = GatewayClient(host, port)
    client.step(_series(1, 4)[0])
    deadline = time.time() + 5
    while gw.pool.active != 1 and time.time() < deadline:
        time.sleep(0.01)
    assert gw.pool.active == 1
    client.close()  # abrupt: no explicit close op
    deadline = time.time() + 5
    while gw.pool.active != 0 and time.time() < deadline:
        time.sleep(0.01)
    assert gw.pool.active == 0  # slot reclaimed on teardown


def test_concurrent_stream_sessions(served, svc):
    """Several connections stream at once; each sees exactly its own
    stream's solo running errors despite sharing the pooled state block."""
    host, port, _ = served
    n, t_len = 3, 8
    data = [_series(10 + i, t_len) for i in range(n)]
    solo = [_solo_errors(svc, d) for d in data]
    results = [None] * n

    def run(i):
        with GatewayClient(host, port) as client:
            for t in range(t_len):
                client.step(data[i][t])
            results[i] = client.end_session()["final"]

    threads = [threading.Thread(target=run, args=(i,)) for i in range(n)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(30)
    for i in range(n):
        np.testing.assert_allclose(results[i], solo[i][-1], rtol=1e-5, atol=1e-5)


def test_session_reopens_after_close(served):
    host, port, _ = served
    with GatewayClient(host, port) as client:
        client.step(_series(2, 4)[0])
        first = client.end_session()["final"]
        with pytest.raises(GatewayClientError) as ei:
            client.end_session()  # nothing open now
        assert ei.value.error == "ValueError"
        client.step(_series(2, 4)[0])  # a later step starts a fresh session
        assert client.end_session()["final"] == pytest.approx(first)


# -- one-shot scoring through the background pump --------------------------


def test_one_shot_scores_match_direct(served, svc):
    """Concurrent one-shot scores over the wire (mixed lengths, out-of-order
    completion) match direct in-process ``AnomalyService.score``."""
    host, port, _ = served
    lens = [5, 9, 16, 7, 12, 6]
    windows = [_series(20 + i, L, seed=3) for i, L in enumerate(lens)]
    with GatewayClient(host, port) as client:
        scores = client.score_many(windows)
    for w, s in zip(windows, scores):
        direct = float(svc.score(jnp.asarray(w[None]))[0])
        np.testing.assert_allclose(s, direct, rtol=1e-5, atol=1e-5)


def test_background_pump_flushes_partial_bucket(served):
    """A single sub-max_batch request completes via the age-triggered
    background pump — no further traffic, no caller-driven pump()."""
    host, port, _ = served
    with GatewayClient(host, port) as client:
        t0 = time.perf_counter()
        score = client.score(_series(30, 6))  # blocks until the pump flushes
        assert time.perf_counter() - t0 < 20.0
        assert np.isfinite(score)


def test_interleaved_stream_and_scores_one_connection(served, svc):
    """One connection can interleave session steps with in-flight one-shot
    submissions; score responses arrive out of order and match by id."""
    host, port, _ = served
    data = _series(40, 6)
    solo = _solo_errors(svc, data)
    windows = [_series(41, 8), _series(42, 11)]
    with GatewayClient(host, port) as client:
        rids = [client.submit(w) for w in windows]
        for t in range(len(data)):  # step responses overtake the scores
            resp = client.step(data[t])
            np.testing.assert_allclose(resp["running_error"], solo[t],
                                       rtol=1e-5, atol=1e-5)
        scores = [client.collect(r)["score"] for r in rids]
    for w, s in zip(windows, scores):
        direct = float(svc.score(jnp.asarray(w[None]))[0])
        np.testing.assert_allclose(s, direct, rtol=1e-5, atol=1e-5)


# -- backpressure + admission over the wire --------------------------------


def test_overload_rejection_over_socket(svc):
    """Queue overload surfaces as an ok:false GatewayOverloadedError
    response on the offending request only; drain answers the rest."""
    gw = svc.open_gateway(capacity=1, max_batch=8, max_queue=2,
                          max_wait_ms=60_000.0)
    server = GatewayServer(gw, port=0, pump_interval_ms=1000.0)
    host, port = server.start_in_thread()
    try:
        with GatewayClient(host, port) as client:
            rids = [client.submit(_series(50 + i, 6)) for i in range(3)]
            with pytest.raises(GatewayClientError) as ei:
                client.collect(rids[2])
            assert ei.value.error == "GatewayOverloadedError"
            # the two admitted requests are still pending (queue intact)
            assert gw.batcher.queue_depth == 2
    finally:
        server.stop_in_thread()  # drain flushes the two pending tickets
    assert gw.batcher.queue_depth == 0
    assert gw.stats()["counters"]["queue.completed"] == 2


def test_pool_full_rejects_fifth_session(served):
    host, port, _ = served  # capacity=4
    clients = [GatewayClient(host, port) for _ in range(5)]
    try:
        for c in clients[:4]:
            c.step(np.zeros(FEATS, np.float32))
        with pytest.raises(GatewayClientError) as ei:
            clients[4].step(np.zeros(FEATS, np.float32))
        assert ei.value.error == "PoolFullError"
        clients[0].end_session()
        clients[4].step(np.zeros(FEATS, np.float32))  # freed slot admits
    finally:
        for c in clients:
            c.close()


def test_oversized_and_malformed_requests(served):
    host, port, gw = served
    with GatewayClient(host, port) as client:
        with pytest.raises(GatewayClientError) as ei:
            client.score(np.zeros((2048, FEATS), np.float32))
        assert ei.value.error == "ValueError" and "max_seq_len" in ei.value.message
        with pytest.raises(GatewayClientError) as ei:
            client.request("warp")  # unknown op
        assert "unknown op" in ei.value.message
        with pytest.raises(GatewayClientError) as ei:
            client.step(np.zeros(FEATS + 1, np.float32))  # bad first step
        assert "sample shape" in ei.value.message
        assert gw.pool.active == 0  # ...must not pin a phantom pool slot
        assert client.ping()  # connection survived all three


# -- live recalibration over the wire --------------------------------------


def test_recalibrate_over_socket_flips_alerts(served, svc):
    host, port, gw = served
    data = _series(60, 6)
    try:
        with GatewayClient(host, port) as client:
            base = client.score(data)
            assert "alert" not in client.request(
                "score", series=data.tolist())  # uncalibrated: no alert field
            out = client.recalibrate(base - 1e-6)
            assert out["threshold"] == pytest.approx(base - 1e-6)
            assert client.request("score", series=data.tolist())["alert"] is True
            # the resident-session path alerts off the same live threshold
            client.step(data[0])
            assert "alert" in client.step(data[1])
            out = client.recalibrate(None)  # live disable
            assert out["threshold"] is None
            assert "alert" not in client.request("score", series=data.tolist())
    finally:
        gw.recalibrate(threshold=None)  # svc is module-scoped: restore


# -- failure injection through the transport -------------------------------


def test_engine_failure_mid_flush_leaves_server_serving(svc, monkeypatch):
    """Acceptance: a forced engine failure mid-flush answers the affected
    requests with the engine's error and the server keeps serving new
    traffic (no depth leak, no wedge)."""
    gw = svc.open_gateway(capacity=1, max_batch=2, max_wait_ms=5.0)
    fail = [1]
    monkeypatch.setattr(svc.engine, "score_masked",
                        breaking_score_masked(svc.engine, fail))
    server = GatewayServer(gw, port=0, pump_interval_ms=2.0)
    host, port = server.start_in_thread()
    try:
        with GatewayClient(host, port) as client:
            rids = [client.submit(_series(70 + i, 6)) for i in range(2)]
            for rid in rids:
                with pytest.raises(GatewayClientError) as ei:
                    client.collect(rid)
                assert "injected engine failure" in ei.value.message
            assert gw.batcher.queue_depth == 0
            score = client.score(_series(72, 6))  # server still serving
            direct = float(svc.score(jnp.asarray(_series(72, 6)[None]))[0])
            np.testing.assert_allclose(score, direct, rtol=1e-5, atol=1e-5)
        assert gw.stats()["counters"]["queue.failed"] == 2
    finally:
        server.stop_in_thread()

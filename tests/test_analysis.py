"""Static-analysis gate: every rule id against a known-bad fixture
(exact finding ids + line numbers), the committed-baseline round trip,
and the repo-walk clean check CI relies on.

The fixtures live in ``tests/fixtures/analysis/`` — one file (or role
pair, for the cross-file contract rules) per rule.  Each case runs the
engine with EXPLICIT paths, which bypasses targeting globs and runs
every rule, so the expected set doubles as a no-false-positive check:
any other rule firing on the fixture fails the exact-set assertion.
"""
import json
from pathlib import Path

import pytest

from repro.analysis import AnalysisEngine, Baseline, default_rules
from repro.analysis.__main__ import main as cli_main

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).parent / "fixtures" / "analysis"

# rule id under test, fixture files, exact expected {(rule, line), ...}
CASES = [
    ("JAX101", ["jax101.py"], {("JAX101", 7)}),
    ("JAX102", ["jax102.py"], {("JAX102", 8), ("JAX102", 9)}),
    ("JAX103", ["jax103.py"], {("JAX103", 7)}),
    ("JAX104", ["jax104.py"], {("JAX104", 7)}),
    ("JAX105", ["jax105.py"], {("JAX105", 5), ("JAX105", 9)}),
    ("JAX106", ["jax106.py"], {("JAX106", 6)}),
    ("ASY201", ["asy201.py"], {("ASY201", 5), ("ASY201", 6)}),
    ("ASY202", ["asy202.py"], {("ASY202", 7)}),
    ("ASY203", ["asy203.py"], {("ASY203", 2)}),
    ("ASY204", ["asy204.py"], {("ASY204", 11)}),
    ("ASY205", ["asy205.py"], {("ASY205", 7), ("ASY205", 8)}),
    # wire contract rules need both roles in the file set; the findings
    # anchor in the consumer (client) file
    ("CON301", ["wire_client.py", "wire_server.py"],
     {("CON301", 3), ("CON302", 7)}),
    ("CON302", ["wire_client.py", "wire_server.py"],
     {("CON301", 3), ("CON302", 7)}),
    ("CON303", ["tel_gateway.py", "tel_prometheus.py"], {("CON303", 5)}),
    ("CON304", ["con304.py"], {("CON304", 4), ("CON304", 8)}),
    ("ENGINE000", ["broken.py"], {("ENGINE000", 1)}),
]


@pytest.mark.parametrize("rule_id,files,expected", CASES,
                         ids=[c[0] for c in CASES])
def test_rule_fires_at_exact_lines(rule_id, files, expected):
    engine = AnalysisEngine(ROOT)
    findings = engine.run([FIXTURES / f for f in files])
    got = {(f.rule_id, f.line) for f in findings}
    assert got == expected
    assert any(f.rule_id == rule_id for f in findings)


def test_every_registered_rule_has_a_fixture_case():
    file_rules, repo_rules = default_rules()
    registered = {r.id for r in file_rules} | {r.id for r in repo_rules}
    covered = {rid for _, _, expected in CASES for rid, _ in expected}
    assert registered <= covered, (
        f"rules without a fixture case: {sorted(registered - covered)}"
    )


def test_one_sided_contract_fixture_is_silent():
    # a lone client (or emitter) with no counterpart present must not
    # misfire — the repo rules need both roles to diff
    engine = AnalysisEngine(ROOT)
    findings = engine.run([FIXTURES / "wire_client.py"])
    assert findings == []


def test_known_good_patterns_are_clean(tmp_path):
    good = tmp_path / "good.py"
    good.write_text(
        "import asyncio\n"
        "import jax\n"
        "import jax.numpy as jnp\n"
        "\n\n"
        "@jax.jit\n"
        "def f(x, n: int = 2):\n"
        "    if n > 1:\n"          # branch on an int-annotated config arg
        "        return jnp.abs(x)\n"
        "    return x\n"
        "\n\n"
        "async def pump(q):\n"
        "    async with q.lock:\n"  # asyncio lock across await is fine
        "        await q.flush()\n"
        "    await asyncio.sleep(0.1)\n"
    )
    engine = AnalysisEngine(ROOT)
    assert engine.run([good]) == []


def test_repo_walk_is_clean_against_committed_baseline():
    """The exact check the CI lint job performs."""
    engine = AnalysisEngine(ROOT)
    findings = engine.run()
    baseline = Baseline.load(ROOT / "analysis" / "baseline.json")
    new, suppressed, stale = baseline.split(findings)
    assert new == [], "\n".join(f.render() for f in new)
    assert stale == [], f"stale baseline entries: {stale}"
    # zero-silent-suppression invariant: every suppression carries a
    # non-placeholder reason
    for entry in baseline.entries.values():
        assert entry["reason"]
        assert not entry["reason"].startswith("unreviewed")


def test_fingerprint_survives_line_drift(tmp_path):
    mod = tmp_path / "mod.py"
    body = "import time\n\n\nasync def f():\n    time.sleep(1)\n"
    engine = AnalysisEngine(ROOT)
    mod.write_text(body)
    first = engine.run([mod])
    mod.write_text("# moved\n# down\n" + body)
    second = engine.run([mod])
    assert [f.fingerprint for f in first] == [f.fingerprint for f in second]
    assert [f.line + 2 for f in first] == [f.line for f in second]


def test_baseline_requires_a_reason(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({
        "version": 1,
        "entries": [{"fingerprint": "a" * 16, "rule": "ASY201",
                     "path": "x.py", "line": 1, "snippet": "s",
                     "reason": ""}],
    }))
    with pytest.raises(ValueError, match="reason"):
        Baseline.load(path)


def test_baseline_rejects_unknown_version(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError, match="version"):
        Baseline.load(path)


# -- CLI -------------------------------------------------------------------


def _write_bad(path: Path) -> None:
    path.write_text("import time\n\n\nasync def f():\n    time.sleep(1)\n")


def test_cli_baseline_round_trip(tmp_path, capsys):
    bad = tmp_path / "bad_mod.py"
    _write_bad(bad)
    baseline = tmp_path / "baseline.json"
    common = [str(bad), "--root", str(ROOT), "--baseline", str(baseline)]

    assert cli_main(common) == 1                       # finding, no baseline
    assert cli_main(common + ["--update-baseline"]) == 0
    assert baseline.exists()
    capsys.readouterr()

    assert cli_main(common) == 0                       # baselined -> clean
    out = capsys.readouterr().out
    assert "ASY201" in out and "baselined" in out

    # the gate stays a gate: a NEW non-baselined finding still fails
    worse = tmp_path / "worse_mod.py"
    worse.write_text("def kick(loop, coro):\n    loop.create_task(coro)\n")
    rc = cli_main([str(bad), str(worse), "--root", str(ROOT),
                   "--baseline", str(baseline)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "ASY203" in out and "worse_mod.py:2" in out


def test_cli_json_format_and_report(tmp_path, capsys):
    bad = tmp_path / "bad_mod.py"
    _write_bad(bad)
    report = tmp_path / "report.json"
    rc = cli_main([str(bad), "--root", str(ROOT), "--baseline", "",
                   "--format", "json", "--report", str(report)])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload == json.loads(report.read_text())
    assert payload["ok"] is False
    (finding,) = payload["findings"]
    assert finding["rule"] == "ASY201"
    assert finding["line"] == 5
    assert finding["fingerprint"]


def test_cli_update_keeps_reviewed_reasons(tmp_path, capsys):
    bad = tmp_path / "bad_mod.py"
    _write_bad(bad)
    baseline_path = tmp_path / "baseline.json"
    common = [str(bad), "--root", str(ROOT),
              "--baseline", str(baseline_path)]
    cli_main(common + ["--update-baseline"])
    data = json.loads(baseline_path.read_text())
    data["entries"][0]["reason"] = "reviewed: fixture sleeps on purpose"
    baseline_path.write_text(json.dumps(data))
    capsys.readouterr()

    cli_main(common + ["--update-baseline"])           # re-run keeps reason
    data = json.loads(baseline_path.read_text())
    assert data["entries"][0]["reason"] == \
        "reviewed: fixture sleeps on purpose"


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule_id in ("JAX101", "ASY204", "CON303", "CON304"):
        assert rule_id in out

import os
import sys

# tests run on the REAL single CPU device (the 512-device override is
# exclusively for launch/dryrun.py, per the assignment)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# -- shared gateway/transport test helpers (test_gateway.py, test_server.py).
# Both suites check the same contract — pooled/socketed serving is value-
# identical to solo streaming — so the reference data and solo oracle live
# here, one copy.  The AnomalyService fixtures stay per-module on purpose:
# several tests mutate the service (thresholds, monkeypatched engines) and
# module isolation keeps those blast radii apart.

GATEWAY_ARCH = "lstm-ae-f32-d2"
GATEWAY_FEATS = 32


def gateway_series(stream: int, t_len: int = 16, seed: int = 0):
    """Deterministic (T, F) window for logical stream ``stream``."""
    import numpy as np

    rng = np.random.default_rng(np.random.SeedSequence([seed, stream]))
    return rng.standard_normal((t_len, GATEWAY_FEATS)).astype(np.float32)


def breaking_score_masked(engine, fail_times: list, make_exc=None):
    """Wrap ``engine.score_masked`` to raise while ``fail_times[0] > 0``
    (then recover) — the flush-failure injection both suites use."""
    real = engine.score_masked
    if make_exc is None:
        def make_exc():
            return RuntimeError("injected engine failure")

    def broken(batch):
        if fail_times[0] > 0:
            fail_times[0] -= 1
            raise make_exc()
        return real(batch)

    return broken


def solo_stream_errors(svc, samples) -> list:
    """Running errors of one stream stepped alone (B=1), per timestep —
    the oracle every pooled/socketed serving path must match."""
    import jax.numpy as jnp

    sess = svc.stream_start(1)
    out = []
    for x in samples:
        errs, sess = svc.stream_step(jnp.asarray(x[None]), sess)
        out.append(float(errs[0]))
    return out

import os
import sys

# tests run on the REAL single CPU device (the 512-device override is
# exclusively for launch/dryrun.py, per the assignment)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

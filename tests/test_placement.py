"""First-class Placement API (ISSUE 4): the declarative surface must be
hashable/comparable (it is part of the schedule cache key), the
``data_parallel`` shim must map with a warning, the single placement must
be a strict no-op, and — on a forced 4-host-device mesh in a subprocess —
sharded-pool streaming and data-parallel bucket scores must be
bit-equivalent to the unsharded pool and to solo ``stream_step``, with
admission control at ``capacity = slots_per_device x devices``."""
import os
import subprocess
import sys

import pytest

from conftest import GATEWAY_ARCH as ARCH
from repro.config import get_config
from repro.engine import AnomalyService, EngineConfig, Placement, build_engine
from repro.engine.placement import _mesh_for


# -- declarative surface ---------------------------------------------------


def test_placement_defaults_and_constructors():
    assert Placement() == Placement.single() == Placement.data(1)
    assert not Placement.single().is_sharded
    pl = Placement.data(4)
    assert pl.is_sharded and pl.devices_needed == 4
    assert pl == Placement(data_shards=4)
    assert hash(pl) == hash(Placement(data_shards=4))
    assert "Placement.data(4" in repr(pl)
    assert repr(Placement.single()) == "Placement.single()"


def test_placement_pad_rows_and_row_mapping():
    pl = Placement.data(4)
    assert [pl.pad_rows(n) for n in (1, 4, 5, 8, 30)] == [4, 4, 8, 8, 32]
    assert Placement.single().pad_rows(7) == 7
    # contiguous blocks: rows [d*rows/n, (d+1)*rows/n) live on shard d
    assert [pl.shard_of_row(r, 8) for r in range(8)] == [0, 0, 1, 1, 2, 2, 3, 3]


def test_placement_validation():
    with pytest.raises(ValueError, match="data_shards"):
        Placement(data_shards=0)
    with pytest.raises(ValueError, match="must differ"):
        Placement(data_axis="x", stage_axis="x")


def test_placement_from_spec():
    assert Placement.from_spec("data=4") == Placement.data(4)
    assert Placement.from_spec(" data=2 ,") == Placement.data(2)
    assert Placement.from_spec("") == Placement.single()
    with pytest.raises(ValueError, match="axes supported"):
        Placement.from_spec("model=2")
    with pytest.raises(ValueError, match="not an int"):
        Placement.from_spec("data=two")


def test_placement_mesh_requires_devices():
    """A placement wider than the device pool fails loudly at mesh build
    (engines/pools fail fast at construction, not first call)."""
    with pytest.raises(ValueError, match="devices"):
        _mesh_for(1999, "data")
    with pytest.raises(ValueError, match="devices"):
        build_engine(
            get_config(ARCH),
            EngineConfig(schedule="wavefront", placement=Placement.data(1999)),
        )


# -- deprecation shim ------------------------------------------------------


def test_data_parallel_shim_warns_and_maps():
    with pytest.warns(DeprecationWarning, match=r"Placement.data\(3\)"):
        shim = EngineConfig(schedule="wavefront", data_parallel=3)
    explicit = EngineConfig(schedule="wavefront", placement=Placement.data(3))
    assert shim == explicit and hash(shim) == hash(explicit)
    # the placement is the single source of truth: the legacy int folds in
    # and resets, the axis names mirror the placement
    assert shim.placement == Placement.data(3)
    assert shim.data_parallel is None is explicit.data_parallel
    assert shim.data_axis == "data" and shim.stage_axis == "model"


def test_explicit_placement_wins_over_legacy_fields():
    """Two sharded layouts in one config: the explicit placement wins, but
    never silently."""
    with pytest.warns(UserWarning, match="ignoring data_parallel=9"):
        cfg = EngineConfig(
            schedule="wavefront", data_parallel=9, placement=Placement.data(2)
        )
    assert cfg.placement == Placement.data(2) and cfg.data_parallel is None


def test_dataclasses_replace_data_parallel_still_shims():
    """``dataclasses.replace(cfg, data_parallel=N)`` on an unsharded config
    (a PR 1–3 idiom — the replaced config carries a non-None single
    placement) must map through the shim, not silently unshard."""
    import dataclasses

    base = EngineConfig(schedule="wavefront")
    with pytest.warns(DeprecationWarning, match=r"Placement.data\(4\)"):
        cfg = dataclasses.replace(base, data_parallel=4)
    assert cfg.placement == Placement.data(4) and cfg.data_parallel is None


def test_legacy_unshard_request_is_never_silent():
    """``replace(sharded_cfg, data_parallel=1)`` (the legacy 'unshard'
    spelling) cannot win over an explicit sharded placement, but it must
    say so — the real unshard is placement=Placement.single()."""
    import dataclasses

    sharded = EngineConfig(schedule="wavefront", placement=Placement.data(4))
    with pytest.warns(UserWarning, match="ignoring data_parallel=1"):
        cfg = dataclasses.replace(sharded, data_parallel=1)
    assert cfg.placement == Placement.data(4)


def test_dataclasses_replace_placement_unshards_cleanly():
    """``replace(sharded_cfg, placement=Placement.single())`` must yield an
    unsharded config without warnings — a stale legacy mirror must never
    veto an explicit placement (data_parallel folds to None, so there is
    no mirror to conflict with)."""
    import dataclasses
    import warnings

    sharded = EngineConfig(schedule="wavefront", placement=Placement.data(2))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg = dataclasses.replace(sharded, placement=Placement.single())
    assert cfg.placement == Placement.single()


def test_default_config_carries_single_placement():
    cfg = EngineConfig()
    assert cfg.placement == Placement.single()
    assert cfg.data_parallel is None


# -- single-device no-op guarantee ----------------------------------------


@pytest.fixture(scope="module")
def svc():
    return AnomalyService(ARCH, schedule="wavefront")


def test_single_placement_is_noop(svc):
    engine = svc.engine
    assert engine.placement == Placement.single()
    assert engine._sharded == {}  # no sharded variants, no mesh built
    assert engine.with_placement(Placement.single()) is engine

    gw = svc.open_gateway(capacity=4, max_batch=4)
    assert gw.engine is svc.engine           # no engine re-layout
    assert gw.batcher.lanes == 4             # lanes == max_batch, unchanged
    assert gw.pool.slots_per_device == 4     # one device holds everything
    assert "placement" not in gw.stats()     # telemetry unchanged
    assert gw.pool.per_device_active() == [0]


def test_open_gateway_single_placement_kw(svc):
    gw = svc.open_gateway(capacity=2, placement=Placement.single())
    assert gw.engine is svc.engine and gw.service is svc


def test_gateway_placement_needs_devices(svc):
    from repro.gateway import AnomalyGateway

    with pytest.raises(ValueError, match="devices"):
        AnomalyGateway(svc, capacity=4, placement=Placement.data(1998))
    with pytest.raises(ValueError, match="devices"):
        svc.open_gateway(capacity=4, placement=1998)  # int shorthand
    with pytest.raises(TypeError, match="Placement or int"):
        AnomalyGateway(svc, capacity=4, placement="data=2")


# -- sharded serving on a forced 4-host-device mesh ------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import jax, jax.numpy as jnp
from repro.engine import AnomalyService, EngineConfig, Placement

ARCH, FEATS, T = "lstm-ae-f32-d2", 32, 7
pl = Placement.data(4)
svc = AnomalyService(ARCH, schedule="wavefront")
rng = np.random.default_rng(0)

# capacity = slots_per_device x devices, served sharded AND unsharded
cap = 2 * 4
gws = svc.open_gateway(capacity=cap, max_batch=4, placement=pl)
gwu = svc.open_gateway(capacity=cap, max_batch=4)
assert gws.engine is not svc.engine and gws.placement == pl
assert gws.pool.slots_per_device == 2 and gws.batcher.lanes == 4
leaf = jax.tree.leaves(gws.pool._state)[0]
assert len(leaf.sharding.device_set) == 4, leaf.sharding

data = [rng.standard_normal((T, FEATS)).astype(np.float32) for _ in range(cap)]
for i in range(cap):
    gws.admit(i); gwu.admit(i)
# admission control: the sharded pool admits exactly capacity streams
try:
    gws.admit("overflow"); raise SystemExit("overadmitted past capacity")
except Exception as exc:
    assert type(exc).__name__ == "PoolFullError", exc
assert gws.pool.per_device_active() == [2, 2, 2, 2]  # balanced admission

# pooled streaming: sharded == unsharded, stepping irregular subsets
for t in range(T):
    stepping = [i for i in range(cap) if (t + i) % 3 != 2]
    rs = gws.step({i: data[i][t] for i in stepping})
    ru = gwu.step({i: data[i][t] for i in stepping})
    for i in stepping:
        np.testing.assert_array_equal(rs[i], ru[i])

# ... and both equal solo stream_step (the PR-2 oracle), per stream
for i in (0, 3, 7):
    sess = svc.stream_start(1)
    for t in range(T):
        if (t + i) % 3 != 2:
            errs, sess = svc.stream_step(jnp.asarray(data[i][t][None]), sess)
    np.testing.assert_allclose(gws.pool.error_of(i), float(errs[0]),
                               rtol=1e-6, atol=1e-7)

# evict -> slot frees -> readmission balances back onto the same device
final_s, final_u = gws.evict(5), gwu.evict(5)
np.testing.assert_array_equal(final_s, final_u)
gws.admit("fresh")
assert gws.pool.per_device_active() == [2, 2, 2, 2]

# data-parallel bucket scoring: sharded flush (padded to per-device
# multiple) == unsharded flush == direct B=1 scoring
lens = [5, 9, 16, 7, 12, 6, 31, 8]
windows = [rng.standard_normal((L, FEATS)).astype(np.float32) for L in lens]
ss, su = gws.score(windows), gwu.score(windows)
np.testing.assert_array_equal(ss, su)
for w, s in zip(windows[:3], ss[:3]):
    np.testing.assert_allclose(
        s, float(svc.score(jnp.asarray(w[None]))[0]), rtol=1e-6, atol=1e-7)

# telemetry: mesh layout + per-device occupancy and flush fill observable
st = gws.stats()
assert st["placement"]["data"] == 4
assert st["placement"]["slots_per_device"] == 2
assert st["placement"]["device_active"] == [2, 2, 2, 2]
assert len(st["gauge_vecs"]["pool.device_active"]) == 4
assert len(st["gauge_vecs"]["queue.device_fill"]) == 4
assert "placement" not in gwu.stats()

# uneven capacity pads the block but never admits the padding rows
gw6 = svc.open_gateway(capacity=6, placement=pl)
assert gw6.pool._block == 8 and gw6.pool.slots_per_device == 2
for i in range(6):
    gw6.admit(i)
try:
    gw6.admit("pad-row"); raise SystemExit("admitted a padding row")
except Exception as exc:
    assert type(exc).__name__ == "PoolFullError", exc

# the deprecation shim maps to the sharded placement
import warnings
with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    shim_cfg = EngineConfig(schedule="wavefront", data_parallel=4)
assert shim_cfg.placement == pl

# re-laying a sharded engine back onto the single placement must neither
# warn nor drag the old sharded layout along (legacy mirrors reset)
with warnings.catch_warnings():
    warnings.simplefilter("error")
    down = gws.engine.with_placement(Placement.single())
assert down.placement == Placement.single() and down._sharded == {}

# a service-side param swap must reach the placement-override gateway's
# own engine: it never serves stale params (the open-gateway contract)
orig_params = svc.params
other = AnomalyService(ARCH, schedule="wavefront", seed=123)
svc.recalibrate(params=other.params)
assert gws.engine.params is other.params
w0 = windows[0]
np.testing.assert_allclose(
    gws.score([w0])[0], float(other.score(jnp.asarray(w0[None]))[0]),
    rtol=1e-6, atol=1e-7)

# ... and a swap initiated on a SIBLING gateway routes through the
# service's _bind, so the placement-override gateway rebinds too
gwu.recalibrate(params=orig_params)
assert gws.engine.params is orig_params

# non-divisible batches fall back to the unsharded program, same values
e = gws.engine
b5 = jnp.asarray(np.stack([np.pad(w[:5], ((0, 0), (0, 0))) for w in windows[:5]]))
np.testing.assert_array_equal(
    np.asarray(e.score({"series": b5})),
    np.asarray(svc.engine.score({"series": b5})),
)
print("PLACEMENT_SHARDED_OK")
"""


def test_sharded_gateway_multi_device():
    """The real sharded path on 4 emulated host devices in a subprocess
    (device count is process-global): pooled streaming and bucket scores
    bit-equal to the unsharded pool, equivalence with solo stream_step,
    admission control at slots_per_device x devices, balanced admission,
    per-device telemetry, block padding, and the data_parallel shim."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PLACEMENT_SHARDED_OK" in out.stdout

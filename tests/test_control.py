"""Adaptive serving control plane (repro.control): windowed rate
sensing, priority-aware admission (shed bottom class first, legacy
clients untouched), SLO-feedback batching with hysteresis and bounded
steps, the autoscaler's utilization band, and the wiring — in-process
GatewayControl on submit()/pump, supervisor ControlLoop over injected
front stats — including the controller.jsonl decision journal."""
import json

import numpy as np
import pytest

from conftest import (
    GATEWAY_ARCH as ARCH,
    gateway_series as _series,
)
from repro.control import (
    AdmissionController,
    Autoscaler,
    BatchingController,
    CONTROLLER_LOG,
    ControlConfig,
    ControlLoop,
    TokenBucket,
    enable_control,
)
from repro.engine import AnomalyService
from repro.gateway import AnomalyGateway, GatewayOverloadedError
from repro.gateway.telemetry import Telemetry, _RateWindow
from repro.obs.prometheus import render_stats


@pytest.fixture(scope="module")
def svc():
    return AnomalyService(ARCH, schedule="wavefront")


# -- sliding-window rates (the satellite bugfix) ----------------------------


def test_rate_window_tracks_recent_not_lifetime():
    w = _RateWindow(0.0, window_s=10.0, intervals=20)
    for i in range(100):  # 100 events in the first second
        w.add(i / 100.0)
    assert w.rate(1.0) == pytest.approx(100.0, rel=0.05)
    # 60 idle seconds later the lifetime mean is ~1.6/s; the window is 0
    assert w.rate(61.0) == 0.0


def test_rate_window_partial_fill_is_unbiased():
    w = _RateWindow(0.0, window_s=10.0, intervals=20)
    w.add(0.2)
    w.add(0.4)
    # 2 events in 0.5s elapsed: ~4/s, NOT 2/10s — the young ring divides
    # by elapsed time, not the full window span
    assert w.rate(0.5) == pytest.approx(4.0, rel=0.1)


def test_telemetry_windowed_rates_in_stats():
    clock = [0.0]
    tel = Telemetry(clock=lambda: clock[0])
    for i in range(50):
        clock[0] = i * 0.1
        tel.count("queue.submitted")
    clock[0] = 5.0
    s = tel.stats()
    assert s["arrival_rps_window"] == pytest.approx(10.0, rel=0.1)
    assert s["completed_rps_window"] == 0.0
    clock[0] = 100.0  # long idle: windows drain to zero, lifetime would not
    assert tel.stats()["arrival_rps_window"] == 0.0


# -- runtime batching knobs -------------------------------------------------


def test_set_knobs_clamps_to_compiled_lanes(svc):
    gw = AnomalyGateway(svc, capacity=1, max_batch=4, max_wait_ms=5.0)
    lanes = gw.batcher.lanes
    applied = gw.batcher.set_knobs(max_batch=10 * lanes, max_wait_ms=-3.0)
    # max_batch never escapes [1, lanes] (the compiled shapes), wait
    # floors at 0 — a controller can actuate freely without recompiles
    assert applied == {"max_batch": lanes, "max_wait_ms": 0.0}
    assert gw.batcher.set_knobs(max_batch=0)["max_batch"] == 1
    assert gw.batcher.set_knobs(max_wait_ms=2.5) == {
        "max_batch": 1, "max_wait_ms": 2.5}


# -- admission: priority classes + tenant buckets ---------------------------


def test_admission_sheds_bottom_class_first():
    adm = AdmissionController(classes=3, clock=lambda: 0.0)
    # class-2 limit is a third of the queue, class-1 two thirds, class-0
    # the full queue — shedding starts at the bottom and climbs
    assert adm.depth_limit(0, 60) == 60
    assert adm.depth_limit(1, 60) == 40
    assert adm.depth_limit(2, 60) == 20
    adm.admit(depth=19, max_queue=60, priority=2)
    with pytest.raises(GatewayOverloadedError):
        adm.admit(depth=20, max_queue=60, priority=2)
    adm.admit(depth=20, max_queue=60, priority=1)   # p1 still fits
    adm.admit(depth=59, max_queue=60, priority=0)   # p0 keeps the flat limit
    with pytest.raises(GatewayOverloadedError):
        adm.admit(depth=60, max_queue=60, priority=0)
    d = adm.describe()
    assert d["shed_by_class"] == {"0": 1.0, "1": 0.0, "2": 1.0}


def test_admission_none_priority_is_flat_class0():
    """Legacy clients (no ``priority`` field) behave bit-for-bit like the
    flat gateway: admitted to the full queue, shed only at max_queue."""
    adm = AdmissionController(classes=3, clock=lambda: 0.0)
    assert adm.normalize(None) == 0
    assert adm.normalize(99) == 2   # clamped into [0, classes)
    assert adm.normalize(-5) == 0
    adm.admit(depth=59, max_queue=60)           # no priority kwarg at all
    with pytest.raises(GatewayOverloadedError):
        adm.admit(depth=60, max_queue=60)
    assert adm.describe()["shed_by_class"]["0"] == 1.0


def test_token_bucket_refill_and_burst_cap():
    b = TokenBucket(rate=2.0, burst=4.0, now=0.0)
    assert all(b.try_take(0.0) for _ in range(4))   # burst drained
    assert not b.try_take(0.0)
    assert b.try_take(0.5)                          # 0.5s * 2/s = 1 token
    assert not b.try_take(0.5)
    b.try_take(100.0)                               # refill caps at burst
    assert b.tokens == pytest.approx(3.0)


def test_admission_tenant_rate_limit_is_per_tenant():
    clock = [0.0]
    adm = AdmissionController(classes=1, tenant_rate=5.0,
                              clock=lambda: clock[0])
    for _ in range(10):  # burst defaults to 2*rate
        adm.admit(depth=0, max_queue=64, tenant="mallory")
    with pytest.raises(GatewayOverloadedError, match="rate limit"):
        adm.admit(depth=0, max_queue=64, tenant="mallory")
    adm.admit(depth=0, max_queue=64, tenant="alice")  # other tenants fine
    d = adm.describe()
    assert d["rate_limited"] == 1.0
    assert d["tenants_tracked"] == 2


# -- batching controller: feedforward, hysteresis, bounded steps ------------


def _bc(**kw):
    kw.setdefault("slo_p95_ms", 10.0)
    kw.setdefault("floor_ms", 2.0)
    kw.setdefault("lanes", 16)
    return BatchingController(**kw)


def _obs(bc, p95, **kw):
    kw.setdefault("fill", 0.5)
    kw.setdefault("depth", 0)
    kw.setdefault("arrival_rps", 100.0)
    kw.setdefault("max_batch", 8)
    kw.setdefault("max_wait_ms", 4.0)
    return bc.decide(p95_ms=p95, **kw)


def test_batching_prior_spends_quarter_of_budget():
    bc = _bc()  # budget = 8ms -> prior wait 2ms, capped by wait_cap 6.4ms
    knobs = bc.prior_knobs(32, 0.0)
    assert knobs["max_batch"] == 16  # clamped to lanes
    assert knobs["max_wait_ms"] == pytest.approx(2.0)


def test_batching_infeasible_slo_pins_wait_once():
    bc = _bc(slo_p95_ms=1.0, floor_ms=2.0)
    assert not bc.feasible
    first = _obs(bc, 5.0)
    assert first["action"] == "pin_wait"
    assert first["knobs"] == {"max_wait_ms": 0.0}
    # said once — afterwards it holds instead of thrashing
    assert _obs(bc, 5.0)["action"] == "hold"
    assert _obs(bc, 0.5)["reason"] == "slo_infeasible"


def test_batching_hysteresis_needs_patience_then_cools_down():
    bc = _bc(patience=2, cooldown_ticks=2)
    assert _obs(bc, 15.0)["action"] == "hold"       # 1st hot tick: wait
    act = _obs(bc, 15.0)                            # 2nd: act
    assert act["action"] == "shrink_wait"
    assert act["knobs"]["max_wait_ms"] == pytest.approx(2.0)  # bounded /2
    assert _obs(bc, 15.0)["reason"] == "cooldown"   # then quiet
    assert _obs(bc, 15.0)["reason"] == "cooldown"
    assert bc.actions == 1


def test_batching_over_slo_with_full_batches_grows_batch():
    bc = _bc(patience=1)
    d = _obs(bc, 15.0, fill=0.95, max_batch=8)
    assert d["action"] == "grow_batch"
    assert d["knobs"]["max_batch"] == 16  # doubled, clamped to lanes


def test_batching_under_slo_grows_wait_toward_cap():
    bc = _bc(patience=1)
    d = _obs(bc, 1.0, max_wait_ms=4.0)  # far under 0.6*slo
    assert d["action"] == "grow_wait"
    assert d["knobs"]["max_wait_ms"] == pytest.approx(6.4)  # wait_cap
    assert _obs(bc, 7.0)["reason"] in ("cooldown", "in_band")


def test_batching_idle_ticks_hold():
    bc = _bc(patience=1)
    # p95 of 0 means "no traffic this window", not "fast": hold
    assert _obs(bc, 0.0)["action"] == "hold"


# -- autoscaler -------------------------------------------------------------


def test_autoscaler_scales_up_on_sustained_overload():
    a = Autoscaler(min_workers=1, max_workers=4, worker_rps=100.0,
                   patience=2, cooldown_ticks=1)
    assert a.decide(arrival_rps=150.0, workers=1)["delta"] == 0  # patience
    d = a.decide(arrival_rps=150.0, workers=1)
    assert d["delta"] == +1 and d["reason"] == "over_capacity"
    assert d["utilization"] == pytest.approx(1.5)
    assert a.decide(arrival_rps=150.0, workers=2)["reason"] == "cooldown"


def test_autoscaler_scales_down_only_to_min():
    a = Autoscaler(min_workers=1, max_workers=4, worker_rps=100.0,
                   patience=2, cooldown_ticks=0)
    for _ in range(2):
        d = a.decide(arrival_rps=10.0, workers=2)
    assert d["delta"] == -1 and d["reason"] == "under_utilized"
    for _ in range(2):
        d = a.decide(arrival_rps=10.0, workers=1)
    assert d["delta"] == 0 and d["reason"] == "idle_at_min"


def test_autoscaler_depth_saturation_triggers_without_rate():
    a = Autoscaler(min_workers=1, max_workers=4, worker_rps=1e6,
                   patience=1, cooldown_ticks=0)
    d = a.decide(arrival_rps=1.0, workers=1, queue_depth=600, max_queue=1024)
    assert d["delta"] == +1  # depth_frac 0.59 > 0.5 despite idle util


def test_autoscaler_respects_bounds_immediately():
    a = Autoscaler(min_workers=2, max_workers=3, worker_rps=100.0)
    assert a.decide(arrival_rps=0.0, workers=1)["reason"] == "below_min"
    assert a.decide(arrival_rps=9e9, workers=5)["reason"] == "above_max"


# -- in-process plane: gateway.submit() + pump ticks ------------------------


def test_gateway_priority_shed_order_and_counters(svc):
    """Under forced overload p2 sheds first and p0 rides the flat limit;
    the per-class counters land in stats() and /metrics."""
    gw = AnomalyGateway(svc, capacity=1, max_batch=8, max_queue=6,
                        max_wait_ms=1e9)
    enable_control(gw, ControlConfig(priority_classes=3))
    for i in range(4):
        gw.submit(_series(i, 6), priority=0)
    # depth 4 >= class-2 limit (2) and class-1 limit (4): both shed
    with pytest.raises(GatewayOverloadedError):
        gw.submit(_series(90, 6), priority=2)
    with pytest.raises(GatewayOverloadedError):
        gw.submit(_series(91, 6), priority=1)
    gw.submit(_series(92, 6), priority=0)           # p0 still admitted
    gw.submit(_series(93, 6))                       # legacy: class 0
    with pytest.raises(GatewayOverloadedError):
        gw.submit(_series(94, 6), priority=0)       # flat limit reached
    s = gw.stats()
    assert s["counters"]["admission.shed_p2"] == 1
    assert s["counters"]["admission.shed_p1"] == 1
    assert s["counters"]["admission.shed_p0"] == 1
    assert s["counters"]["admission.admitted_p0"] == 6
    assert s["control"]["admission"]["shed_by_class"]["2"] == 1.0
    text = render_stats(s)
    assert "repro_admission_shed_p2_total 1" in text
    assert "repro_control_ticks" in text
    gw.flush()


def test_gateway_without_control_ignores_priority(svc):
    """No control plane attached: the wire fields are inert and the flat
    queue-depth limit is the only admission check (backward compat)."""
    gw = AnomalyGateway(svc, capacity=1, max_batch=8, max_queue=3,
                        max_wait_ms=1e9)
    assert gw.control is None
    for i in range(3):
        gw.submit(_series(i, 6), priority=2, tenant="x")
    with pytest.raises(GatewayOverloadedError):
        gw.submit(_series(9, 6), priority=0)  # priority buys nothing
    assert "admission.shed_p0" not in gw.stats()["counters"]
    assert "control" not in gw.stats()
    gw.flush()


def test_gateway_control_ticks_on_pump_and_journals(tmp_path):
    clock = [0.0]
    svc = AnomalyService(ARCH, schedule="wavefront")
    gw = AnomalyGateway(svc, capacity=1, max_batch=4, max_wait_ms=2.0,
                        clock=lambda: clock[0])
    ctl = enable_control(
        gw,
        ControlConfig(slo_p95_ms=500.0, tick_interval_s=1.0, arch=ARCH,
                      floor_timesteps=16),
        event_dir=str(tmp_path),
    )
    assert ctl.batching is not None and ctl.floor_ms > 0.0
    # the feedforward prior already bounded the wait below the budget
    assert gw.batcher.max_wait_ms <= ctl.batching.wait_cap_ms
    gw.submit(_series(0, 6))
    assert ctl.maybe_tick() is None     # not due yet
    clock[0] = 1.5
    # in production the transport's pump loop drives this (server.py)
    assert ctl.maybe_tick() is not None
    assert ctl.ticks == 1
    clock[0] = 1.7
    assert ctl.maybe_tick() is None     # next tick not due
    assert ctl.ticks == 1
    s = gw.stats()
    assert s["control"]["ticks"] == 1
    assert s["control"]["slo_p95_ms"] == 500.0
    lines = [json.loads(ln) for ln in
             (tmp_path / CONTROLLER_LOG).read_text().splitlines()]
    assert lines and lines[0]["kind"] == "control_tick"
    assert lines[0]["scope"] == "gateway"
    assert lines[0]["tick"] == 1
    assert "action" in lines[0] and "p95_ms" in lines[0]


# -- supervisor plane: ControlLoop over injected front stats ----------------


class _FakeFront:
    """Records actuations; stats are injected per tick, so no workers."""

    def __init__(self):
        self.batching_calls = []
        self.ups = 0
        self.downs = 0
        self.control = None

    def set_batching(self, **kw):
        self.batching_calls.append(kw)
        return {**kw, "workers": 2, "attempted": 2}

    def scale_up(self):
        self.ups += 1
        return {"index": self.ups, "workers": 1 + self.ups}

    def scale_down(self):
        self.downs += 1
        return {"dropped_tickets": 0, "clean": True, "workers": 2}


def _front_stats(p95_bucket_counts, *, arrival=0.0, depth=0, workers=2,
                 filled=0, slots=0):
    return {
        "arrival_rps_window": arrival,
        "queue_depth": depth,
        "max_batch": 8,
        "workers": {"count": workers},
        "counters": {"batch.filled": filled, "batch.slots": slots},
        "histograms": {"request_ms": {"counts": p95_bucket_counts,
                                      "count": sum(p95_bucket_counts.values()),
                                      "sum": 0.0}},
    }


def test_control_loop_ticks_scale_and_journal(tmp_path):
    from repro.config import get_config

    cfg = get_config(ARCH)
    front = _FakeFront()
    loop = ControlLoop(
        front,
        ControlConfig(slo_p95_ms=1e4, autoscale_min=1, autoscale_max=4,
                      worker_rps=100.0, patience=1, arch=ARCH,
                      floor_timesteps=16,
                      extra={"max_wait_ms": 2.0}),
        lanes=8, max_queue=64, model_cfg=cfg.lstm_ae,
        event_dir=str(tmp_path),
    )
    assert front.control is loop    # attached like gateway.control
    assert loop.floor_ms > 0.0
    # tick 1: overload (util 2.5) — patience satisfied at tick 2
    loop.tick(_front_stats({}, arrival=500.0, workers=2))
    d = loop.tick(_front_stats({}, arrival=500.0, workers=2))
    assert d["scale"]["delta"] == +1 and front.ups == 1
    # idle long enough (cooldown 3, patience 2) — eventually drains one
    for _ in range(8):
        d = loop.tick(_front_stats({}, arrival=1.0, workers=3))
    assert front.downs == 1
    assert d["scale"]["delta"] <= 0
    desc = loop.describe()
    assert desc["ticks"] == 10
    assert desc["autoscale"]["actions"] == 2
    lines = [json.loads(ln) for ln in
             (tmp_path / CONTROLLER_LOG).read_text().splitlines()]
    assert len(lines) == 10
    assert all(ln["scope"] == "front" for ln in lines)
    assert lines[1]["scale"]["reason"] == "over_capacity"


def test_control_loop_batching_actuates_through_front(tmp_path):
    front = _FakeFront()
    loop = ControlLoop(
        front,
        ControlConfig(slo_p95_ms=10.0, patience=1, cooldown_ticks=0,
                      min_wait_ms=0.25, extra={"max_wait_ms": 4.0}),
        lanes=8, event_dir=str(tmp_path),
    )
    from repro.obs.histogram import bucket_index

    assert loop.batching is not None
    assert loop.floor_ms == 0.0     # no model_cfg: pure-feedback mode
    hot = {bucket_index(50.0): 10}  # every request far over the 10ms SLO
    loop.tick(_front_stats(hot, arrival=100.0))
    assert front.batching_calls     # shrink_wait fanned out
    assert front.batching_calls[0]["max_wait_ms"] == pytest.approx(2.0)
    assert loop.describe()["knobs"]["max_wait_ms"] == pytest.approx(2.0)
    loop.stop()                     # never started: stop is a clean no-op

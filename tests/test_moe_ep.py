"""Expert-parallel all_to_all MoE (the §Perf dispatch fix) must match the
single-device scatter path bit-for-bit when nothing is dropped."""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.config.core import ModelConfig, MoEConfig
from repro.distributed.sharding import mesh_context, rules_for_mesh
from repro.layers.moe import apply_moe, apply_moe_ep, init_moe
from repro.launch.mesh import make_host_mesh

cfg = ModelConfig(
    name="t", family="transformer", num_layers=1, d_model=32, num_heads=4,
    num_kv_heads=4, d_ff=64, vocab_size=64,
    moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=8.0, impl="ep_a2a"),
)
params = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

# reference: single-device scatter path (no mesh)
y_ref, aux_ref = apply_moe(params, x, cfg)

mesh = make_host_mesh((2, 4), ("data", "model"))
rules = rules_for_mesh(mesh)

def run(p, xx):
    with mesh_context(mesh, rules):
        return apply_moe_ep(p, xx, cfg)

y_ep, aux_ep = jax.jit(run)(params, x)
np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-4)

# gradients flow through the a2a path
def loss(p):
    with mesh_context(mesh, rules):
        y, aux = apply_moe_ep(p, x, cfg)
    return jnp.sum(jnp.square(y)) + 0.01 * aux
g = jax.jit(jax.grad(loss))(params)
gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
assert np.isfinite(gnorm) and gnorm > 0

# decode variant (S=1 -> replicated tokens + psum combine)
x1 = jax.random.normal(jax.random.PRNGKey(2), (8, 1, 32))
y_ref1, aux_ref1 = apply_moe(params, x1, cfg)
y_ep1, aux_ep1 = jax.jit(run)(params, x1)
np.testing.assert_allclose(np.asarray(y_ep1), np.asarray(y_ref1), rtol=2e-4, atol=2e-5)
np.testing.assert_allclose(float(aux_ep1), float(aux_ref1), rtol=1e-4)
print("MOE_EP_OK", gnorm)
"""


def test_moe_ep_matches_scatter():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MOE_EP_OK" in out.stdout

"""Observability plane (repro.obs): spans/tracer, the JSONL event log,
Prometheus exposition + the /metrics endpoint, engine compile profiling
in stats(), and the end-to-end traced request whose stages must sum to
the observed wire latency."""
import json
import urllib.request

import pytest

from conftest import GATEWAY_ARCH as ARCH, gateway_series as _series
from repro.engine import AnomalyService
from repro.gateway.client import GatewayClient
from repro.gateway.server import GatewayServer
from repro.obs import EventLog, MetricsServer, Span, Tracer, render_stats


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def svc():
    return AnomalyService(ARCH, schedule="wavefront")


@pytest.fixture
def served(svc):
    gw = svc.open_gateway(capacity=4, max_batch=4, max_wait_ms=10.0)
    server = GatewayServer(gw, port=0, pump_interval_ms=2.0)
    host, port = server.start_in_thread()
    yield host, port, gw
    server.stop_in_thread()


# -- spans / tracer ---------------------------------------------------------


def test_span_marks_accumulate_and_sum():
    clk = FakeClock()
    tracer = Tracer(clock=clk)
    span = tracer.start("step")
    clk.advance(0.001)
    span.mark("dispatch")
    clk.advance(0.003)
    span.mark("compute")
    span.stage("wire", 2.0)  # externally measured stage
    tracer.finish(span)
    assert span.stages["dispatch"] == pytest.approx(1.0)
    assert span.stages["compute"] == pytest.approx(3.0)
    assert span.stage_sum_ms() == pytest.approx(6.0)
    assert span.total_ms == pytest.approx(4.0)  # wall, not incl. external
    wire = span.to_wire()
    assert set(wire) == {"id", "stages", "total_ms"}
    assert wire["id"].startswith("t")


def test_tracer_sampling_emits_every_nth_span(tmp_path):
    path = tmp_path / "events.jsonl"
    clk = FakeClock()
    events = EventLog(path, clock=clk)
    tracer = Tracer(clock=clk, events=events, sample_every=3)
    for _ in range(7):
        tracer.finish(tracer.start("step"))
    events.close()
    kinds = [json.loads(line)["kind"]
             for line in path.read_text().splitlines()]
    assert kinds.count("span") == 3  # 1-in-3, first included: 1, 4, 7
    with pytest.raises(ValueError):
        Tracer(sample_every=0)


def test_event_log_noop_and_jsonl_schema(tmp_path):
    noop = EventLog(None)
    assert not noop.enabled
    noop.emit("boot", worker=0)  # must not raise
    path = tmp_path / "sub" / "log.jsonl"  # parent dir auto-created
    log = EventLog(path, clock=FakeClock(5.0))
    log.emit("boot", worker=1, pid=42)
    log.emit("drain", active_streams=0)
    log.close()
    log.emit("late", x=1)  # after close: swallowed, not raised
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert rows == [
        {"ts": 5.0, "kind": "boot", "worker": 1, "pid": 42},
        {"ts": 5.0, "kind": "drain", "active_streams": 0},
    ]


# -- prometheus exposition --------------------------------------------------


def test_render_stats_names_types_and_histogram():
    from repro.obs.histogram import Histogram

    h = Histogram()
    h.record_many([1.0, 2.0, 1e9])  # 1e9 ms -> overflow bucket
    text = render_stats({
        "uptime_s": 12.5,
        "counters": {"queue.completed": 7},
        "gauges": {"pool.occupancy": 0.5},
        "gauge_vecs": {"pool.device_active": [1.0, 3.0]},
        "histograms": {"request_ms": h.to_dict()},
        "workers": {"count": 2, "restarts": 1},
    }, labels={"worker": "0"})
    assert '# TYPE repro_queue_completed_total counter' in text
    assert 'repro_queue_completed_total{worker="0"} 7' in text
    assert 'repro_pool_occupancy{worker="0"} 0.5' in text
    assert 'repro_pool_device_active{shard="0",worker="0"} 1' in text
    assert 'repro_pool_device_active{shard="1",worker="0"} 3' in text
    assert 'repro_workers_count{worker="0"} 2' in text
    assert 'repro_request_ms_count{worker="0"} 3' in text
    # cumulative buckets end at +Inf == count
    assert f'repro_request_ms_bucket{{le="+Inf",worker="0"}} 3' in text
    assert text.endswith("\n")


def test_metrics_server_serves_live_gateway(served):
    host, port, gw = served
    ms = MetricsServer(gw.stats, port=0).start()
    try:
        with GatewayClient(host, port) as client:
            client.score(_series(0, 6))
        url = f"http://127.0.0.1:{ms.port}/metrics"
        body = urllib.request.urlopen(url, timeout=10).read().decode()
        assert "repro_queue_completed_total 1" in body
        assert 'repro_request_ms_bucket{le="+Inf"} 1' in body
        assert "repro_uptime_s" in body
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(
                f"http://127.0.0.1:{ms.port}/other", timeout=10)
        assert ei.value.code == 404
    finally:
        ms.stop()


# -- end-to-end traced request ---------------------------------------------


def test_traced_score_stages_cover_e2e(served):
    host, port, gw = served
    with GatewayClient(host, port) as client:
        client.score(_series(1, 6))  # warm the bucket: no compile in spans
        out = client.traced_score(_series(2, 6))
    assert out["trace_id"].startswith("c")
    stages = out["stages"]
    # the acceptance bar: >= 4 named stages, summing to the observed
    # end-to-end latency (within 5%; wire is the exact remainder, so the
    # sum is equal by construction — the tolerance guards rounding)
    server_side = {"dispatch", "queue_wait", "assemble", "compute"}
    assert server_side <= set(stages)
    assert {"serialize", "wire"} <= set(stages)
    assert sum(stages.values()) == pytest.approx(out["e2e_ms"], rel=0.05)
    assert all(v >= 0.0 for v in stages.values())
    assert out["server_ms"] <= out["e2e_ms"]
    # the span also landed in the server-side stage histograms
    s = gw.stats()
    assert s["histograms"]["compute_ms"]["count"] >= 2
    assert s["histograms"]["wire_ms"]["count"] >= 2


def test_untraced_requests_carry_no_trace(served):
    host, port, _ = served
    with GatewayClient(host, port) as client:
        rid = client.submit(_series(3, 6))
        resp = client.collect(rid)
    assert "trace" not in resp


def test_step_trace_over_wire(served):
    host, port, _ = served
    with GatewayClient(host, port) as client:
        resp = client.request("step", x=_series(4, 1)[0].tolist(),
                              trace="t-abc")
        assert resp["trace"]["id"] == "t-abc"
        assert set(resp["trace"]["stages"]) >= {"dispatch", "compute"}
        client.end_session()


# -- engine profiling in stats ---------------------------------------------


def test_engine_profile_and_schedule_cache_in_stats(svc):
    gw = svc.open_gateway(capacity=2, max_batch=2, max_wait_ms=5.0)
    gw.score([_series(5, 6)])
    eng = gw.stats()["engine"]
    before = eng["compiles"]
    assert before >= 1
    assert eng["compile_ms"] > 0.0
    per = eng["per_program"]["score_masked"]
    assert per["compiles"] >= 1
    assert all(len(shape) == 3 for shape in per["shapes"])
    # same shape again: first-call-per-shape proxy records no new compile
    gw.score([_series(6, 6)])
    assert gw.stats()["engine"]["compiles"] == before
    cache = eng["schedule_cache"]
    assert cache["hits"] >= 0 and cache["misses"] >= 1
    json.dumps(eng)  # JSON-safe all the way down


def test_gateway_event_log_records_lifecycle(tmp_path, svc):
    gw = svc.open_gateway(capacity=2, max_batch=2, max_wait_ms=5.0)
    gw.attach_event_log(tmp_path / "gw.jsonl")
    gw.recalibrate(threshold=0.5)
    gw.attach_event_log(None)  # detach closes the file
    rows = [json.loads(line)
            for line in (tmp_path / "gw.jsonl").read_text().splitlines()]
    assert [r["kind"] for r in rows] == ["recalibrate"]
    assert rows[0]["threshold"] == 0.5

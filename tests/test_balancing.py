"""Properties of the paper's dataflow-balancing equations (Section 3.3)."""
import math

import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback shim

from repro.config import get_config
from repro.config.core import LSTMAEConfig
from repro.core.balancing import (
    accelerator_latency_cycles,
    balance_model,
    balanced_rh,
    balanced_rx,
    lstm_layer_flops,
    mvm_h_latency,
    mvm_x_latency,
    sequential_latency_cycles,
    stage_partition,
    utilization,
)
from repro.core.latency import PAPER_RH_M


def test_paper_models_fully_balanced():
    """With the paper's Table-1 RH_m, every module's per-timestep latency
    equals the bottleneck's (Eq 8's purpose)."""
    for name, rh_m in PAPER_RH_M.items():
        cfg = get_config(name).lstm_ae
        balances = balance_model(cfg, rh_m)
        lats = [b.lat_t for b in balances]
        assert len(set(lats)) == 1, f"{name}: unbalanced {lats}"
        assert utilization(balances) == pytest.approx(1.0)


def test_eq8_identity_at_bottleneck():
    # Eq (8) must return RH_m for the bottleneck module itself
    for rh_m in (1, 2, 4, 8):
        assert balanced_rh(32, 32, rh_m) == pytest.approx(rh_m)


@given(
    lh_m=st.sampled_from([16, 32, 64, 128]),
    ratio=st.sampled_from([1, 2, 4, 8]),
    rh_m=st.integers(min_value=1, max_value=8),
)
def test_eq8_exact_balance_for_power_of_two(lh_m, ratio, rh_m):
    """For power-of-two layer sizes (the paper's AE family), Eq (8) gives
    integer RH_i and exact H_t equality."""
    lh_i = lh_m // ratio
    rh_i = balanced_rh(lh_i, lh_m, rh_m)
    assert rh_i == int(rh_i)
    assert mvm_h_latency(lh_i, int(rh_i)) == mvm_h_latency(lh_m, rh_m)


@given(
    lx=st.integers(min_value=4, max_value=128),
    lh=st.integers(min_value=4, max_value=128),
    rh=st.integers(min_value=1, max_value=16),
)
def test_eq7_floor_preserves_bottleneck(lx, lh, rh):
    """Flooring fractional RX keeps X_t <= H_t + LX (i.e. the intra-module
    bottleneck stays the H path up to the one-element rounding remainder)."""
    rx = max(1, math.floor(balanced_rx(lx, lh, rh)))
    x_t = mvm_x_latency(lx, lh, rx)
    h_t = mvm_h_latency(lh, rh)
    if balanced_rx(lx, lh, rh) >= 1:
        assert x_t <= h_t + lx  # floor slack is < 1 cycle/element


def test_eq1_dataflow_beats_sequential():
    """Temporal parallelism's headline claim: for T >> N the dataflow
    latency approaches sum/max = depth-fold speedup over layer-by-layer."""
    cfg = get_config("lstm-ae-f32-d6").lstm_ae
    balances = balance_model(cfg, 1)
    t = 512
    df = accelerator_latency_cycles(t, balances)
    sq = sequential_latency_cycles(t, balances)
    n = len(balances)
    speedup = sq / df
    assert speedup > 0.9 * n  # balanced modules -> ~N-fold


@given(
    costs=st.lists(st.floats(min_value=1, max_value=1e4), min_size=1, max_size=9),
    n_stages=st.integers(min_value=1, max_value=5),
)
@settings(max_examples=200)
def test_stage_partition_optimal(costs, n_stages):
    """The DP must match brute-force enumeration of contiguous partitions."""
    assignment, bottleneck = stage_partition(costs, n_stages)
    # brute force over all contiguous partitions into <= n_stages groups
    n = len(costs)

    def brute(i, stages_left):
        if i == n:
            return 0.0
        if stages_left == 0:
            return float("inf")
        best = float("inf")
        acc = 0.0
        for j in range(i, n):
            acc += costs[j]
            best = min(best, max(acc, brute(j + 1, stages_left - 1)))
        return best

    expected = brute(0, n_stages)
    assert bottleneck == pytest.approx(expected, rel=1e-9)
    # assignment consistency: contiguous, non-decreasing, realises bottleneck
    assert all(b - a in (0, 1) for a, b in zip(assignment, assignment[1:]))
    group_costs = {}
    for c, s in zip(costs, assignment):
        group_costs[s] = group_costs.get(s, 0.0) + c
    assert max(group_costs.values()) == pytest.approx(bottleneck, rel=1e-9)


def test_flops_model_matches_dims():
    assert lstm_layer_flops(32, 16) == 4 * 16 * 48


def test_resource_table_ordering():
    """Paper Table 1: wider models need bigger RH_m; the balanced multiplier
    demand must decrease with RH_m (Eqs 5/6)."""
    f32 = balance_model(get_config("lstm-ae-f32-d2").lstm_ae, 1)
    f64_rh1 = balance_model(get_config("lstm-ae-f64-d2").lstm_ae, 1)
    f64_rh4 = balance_model(get_config("lstm-ae-f64-d2").lstm_ae, 4)
    mults = lambda bs: sum(b.mx + b.mh for b in bs)
    assert mults(f64_rh1) > mults(f32)        # wider at same reuse -> more DSPs
    assert mults(f64_rh4) < mults(f64_rh1)    # higher reuse -> fewer DSPs

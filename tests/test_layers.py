"""Layer-level correctness: blocked attention vs naive, wedge equivalence,
decode vs full recompute, MoE scatter vs dense oracle, RWKV/Mamba
sequence-vs-step equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback shim

from repro.config import reduced_config
from repro.config.core import ModelConfig, MoEConfig
from repro.kernels.ref import ref_attention
from repro.layers.attention import (
    apply_attention,
    blocked_attention,
    decode_attention,
    init_attention,
    init_kv_cache,
)
from repro.layers.moe import apply_moe, init_moe
from repro.layers.norms import apply_norm, init_norm


# ---------------- attention ----------------

@given(
    s=st.sampled_from([16, 64, 100]),
    kv_chunk=st.sampled_from([8, 16, 64]),
    causal=st.booleans(),
    seed=st.integers(min_value=0, max_value=100),
)
@settings(max_examples=20, deadline=None)
def test_blocked_attention_matches_exact(s, kv_chunk, causal, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    b, h, d = 2, 3, 16
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    out = blocked_attention(q, k, v, causal=causal, kv_chunk=kv_chunk)
    ref = jnp.swapaxes(
        ref_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                      jnp.swapaxes(v, 1, 2), causal=causal), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_wedge_qchunks_equivalence():
    """The causal-wedge optimization (q_chunks>1) is numerically identical."""
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    b, s, h, d = 2, 128, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    base = blocked_attention(q, k, v, causal=True, kv_chunk=32, q_chunks=1)
    wedge = blocked_attention(q, k, v, causal=True, kv_chunk=32, q_chunks=4)
    np.testing.assert_allclose(np.asarray(wedge), np.asarray(base), rtol=1e-5, atol=1e-6)


def test_decode_matches_prefill_attention():
    """Decoding token t against the cache == attending position t in a full
    causal pass (GQA + RoPE path)."""
    cfg = reduced_config("tinyllama-1.1b")
    key = jax.random.PRNGKey(5)
    params = init_attention(key, cfg)
    b, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(6), (b, s, cfg.d_model), jnp.float32)

    full, (k_all, v_all) = apply_attention(
        params, x, cfg=cfg, causal=True, return_kv=True, kv_chunk=4
    )

    # replay the last token through the decode path
    cache = init_kv_cache(cfg, b, s, jnp.float32)
    cache = {
        "k": cache["k"].at[:, : s - 1].set(k_all[:, : s - 1]),
        "v": cache["v"].at[:, : s - 1].set(v_all[:, : s - 1]),
    }
    y, _ = decode_attention(
        params, x[:, -1:, :], cache, jnp.int32(s - 1), cfg=cfg
    )
    np.testing.assert_allclose(
        np.asarray(y[:, 0]), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3
    )


# ---------------- MoE ----------------

def _tiny_moe_cfg(impl: str, capacity_factor: float = 8.0) -> ModelConfig:
    return ModelConfig(
        name="t", family="transformer", num_layers=1, d_model=32, num_heads=4,
        num_kv_heads=4, d_ff=64, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=capacity_factor, impl=impl),
    )


def test_moe_scatter_matches_dense_oracle():
    """With ample capacity (nothing dropped) the production scatter path
    must equal the dense GShard oracle."""
    key = jax.random.PRNGKey(7)
    cfg_s = _tiny_moe_cfg("scatter")
    cfg_d = _tiny_moe_cfg("dense")
    params = init_moe(key, cfg_s)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, 32))
    y_s, aux_s = apply_moe(params, x, cfg_s)
    y_d, aux_d = apply_moe(params, x, cfg_d)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_d), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_moe_capacity_dropping_zeroes_tokens():
    """With capacity ~0 every token drops -> output exactly zero (Switch
    semantics: dropped tokens pass through the residual only)."""
    key = jax.random.PRNGKey(9)
    cfg = _tiny_moe_cfg("scatter", capacity_factor=1e-9)
    params = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 16, 32))
    y, _ = apply_moe(params, x, cfg)
    # capacity rounds up to 8 slots; most tokens beyond slot 8 must be zero
    n_zero = int(jnp.sum(jnp.all(y == 0.0, axis=-1)))
    assert n_zero >= 8  # 32 (token,k) pairs into 8 slots/expert -> drops exist


def test_moe_aux_loss_uniform_is_one_and_skew_is_larger():
    """Switch normalisation: balanced dispatch -> aux ~= 1; skewed routing
    (all tokens to one expert) -> aux ~= E/k (worse)."""
    from repro.layers.moe import _aux_loss
    n, e, k = 64, 4, 2
    uniform_probs = jnp.full((n, e), 1.0 / e)
    balanced_idx = jnp.stack(
        [jnp.arange(n) % e, (jnp.arange(n) + 1) % e], axis=1
    ).astype(jnp.int32)
    aux_bal = _aux_loss(uniform_probs, balanced_idx, e)
    assert float(aux_bal) == pytest.approx(1.0, rel=1e-5)
    # skew BOTH signals (aux is linear in f under uniform p): router mass
    # and dispatch concentrated on one expert -> aux = E
    skewed_probs = jnp.zeros((n, e)).at[:, 0].set(1.0)
    skewed_idx = jnp.zeros((n, k), jnp.int32)
    aux_skew = _aux_loss(skewed_probs, skewed_idx, e)
    assert float(aux_skew) == pytest.approx(float(e), rel=1e-5)
    assert float(aux_skew) > float(aux_bal)


# ---------------- recurrent layers: sequence == chained steps ----------------

def test_wkv_chunked_matches_exact_scan():
    """The §Perf chunked-matmul WKV (GLA-style tiles) == the exact per-step
    scan, including carried state and uneven lengths."""
    from repro.layers.rwkv import wkv_scan, wkv_scan_chunked
    key = jax.random.PRNGKey(21)
    ks = jax.random.split(key, 6)
    b, s, h, hd = 2, 50, 3, 32
    r = jax.random.normal(ks[0], (b, s, h, hd)) * 0.3
    k = jax.random.normal(ks[1], (b, s, h, hd)) * 0.3
    v = jax.random.normal(ks[2], (b, s, h, hd)) * 0.3
    # decays above the numerical clamp (exp(-4)) so both paths are exact
    w = jnp.exp(-jnp.exp(jax.random.uniform(ks[3], (b, s, h, hd), minval=-6.0, maxval=0.5)))
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    s0 = jax.random.normal(ks[5], (b, h, hd, hd)) * 0.1
    y1, st1 = wkv_scan(r, k, v, w, u, s0)
    y2, st2 = wkv_scan_chunked(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st1), rtol=2e-4, atol=2e-5)


def test_wkv_chunked_grads_finite():
    from repro.layers.rwkv import wkv_scan_chunked
    key = jax.random.PRNGKey(22)
    ks = jax.random.split(key, 4)
    b, s, h, hd = 1, 32, 2, 16
    r = jax.random.normal(ks[0], (b, s, h, hd)) * 0.3
    k = jax.random.normal(ks[1], (b, s, h, hd)) * 0.3
    v = jax.random.normal(ks[2], (b, s, h, hd)) * 0.3
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, hd))))
    u = jnp.zeros((h, hd))
    s0 = jnp.zeros((b, h, hd, hd))

    def loss(args):
        y, _ = wkv_scan_chunked(*args, u, s0)
        return jnp.sum(jnp.square(y))

    g = jax.grad(loss)((r, k, v, w))
    for t in g:
        assert bool(jnp.isfinite(t).all())


def test_rwkv_sequence_equals_steps():
    from repro.layers.rwkv import (
        apply_time_mix, apply_time_mix_step, init_time_mix,
    )
    cfg = reduced_config("rwkv6-7b")
    key = jax.random.PRNGKey(13)
    params = init_time_mix(key, cfg)
    b, s = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(14), (b, s, cfg.d_model))
    y_seq, (x_last, st_seq) = apply_time_mix(params, x, cfg)

    h = cfg.d_model // cfg.rwkv.head_dim
    x_prev = jnp.zeros((b, cfg.d_model))
    st = jnp.zeros((b, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim))
    ys = []
    for t in range(s):
        y_t, (x_prev, st) = apply_time_mix_step(params, x[:, t], cfg, x_prev, st)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_seq), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_seq), rtol=2e-4, atol=2e-5)


def test_mamba_sequence_equals_steps():
    from repro.layers.mamba import apply_mamba, apply_mamba_step, init_mamba, init_mamba_state
    cfg = reduced_config("jamba-v0.1-52b")
    key = jax.random.PRNGKey(15)
    params = init_mamba(key, cfg)
    b, s = 2, 6
    x = jax.random.normal(jax.random.PRNGKey(16), (b, s, cfg.d_model))
    y_seq, st_seq = apply_mamba(params, x, cfg, chunk=4)

    st = init_mamba_state(cfg, b, x.dtype)
    ys = []
    for t in range(s):
        y_t, st = apply_mamba_step(params, x[:, t], cfg, st)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_seq), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(st["ssm"]), np.asarray(st_seq["ssm"]), rtol=2e-4, atol=2e-5
    )


def test_rwkv_model_prefill_then_decode_consistent():
    """Full-model check: prefill state + decode steps == teacher-forced run."""
    from repro.models import build_model
    cfg = reduced_config("rwkv6-7b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(17))
    b, s = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(18), (b, s + 1), 0, cfg.vocab_size)
    logits_full, _ = api.prefill(params, {"tokens": tokens})
    # prefill on the prefix, then decode the last token
    logits_pre, state = api.prefill(params, {"tokens": tokens[:, :-1]})
    logits_dec, _ = api.decode(params, tokens[:, -1:], state, jnp.int32(s))
    full_again, _ = api.prefill(params, {"tokens": tokens})
    np.testing.assert_allclose(
        np.asarray(logits_dec.astype(jnp.float32)),
        np.asarray(full_again.astype(jnp.float32)), rtol=3e-2, atol=3e-2,
    )


# ---------------- norms ----------------

@pytest.mark.parametrize("kind", ["rmsnorm", "layernorm", "nonparametric_ln"])
def test_norms_normalize(kind):
    p = init_norm(kind, 64)
    x = jax.random.normal(jax.random.PRNGKey(19), (4, 64)) * 5 + 3
    y = apply_norm(p, x, kind)
    if kind in ("layernorm", "nonparametric_ln"):
        np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(jnp.var(y, -1)), 1.0, rtol=1e-3)
    else:
        np.testing.assert_allclose(
            np.asarray(jnp.mean(jnp.square(y), -1)), 1.0, rtol=1e-3
        )

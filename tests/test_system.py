"""End-to-end behaviour tests for the paper's system.

The paper's claim chain, as executable assertions:
 1. the temporal-parallel engine computes exactly what layer-by-layer does;
 2. balancing makes every module's per-timestep latency equal (util -> 1);
 3. the combined system detects time-series anomalies after benign-only
    training;
 4. the analytical model reproduces the paper's published tables;
 5. the surrounding framework (train step, checkpoint, recovery) composes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config
from repro.core import (
    balance_model,
    init_lstm_ae,
    lstm_ae_sequential,
    utilization,
    wavefront_forward,
)
from repro.core.anomaly import calibrate_threshold, evaluate_detection
from repro.core.latency import PAPER_RH_M, fpga_latency_ms
from repro.data import TimeseriesConfig, make_batch
from repro.models import build_model
from repro.training import build_train_step, init_train_state


def test_paper_claim_chain():
    # (1) schedule equivalence on the paper's largest model
    cfg = get_config("lstm-ae-f64-d6")
    params = init_lstm_ae(jax.random.PRNGKey(0), cfg)
    xs = jax.random.normal(jax.random.PRNGKey(1), (16, 4, 64))
    np.testing.assert_allclose(
        np.asarray(wavefront_forward(params, xs)),
        np.asarray(lstm_ae_sequential(params, xs)),
        rtol=1e-5, atol=1e-6,
    )
    # (2) balanced dataflow
    for name, rh_m in PAPER_RH_M.items():
        assert utilization(balance_model(get_config(name).lstm_ae, rh_m)) == 1.0
    # (4) table reproduction (spot check)
    assert fpga_latency_ms(get_config("lstm-ae-f64-d2").lstm_ae, 64, 4).ms == pytest.approx(
        0.350, rel=0.15
    )


def test_full_pipeline_train_serve_detect(tmp_path):
    """(3) + (5): train -> checkpoint -> restore -> serve -> detect."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    model_cfg = get_config("lstm-ae-f32-d2")
    api = build_model(model_cfg)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=5, total_steps=50)
    state = init_train_state(api, jax.random.PRNGKey(0), tc)
    step = jax.jit(build_train_step(api, tc))
    data_cfg = TimeseriesConfig(features=32, seq_len=24, batch=32)
    for i in range(50):
        series, _ = make_batch(data_cfg, i)
        state, metrics = step(state, {"series": series})
    assert float(metrics["loss"]) < 0.3

    # persist + restore the trained detector (what a deployment would do)
    path = save_checkpoint(tmp_path, 50, state.params)
    restored, _ = restore_checkpoint(
        path, jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    )

    score = jax.jit(lambda p, b: api.prefill(p, b)[0])
    val, _ = make_batch(data_cfg, 777)
    thr = calibrate_threshold(score(restored, {"series": val}))
    test_cfg = TimeseriesConfig(features=32, seq_len=24, batch=128,
                                anomaly_rate=0.3, seed=5)
    series, labels = make_batch(test_cfg, 0)
    report = evaluate_detection(score(restored, {"series": series}), labels, thr)
    assert report.auroc > 0.8


def test_streaming_decode_matches_batch():
    """Streaming one timestep at a time through the cell chain produces the
    same reconstruction as the batch engines (online deployment mode)."""
    cfg = get_config("lstm-ae-f32-d6")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(2))
    b, t = 3, 10
    series = jax.random.normal(jax.random.PRNGKey(3), (b, t, 32))
    batch_recon = lstm_ae_sequential(params, jnp.swapaxes(series, 0, 1))

    state = api.init_cache(b, t)
    outs = []
    for i in range(t):
        y, state = api.decode(params, series[:, i, :], state, jnp.int32(i))
        outs.append(y)
    stream_recon = jnp.stack(outs, axis=0)  # (T, B, F)
    np.testing.assert_allclose(
        np.asarray(stream_recon), np.asarray(batch_recon), rtol=1e-5, atol=1e-6
    )

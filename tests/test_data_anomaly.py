"""Data pipeline determinism + end-to-end anomaly detection (the paper's
application): a trained LSTM-AE must separate benign from anomalous."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config
from repro.core.anomaly import auroc, calibrate_threshold, evaluate_detection
from repro.data import (
    LMDataConfig,
    TimeseriesConfig,
    make_batch,
    make_lm_batch,
    host_slice,
)
from repro.models import build_model
from repro.training import build_train_step, init_train_state


def test_timeseries_deterministic():
    cfg = TimeseriesConfig(features=8, seq_len=16, batch=4, seed=3)
    x1, y1 = make_batch(cfg, 5)
    x2, y2 = make_batch(cfg, 5)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    x3, _ = make_batch(cfg, 6)
    assert not np.array_equal(np.asarray(x1), np.asarray(x3))


def test_lm_batch_properties():
    cfg = LMDataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=1)
    b = make_lm_batch(cfg, 0)
    assert b["tokens"].shape == (4, 32)
    assert int(b["tokens"].max()) < 128
    np.testing.assert_array_equal(
        np.asarray(b["labels"][:, :-1]), np.asarray(b["tokens"][:, 1:])
    )
    sliced = host_slice(b, process_index=0, process_count=2)
    assert sliced["tokens"].shape == (2, 32)


def test_anomaly_injection_increases_error():
    cfg = TimeseriesConfig(features=16, seq_len=32, batch=64, anomaly_rate=0.5, seed=7)
    x, labels = make_batch(cfg, 0)
    assert 0.2 < float(labels.mean()) < 0.8
    # anomalous sequences deviate more from a smooth signal even untrained:
    # use second-difference energy as a crude roughness score
    d2 = jnp.diff(x, n=2, axis=1)
    rough = jnp.mean(jnp.square(d2), axis=(1, 2))
    assert auroc(np.asarray(rough), np.asarray(labels)) > 0.6


def test_lstm_ae_detects_anomalies_end_to_end():
    """Train on benign, score mixed, threshold on val: the paper's pipeline."""
    model_cfg = get_config("lstm-ae-f32-d2")
    api = build_model(model_cfg)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=5, total_steps=60, grad_clip=1.0)
    state = init_train_state(api, jax.random.PRNGKey(0), tc)
    step = jax.jit(build_train_step(api, tc))
    data_cfg = TimeseriesConfig(features=32, seq_len=32, batch=32, anomaly_rate=0.0)
    for i in range(80):
        series, _ = make_batch(data_cfg, i)
        state, metrics = step(state, {"series": series})
    assert float(metrics["loss"]) < 0.35  # learned the benign manifold

    score = jax.jit(lambda p, b: api.prefill(p, b)[0])
    val, _ = make_batch(data_cfg, 1000)
    thr = calibrate_threshold(score(state.params, {"series": val}), k_sigma=3.0)

    test_cfg = TimeseriesConfig(features=32, seq_len=32, batch=128, anomaly_rate=0.4, seed=9)
    series, labels = make_batch(test_cfg, 0)
    errors = score(state.params, {"series": series})
    report = evaluate_detection(errors, labels, thr)
    assert report.auroc > 0.85, f"AUROC {report.auroc:.3f}"
    assert report.recall > 0.5, f"recall {report.recall:.3f}"


def test_auroc_sanity():
    scores = np.array([0.1, 0.2, 0.8, 0.9])
    labels = np.array([0, 0, 1, 1])
    assert auroc(scores, labels) == 1.0
    assert auroc(scores, 1 - labels) == 0.0
    assert auroc(scores, np.array([0, 1, 0, 1])) == pytest.approx(0.75)

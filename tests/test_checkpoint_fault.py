"""Checkpoint roundtrip / atomicity / GC + fault-tolerant recovery loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_checkpoint,
    list_checkpoints,
    restore_checkpoint,
    save_checkpoint,
)
from repro.config import TrainConfig, reduced_config
from repro.data import LMDataConfig, LMIterator
from repro.distributed.fault import (
    FailureInjector,
    HeartbeatMonitor,
    run_with_recovery,
)
from repro.models import build_model
from repro.training import build_train_step, init_train_state


def _tiny_state():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.int32(7)},
        "tup": (jnp.zeros((5,)), jnp.full((1,), 3.5)),
    }


def test_checkpoint_roundtrip_exact(tmp_path):
    state = _tiny_state()
    path = save_checkpoint(tmp_path, 42, state, extra_meta={"foo": "bar"})
    restored, meta = restore_checkpoint(path, jax.eval_shape(lambda: state))
    assert meta["step"] == 42 and meta["foo"] == "bar"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_bfloat16_roundtrips_via_dtype_map(tmp_path):
    """bf16 leaves are stored upcast to float32 (npz cannot hold
    ml_dtypes) but the ORIGINAL dtype is recorded in meta.json and wins
    on restore — this used to silently hand back float32 when the
    restore target didn't pin bf16 itself."""
    import json

    state = {"w": jnp.full((4, 2), 1.5, jnp.bfloat16),
             "b": jnp.arange(3, dtype=jnp.float32)}
    path = save_checkpoint(tmp_path, 7, state)
    meta = json.loads((path / "meta.json").read_text())
    assert meta["dtypes"] == {"w": "bfloat16", "b": "float32"}
    with np.load(path / "leaves.npz") as disk:
        assert disk["w"].dtype == np.float32  # lossless upcast on disk

    # restore against a target that does NOT pin bf16: the saved dtype
    # still wins (this was the silent-upcast bug)
    target = {"w": jax.ShapeDtypeStruct((4, 2), jnp.float32),
              "b": jax.ShapeDtypeStruct((3,), jnp.float32)}
    restored, _ = restore_checkpoint(path, target)
    assert restored["w"].dtype == jnp.bfloat16
    assert restored["b"].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(state["w"], np.float32))

    # legacy checkpoint (no dtype map): fall back to the target's dtype
    meta.pop("dtypes")
    (path / "meta.json").write_text(json.dumps(meta))
    legacy, _ = restore_checkpoint(path, target)
    assert legacy["w"].dtype == jnp.float32


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(tmp_path, 1, _tiny_state())
    assert not any(p.name.endswith(".tmp") for p in tmp_path.iterdir())
    assert list_checkpoints(tmp_path) == [1]


def test_async_checkpointer_gc(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    for s in (10, 20, 30, 40):
        ck.save(s, _tiny_state())
    ck.wait()
    assert list_checkpoints(tmp_path) == [30, 40]
    assert latest_checkpoint(tmp_path).name == "step_00000040"


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = save_checkpoint(tmp_path, 0, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(path, {"w": jax.ShapeDtypeStruct((5, 4), jnp.float32)})


def _recovery_setup(tmp_path, fail_at=()):
    cfg = reduced_config("olmo-1b")
    api = build_model(cfg)
    tc = TrainConfig(loss_chunk=16)
    state = init_train_state(api, jax.random.PRNGKey(0), tc)
    step = jax.jit(build_train_step(api, tc))
    it = LMIterator(LMDataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4))
    injector = FailureInjector(fail_at) if fail_at else None
    return state, step, it, injector


def test_recovery_matches_clean_run(tmp_path):
    """Kill the 'job' twice; the recovered loss trajectory must equal the
    clean run's — the determinism property that matters at 1000 nodes."""
    total = 25
    state, step, it, _ = _recovery_setup(tmp_path / "clean")
    _, clean_losses = run_with_recovery(
        state=state, train_step=step, iterator=it, total_steps=total,
        ckpt_dir=tmp_path / "clean", ckpt_every=10,
    )
    state2, step2, it2, injector = _recovery_setup(tmp_path / "faulty", fail_at=(7, 17))
    _, fault_losses = run_with_recovery(
        state=state2, train_step=step2, iterator=it2, total_steps=total,
        ckpt_dir=tmp_path / "faulty", ckpt_every=10, injector=injector,
    )
    np.testing.assert_allclose(fault_losses, clean_losses, rtol=1e-5)


def test_straggler_detection():
    mon = HeartbeatMonitor(straggler_factor=2.0)
    for i in range(20):
        mon.report("host0", 0.10)
        mon.report("host1", 0.11)
    mon.report("host2", 0.5)  # 5x median
    assert mon.stragglers() == ["host2"]
    assert 0.09 < mon.p50() < 0.2

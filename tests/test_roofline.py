"""The roofline extraction machinery: trip-count-aware HLO cost model and
collective parsing (validated against programs with known exact costs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.extract import active_param_count, model_flops_estimate
from repro.roofline.hlo_cost import analyze_hlo


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    t = analyze_hlo(_compiled_text(lambda x, y: x @ y, a, b))
    assert t.flops == 2 * 256 * 512 * 128


def test_scan_flops_multiplied_by_trip_count():
    def body(h, w):
        return jnp.tanh(h @ w), None

    def scanned(h, ws):
        return jax.lax.scan(body, h, ws)[0]

    h = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((13, 64, 64), jnp.float32)
    t = analyze_hlo(_compiled_text(scanned, h, ws))
    assert t.flops == 13 * 2 * 64**3
    assert not t.notes


def test_nested_scan_flops():
    def inner(h, w):
        return jnp.tanh(h @ w), None

    def outer(h, ws):
        return jax.lax.scan(inner, h, ws)[0], None

    def nested(h, ws):
        return jax.lax.scan(outer, h, ws)[0]

    h = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 5, 32, 32), jnp.float32)
    t = analyze_hlo(_compiled_text(nested, h, ws))
    assert t.flops == 15 * 2 * 32**3


def test_grad_flops_counts_fwd_and_bwd():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    t = analyze_hlo(_compiled_text(jax.grad(loss, argnums=(0, 1)), x, x))
    assert t.flops == 3 * 2 * 128**3  # fwd + dW + dX


def test_bytes_scale_with_tensor_size():
    a1 = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a2 = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    f = lambda x: jnp.tanh(x) * 2.0 + 1.0
    t1 = analyze_hlo(_compiled_text(f, a1))
    t2 = analyze_hlo(_compiled_text(f, a2))
    assert t2.bytes > 10 * t1.bytes  # 16x elements


def test_collective_parse_psum():
    """shard_map psum lowers to all-reduce; payload must be counted."""
    import subprocess, sys, os, json, textwrap
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.roofline.hlo_cost import analyze_hlo
        mesh = jax.make_mesh((4,), ("x",))
        def f(a):
            return jax.lax.psum(a, "x")
        g = shard_map(f, mesh=mesh, in_specs=P("x"), out_specs=P(), check_rep=False)
        text = jax.jit(g).lower(jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile().as_text()
        t = analyze_hlo(text)
        print("COLL", int(t.coll_bytes), t.coll_by_op)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("COLL")][0]
    coll = int(line.split()[1])
    # per-device shard is (16,128) f32 = 8192 bytes; all-reduce payload >= that
    assert coll >= 8192, line
    assert "all-reduce" in line


def test_active_param_count_orders_of_magnitude():
    from repro.config import get_config
    # dense: close to the advertised sizes
    assert 1.0e9 < active_param_count(get_config("tinyllama-1.1b")) < 1.35e9
    assert 0.9e9 < active_param_count(get_config("olmo-1b")) < 1.6e9
    assert 17e9 < active_param_count(get_config("internlm2-20b")) < 23e9
    # MoE: active (not total) params
    moonshot = active_param_count(get_config("moonshot-v1-16b-a3b"))
    assert 2e9 < moonshot < 5e9  # "A3B" = ~3B active
    dbrx = active_param_count(get_config("dbrx-132b"))
    assert 30e9 < dbrx < 45e9    # dbrx ~36B active


def test_model_flops_kinds():
    from repro.config import TRAIN_4K, DECODE_32K, get_config
    cfg = get_config("tinyllama-1.1b")
    train = model_flops_estimate(cfg, TRAIN_4K)
    decode = model_flops_estimate(cfg, DECODE_32K)
    tokens = TRAIN_4K.global_batch * TRAIN_4K.seq_len
    assert train == pytest.approx(6 * active_param_count(cfg) * tokens)
    assert decode == pytest.approx(2 * active_param_count(cfg) * DECODE_32K.global_batch)

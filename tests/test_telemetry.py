"""Telemetry + mergeable histograms (repro.gateway.telemetry /
repro.obs.histogram): percentile edge cases, merge exactness (summed
bucket counts == histogram of the union of samples, bit for bit),
reset/epoch semantics with an injected clock, and counter/gauge
round-trips through ``gateway.stats()``."""
import json

import pytest

from _hypothesis_compat import given, settings, st
from repro.gateway.telemetry import REQUEST_HIST, Telemetry, percentile
from repro.obs.histogram import (
    NUM_BUCKETS,
    OVERFLOW_INDEX,
    Histogram,
    bucket_bound,
    bucket_index,
)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# -- bucket layout ----------------------------------------------------------


def test_bucket_layout_is_total_and_monotone():
    assert bucket_index(0.0) == 0
    assert bucket_index(-1.0) == 0
    assert bucket_index(float("nan")) == 0
    assert bucket_index(float("inf")) == OVERFLOW_INDEX
    assert bucket_index(1e12) == OVERFLOW_INDEX
    last = -1
    for v in (1e-4, 0.01, 0.5, 1.0, 1.4, 3.7, 100.0, 9999.0, 1e7):
        idx = bucket_index(v)
        assert idx >= last
        assert bucket_bound(idx) <= v
        last = idx
    assert NUM_BUCKETS == OVERFLOW_INDEX + 1


def test_bucket_bounds_round_trip_exactly():
    """A value sitting exactly on a bucket's lower bound lands in that
    bucket (no float drift) — the property the front-wide bit-equal
    percentile guarantee rests on."""
    for idx in range(OVERFLOW_INDEX):
        assert bucket_index(bucket_bound(idx)) == idx


# -- percentile edge cases --------------------------------------------------


def test_percentile_empty_and_single():
    h = Histogram()
    assert h.percentile(50) == 0.0
    assert h.percentile(0) == 0.0
    assert h.percentile(100) == 0.0
    assert h.mean() == 0.0
    h.record(bucket_bound(37))
    for p in (0, 50, 99, 100):
        assert h.percentile(p) == bucket_bound(37)
    assert h.count == 1


def test_percentile_p0_p100_are_min_max_buckets():
    h = Histogram()
    values = [bucket_bound(i) for i in (5, 80, 200, 300)]
    h.record_many(values)
    assert h.percentile(0) == values[0]
    assert h.percentile(100) == values[-1]


def test_percentile_matches_raw_nearest_rank_on_bound_values():
    """Samples drawn exactly from bucket bounds: histogram percentiles
    must be BIT-EQUAL to ``telemetry.percentile`` over the raw sorted
    samples (same nearest-rank convention, lower-bound representative)."""
    values = sorted(bucket_bound(7 + 13 * k) for k in range(25))
    h = Histogram()
    h.record_many(values)
    for p in (0, 25, 50, 75, 90, 95, 99, 100):
        assert h.percentile(p) == percentile(values, p)


# -- merge exactness --------------------------------------------------------


def _hist_of(values):
    h = Histogram()
    h.record_many(values)
    return h


@settings(max_examples=40)
@given(
    ia=st.lists(st.integers(0, OVERFLOW_INDEX), min_size=0, max_size=30),
    ib=st.lists(st.integers(0, OVERFLOW_INDEX), min_size=0, max_size=30),
    ic=st.lists(st.integers(0, OVERFLOW_INDEX), min_size=0, max_size=30),
)
def test_merge_is_associative_commutative_and_union_exact(ia, ib, ic):
    """merge(A, B, C) in any order/grouping == histogram of the union of
    the samples — exact because the bucket boundaries are fixed."""
    a, b, c = ([bucket_bound(i) for i in idx] for idx in (ia, ib, ic))
    union = _hist_of(a + b + c)
    abc = Histogram.merged([_hist_of(a), _hist_of(b), _hist_of(c)])
    cba = Histogram.merged([_hist_of(c), _hist_of(b), _hist_of(a)])
    a_bc = _hist_of(a).merge_from(
        _hist_of(b).merge_from(_hist_of(c)))
    for h in (abc, cba, a_bc):
        assert h.counts == union.counts
        assert h.count == union.count
        assert h.sum == pytest.approx(union.sum)
        for p in (50, 95, 99):
            assert h.percentile(p) == union.percentile(p)


def test_merged_percentiles_equal_raw_union_across_telemetries():
    """K Telemetry instances (K workers) fed bound-valued latencies:
    merging their request histograms reproduces raw-sample union
    percentiles bit for bit — the WorkerFront.stats() guarantee."""
    import random

    rng = random.Random(11)
    tels = [Telemetry(clock=FakeClock()) for _ in range(3)]
    all_values = []
    for tel in tels:
        for _ in range(40):
            v = bucket_bound(rng.randrange(1, OVERFLOW_INDEX))
            tel.observe_latency_ms(v)
            all_values.append(v)
    # over-the-pipe shape: to_dict / from_dict round trip, then merge
    merged = Histogram.merged(
        Histogram.from_dict(tel.stats()["histograms"][REQUEST_HIST])
        for tel in tels
    )
    raw = sorted(all_values)
    assert merged.count == len(raw)
    for p in (50, 95, 99):
        assert merged.percentile(p) == percentile(raw, p)


def test_histogram_dict_round_trip_is_json_safe():
    h = _hist_of([0.25, 1.0, 7.5, 1e5])
    wire = json.loads(json.dumps(h.to_dict()))
    back = Histogram.from_dict(wire)
    assert back.counts == h.counts
    assert back.count == h.count
    assert back.sum == h.sum
    assert Histogram.from_dict(None).count == 0
    assert Histogram.from_dict({}).percentile(99) == 0.0


# -- Telemetry semantics ----------------------------------------------------


def test_uptime_epoch_explicit_and_reset_rearms():
    clk = FakeClock(100.0)
    tel = Telemetry(clock=clk)
    # well-defined immediately: no lazy first-event epoch
    assert tel.uptime_s == pytest.approx(1e-9)
    clk.advance(2.0)
    tel.count("queue.completed", 10)
    assert tel.stats()["requests_per_s"] == pytest.approx(5.0)
    tel.reset()
    assert tel.stats()["counters"] == {}
    assert tel.stats()["requests_per_s"] == 0.0
    clk.advance(1.0)  # rates start from the reset, not from construction
    tel.count("queue.completed", 3)
    assert tel.stats()["requests_per_s"] == pytest.approx(3.0)


def test_gauge_vec_separate_from_scalar_gauges():
    tel = Telemetry(clock=FakeClock())
    tel.gauge("pool.occupancy", 0.5)
    tel.gauge_vec("pool.device_active", [1, 2, 0])
    assert tel.gauges == {"pool.occupancy": 0.5}
    assert all(isinstance(v, float) for v in tel.gauges.values())
    s = tel.stats()
    assert s["gauges"]["pool.occupancy"] == 0.5
    assert s["gauge_vecs"]["pool.device_active"] == [1.0, 2.0, 0.0]


def test_detail_flag_gates_stage_histograms_only():
    on, off = Telemetry(clock=FakeClock()), Telemetry(clock=FakeClock(),
                                                      detail=False)
    for tel in (on, off):
        tel.observe_latency_ms(3.0)
        tel.observe_stage("compute_ms", 1.5)
    assert REQUEST_HIST in on.histograms and "compute_ms" in on.histograms
    assert REQUEST_HIST in off.histograms  # request latency always on
    assert "compute_ms" not in off.histograms


def test_counters_gauges_round_trip_through_gateway_stats():
    """End-to-end through a real gateway: counted events and gauges come
    back from ``stats()`` unchanged and JSON-serializable."""
    from conftest import GATEWAY_ARCH, gateway_series
    from repro.engine import AnomalyService

    svc = AnomalyService(GATEWAY_ARCH, schedule="sequential")
    gw = svc.open_gateway(capacity=2, max_batch=2, max_wait_ms=5.0)
    gw.admit("a")
    gw.step({"a": gateway_series(0, 1)[0]})
    gw.evict("a")
    gw.score([gateway_series(1, 6)])
    s = json.loads(json.dumps(gw.stats()))  # must be JSON-safe end to end
    assert s["counters"]["pool.admitted"] == 1
    assert s["counters"]["queue.completed"] == 1
    assert s["gauges"]["pool.occupancy"] == 0.0
    assert s["latency_ms"]["count"] == 1
    assert s["latency_ms"]["p50"] > 0.0
    assert s["histograms"][REQUEST_HIST]["count"] == 1
    # per-stage decomposition present when detail is on (the default)
    for stage in ("queue_wait_ms", "assemble_ms", "compute_ms"):
        assert s["histograms"][stage]["count"] == 1
    assert s["histograms"]["pool_step_ms"]["count"] == 1

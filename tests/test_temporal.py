"""The paper's core claim in software: wavefront == layer-by-layer, and the
multi-device pipeline (shard_map + ppermute FIFOs) == both."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback shim

from repro.config import get_config
from repro.config.core import LSTMAEConfig, ModelConfig
from repro.core import (
    init_lstm_ae,
    lstm_ae_sequential,
    schedule_table,
    wavefront_forward,
)


def _random_ae(depth: int, features: int, t: int, b: int, seed: int):
    cfg = ModelConfig(
        name="t", family="lstm_ae",
        num_layers=depth,
        lstm_ae=LSTMAEConfig(input_features=features, depth=depth),
    )
    key = jax.random.PRNGKey(seed)
    params = init_lstm_ae(key, cfg)
    xs = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, b, features))
    return params, xs


@given(
    depth=st.sampled_from([2, 4, 6]),
    features=st.sampled_from([16, 32, 64]),
    t=st.integers(min_value=1, max_value=12),
    b=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=1000),
)
@settings(max_examples=25, deadline=None)
def test_wavefront_equals_sequential(depth, features, t, b, seed):
    params, xs = _random_ae(depth, features, t, b, seed)
    seq = lstm_ae_sequential(params, xs)
    wav = wavefront_forward(params, xs)
    np.testing.assert_allclose(np.asarray(wav), np.asarray(seq), rtol=1e-5, atol=1e-6)


def test_wavefront_pwl_mode():
    params, xs = _random_ae(2, 32, 8, 2, 7)
    seq = lstm_ae_sequential(params, xs, pwl=True)
    wav = wavefront_forward(params, xs, pwl=True)
    np.testing.assert_allclose(np.asarray(wav), np.asarray(seq), rtol=1e-5, atol=1e-6)


def test_schedule_table_staggered():
    """At steady state every layer is busy (the paper's Fig. 2)."""
    n, t = 4, 10
    table = schedule_table(n, t)
    assert len(table) == t + n - 1
    # wavefront step k=n-1 .. t-1: all n layers active
    for k in range(n - 1, t):
        assert len(table[k]) == n
        layers = [l for l, _ in table[k]]
        steps = [s for _, s in table[k]]
        assert layers == list(range(n))
        assert steps == [k - i for i in range(n)]  # staggered timesteps
    # fill & drain ramps
    assert len(table[0]) == 1
    assert len(table[-1]) == 1


_PIPELINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.config import get_config
from repro.core import init_lstm_ae, lstm_ae_sequential
from repro.core.temporal import build_stage_params, pipelined_forward
from repro.launch.mesh import make_host_mesh

cfg = get_config("lstm-ae-f32-d6")
key = jax.random.PRNGKey(0)
params = init_lstm_ae(key, cfg)
xs = jax.random.normal(jax.random.PRNGKey(1), (11, 4, 32))

mesh = make_host_mesh((2, 4), ("data", "model"))
stage_params, counts, assignment = build_stage_params(params, cfg, 4)
ys = pipelined_forward(stage_params, counts, xs, mesh=mesh, cfg=cfg,
                       stage_axis="model", batch_axes=("data",))
ref = lstm_ae_sequential(params, xs)
np.testing.assert_allclose(np.asarray(ys), np.asarray(ref), rtol=1e-4, atol=1e-5)
print("PIPELINE_OK", assignment)
"""


def test_pipelined_forward_multi_device():
    """Run the shard_map pipeline on 8 emulated devices in a subprocess
    (device count is process-global, so tests keep their single device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", _PIPELINE_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINE_OK" in out.stdout

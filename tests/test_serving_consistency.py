"""Serving-path consistency: for every family, decoding token t against
prefilled state must reproduce the teacher-forced forward at position t.
This is the invariant batched serving relies on (cache correctness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduced_config
from repro.models import build_model


def _tokens(cfg, b, s, key):
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size, jnp.int32)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "moonshot-v1-16b-a3b"])
def test_transformer_decode_consistent_with_prefill(arch):
    cfg = reduced_config(arch)
    if cfg.moe is not None:
        # capacity-based MoE routing is batch-dependent (prefill routes B*S
        # tokens jointly; decode routes B) — give ample capacity so nothing
        # drops and the paths are comparable
        import dataclasses
        cfg = cfg.with_overrides(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = 2, 10
    toks = _tokens(cfg, b, s + 1, jax.random.PRNGKey(1))

    # teacher-forced logits at the last position
    full_logits, _ = api.prefill(params, {"tokens": toks})

    # prefill the prefix, stitch its cache into a decode cache, decode last
    _, prefix_cache = api.prefill(params, {"tokens": toks[:, :-1]})
    cache = api.init_cache(b, s + 1)
    cache = {
        "k": cache["k"].at[:, :, :s].set(prefix_cache["k"].astype(cache["k"].dtype)),
        "v": cache["v"].at[:, :, :s].set(prefix_cache["v"].astype(cache["v"].dtype)),
    }
    dec_logits, _ = api.decode(params, toks[:, -1:], cache, jnp.int32(s))
    if cfg.moe is not None:
        # top-k routing is a discrete boundary: assert the serving-relevant
        # invariant (greedy token identity) instead of elementwise closeness
        np.testing.assert_array_equal(
            np.asarray(jnp.argmax(dec_logits[:, -1], -1)),
            np.asarray(jnp.argmax(full_logits[:, -1], -1)),
        )
    else:
        np.testing.assert_allclose(
            np.asarray(dec_logits.astype(jnp.float32)),
            np.asarray(full_logits.astype(jnp.float32)),
            rtol=6e-2, atol=6e-2,  # bf16 cache round-trip
        )


def test_transformer_decode_unroll_equals_scan():
    """The §Perf unrolled decode loop matches scan (bf16 fusion-order tol)."""
    cfg = reduced_config("tinyllama-1.1b")
    api_scan = build_model(cfg)
    api_unroll = build_model(cfg.with_overrides(decode_loop="unroll"))
    params = api_scan.init(jax.random.PRNGKey(2))
    b = 2
    cache = api_scan.init_cache(b, 16)
    tok = jnp.ones((b, 1), jnp.int32)
    l1, c1 = api_scan.decode(params, tok, cache, jnp.int32(3))
    l2, c2 = api_unroll.decode(params, tok, api_unroll.init_cache(b, 16), jnp.int32(3))
    np.testing.assert_allclose(np.asarray(l1, np.float32), np.asarray(l2, np.float32),
                               rtol=6e-2, atol=6e-2)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(l1[:, -1], -1)), np.asarray(jnp.argmax(l2[:, -1], -1))
    )
    # unroll uses a tuple-of-layers cache; stack it for comparison
    c2_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *c2)
    for a, b_ in zip(jax.tree.leaves(c1), jax.tree.leaves(c2_stacked)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b_, np.float32),
                                   rtol=6e-2, atol=6e-2)


def test_jamba_decode_consistent_with_prefill():
    cfg = reduced_config("jamba-v0.1-52b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(3))
    b, s = 2, 9
    toks = _tokens(cfg, b, s + 1, jax.random.PRNGKey(4))
    full_logits, _ = api.prefill(params, {"tokens": toks})

    _, states = api.prefill(params, {"tokens": toks[:, :-1]})
    # stitch prefill states into decode layout: KV caches padded to s+1
    dec_states = []
    for j, st in enumerate(states):
        if "k" in st:  # attention position
            tmpl = jax.tree.map(
                lambda x: x, api.init_cache(b, s + 1)[j]
            )
            dec_states.append({
                "k": tmpl["k"].at[:, :, :s].set(st["k"].astype(tmpl["k"].dtype)),
                "v": tmpl["v"].at[:, :, :s].set(st["v"].astype(tmpl["v"].dtype)),
            })
        else:
            dec_states.append(st)
    dec_logits, _ = api.decode(params, toks[:, -1:], tuple(dec_states), jnp.int32(s))
    np.testing.assert_allclose(
        np.asarray(dec_logits.astype(jnp.float32)),
        np.asarray(full_logits.astype(jnp.float32)),
        rtol=5e-2, atol=5e-2,
    )


def test_whisper_decode_consistent_with_prefill():
    cfg = reduced_config("whisper-large-v3")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(5))
    b, s = 2, 8
    toks = _tokens(cfg, b, s + 1, jax.random.PRNGKey(6))
    frames = jax.random.normal(jax.random.PRNGKey(7),
                               (b, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    full_logits, _ = api.prefill(params, {"tokens": toks, "frames": frames})

    _, pre = api.prefill(params, {"tokens": toks[:, :-1], "frames": frames})
    cache = api.init_cache(b, s + 1)
    cache = {
        "k": cache["k"].at[:, :, :s].set(pre["k"].astype(cache["k"].dtype)),
        "v": cache["v"].at[:, :, :s].set(pre["v"].astype(cache["v"].dtype)),
        "ck": pre["ck"].astype(cache["ck"].dtype),
        "cv": pre["cv"].astype(cache["cv"].dtype),
    }
    dec_logits, _ = api.decode(params, toks[:, -1:], cache, jnp.int32(s))
    np.testing.assert_allclose(
        np.asarray(dec_logits.astype(jnp.float32)),
        np.asarray(full_logits.astype(jnp.float32)),
        rtol=5e-2, atol=5e-2,
    )


def test_greedy_decode_loop_runs():
    from repro.serving import greedy_decode_loop
    cfg = reduced_config("olmo-1b")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(8))
    b = 2
    cache = api.init_cache(b, 24)
    first = jnp.ones((b, 1), jnp.int32)
    toks, _ = greedy_decode_loop(api, params, cache, first, jnp.int32(0), 8)
    assert toks.shape == (b, 8)
    assert int(toks.max()) < cfg.vocab_size

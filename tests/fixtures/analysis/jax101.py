import jax
import jax.numpy as jnp


@jax.jit
def f(x):
    if x > 0:
        return jnp.abs(x)
    return x

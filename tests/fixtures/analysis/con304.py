def serve(conn):
    try:
        conn.flush()
    except Exception:
        pass
    try:
        conn.close()
    except:
        raise

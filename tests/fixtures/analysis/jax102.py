import jax

LOG = []


@jax.jit
def f(x):
    print("tracing", x)
    LOG.append(x)
    return x

import jax


@jax.jit
def f(x):
    jax.debug.print("x = {}", x)
    return x

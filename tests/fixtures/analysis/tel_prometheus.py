_SCALAR_GAUGES = ("uptime_s", "depth")


def render(stats):
    return [f"{key} {stats[key]}" for key in _SCALAR_GAUGES if key in stats]

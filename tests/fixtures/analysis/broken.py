def broken(:
    pass

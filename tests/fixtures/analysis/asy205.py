import multiprocessing as mp

import jax


def launch(fn):
    ctx = mp.get_context("fork")
    proc = mp.Process(target=fn)
    return ctx, proc

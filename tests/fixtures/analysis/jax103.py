import jax
import numpy as np


@jax.jit
def f(x):
    return np.square(x)

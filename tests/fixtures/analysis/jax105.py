_cache = {}


def lookup(fn, shape):
    return _cache.get(f"{fn.__name__}:{shape}")


def store(fn, value):
    _cache[id(fn)] = value

class Gateway:
    def stats(self) -> dict:
        out = {}
        out.update(depth=self.queue.depth)
        out["inflight"] = self.queue.inflight
        return out

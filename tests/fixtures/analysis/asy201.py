import time


async def handler(reader, writer):
    time.sleep(0.1)
    fh = open("data.txt")
    return fh.read()

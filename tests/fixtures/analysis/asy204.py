import threading


class Registry:
    def __init__(self):
        self._items = {}
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self):
        self._items["boot"] = 1
        with self._lock:
            self._items["ok"] = 2

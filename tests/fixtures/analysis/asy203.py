def kick(loop, coro):
    loop.create_task(coro)

import functools

import jax


@functools.partial(jax.jit, static_argnames=("opts",))
def f(x, opts=[1, 2]):
    return x

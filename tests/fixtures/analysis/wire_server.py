def handle(req):
    series = req["series"]
    return {"ok": True, "score": sum(len(r) for r in series)}

def fetch(sock):
    resp = sock.recv()
    return resp["score"], resp.get("detail")


def send_score(sock, series):
    sock.send({"op": "score", "series": series, "priority": 1})

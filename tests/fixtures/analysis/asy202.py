import threading

_lock = threading.Lock()


async def update(store, key, value):
    with _lock:
        await store.put(key, value)

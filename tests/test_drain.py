"""SIGTERM drain semantics, unit-tested (previously only the
single-server happy path was smoke-asserted): a SIGTERM arriving while
one-shot tickets sit in the micro-batch queue must answer EVERY pending
ticket before the process exits — for the single ``GatewayServer``
(``--http``) and for the multi-worker ``WorkerFront`` (``--workers``)
alike."""
import os
import re
import signal
import socket
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from conftest import GATEWAY_ARCH as ARCH, GATEWAY_FEATS as FEATS
from repro.gateway.client import GatewayClient

_REPO = Path(__file__).resolve().parent.parent


def _spawn_server(extra_args):
    """Launch ``repro.launch.serve --http`` in a subprocess (a real
    process so a real SIGTERM exercises the real drain path); returns
    ``(proc, port)`` once the ready line is printed."""
    import queue
    import threading

    env = dict(os.environ)
    env["PYTHONPATH"] = (str(_REPO / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--arch", ARCH,
         "--http", "--port", "0", "--train-steps", "0", "--capacity", "4",
         # max_batch > pending and an hour-scale max_wait: nothing can
         # flush the bucket before the SIGTERM — except the drain itself
         "--max-batch", "64", "--max-wait-ms", "3600000", *extra_args],
        env=env, cwd=_REPO, stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True,
    )
    # read stdout from a thread: a bare readline() would block past the
    # deadline if the server hangs in boot without printing, turning a
    # 180s fail-fast into the whole CI job's timeout.  The thread keeps
    # collecting until EOF — `collected` (not communicate(), whose pipe
    # this thread has drained) is the server's full output.
    lines: "queue.Queue" = queue.Queue()
    collected: list = []

    def _pump() -> None:
        for line in proc.stdout:
            collected.append(line)
            lines.put(line)

    reader = threading.Thread(target=_pump, daemon=True)
    reader.start()
    deadline = time.monotonic() + 180.0
    port = None
    while time.monotonic() < deadline:
        try:
            line = lines.get(timeout=max(0.1, deadline - time.monotonic()))
        except queue.Empty:
            if proc.poll() is not None:  # died without a ready line
                pytest.fail(f"server exited during startup "
                            f"(rc={proc.poll()}): {''.join(collected)}")
            break
        if "listening on" in line:
            port = int(line.split("listening on ")[1]
                       .split()[0].rsplit(":", 1)[1])
            break
    if port is None:
        proc.kill()
        pytest.fail(f"server never reported its port within 180s: "
                    f"{''.join(collected)}")

    def output(timeout: float) -> str:
        proc.wait(timeout)
        reader.join(10.0)
        return "".join(collected)

    return proc, port, output


@pytest.mark.parametrize("extra_args", [
    pytest.param([], id="single-server"),
    pytest.param(["--workers", "2"], id="worker-front"),
])
def test_sigterm_with_inflight_tickets_answers_everything(extra_args):
    proc, port, output = _spawn_server(extra_args)
    rng = np.random.default_rng(0)
    clients, rids = [], []
    try:
        # two connections x three tickets: under the worker front they
        # may land on different workers — the drain must cover all
        for _ in range(2):
            c = GatewayClient("127.0.0.1", port)
            clients.append(c)
            rids.append([
                c.submit(rng.standard_normal(
                    (6, FEATS)).astype(np.float32) * 0.1)
                for _ in range(3)
            ])
            assert c.ping()  # same-connection ordering: the submits are
            #                  in the server's queue before we SIGTERM
        proc.send_signal(signal.SIGTERM)
        for c, rs in zip(clients, rids):
            for rid in rs:
                resp = c.collect(rid)  # written during drain
                assert resp["ok"] and np.isfinite(resp["score"])
    finally:
        for c in clients:
            try:
                c.close()
            except Exception:
                pass
        try:
            out = output(timeout=120)
        except subprocess.TimeoutExpired:
            proc.kill()
            pytest.fail("server did not exit after SIGTERM drain")
    assert proc.returncode == 0, out
    assert "drained" in out
    if extra_args:  # worker front: every worker clean, nothing dropped
        assert "2/2 workers exited cleanly" in out
        assert "0 dropped tickets" in out
        assert "6 one-shot scores" in out


@pytest.mark.skipif(not hasattr(socket, "SO_REUSEPORT"),
                    reason="WorkerFront needs SO_REUSEPORT")
def test_worker_front_drain_answers_streaming_session_close(tmp_path):
    """A resident streaming session survives until the drain closes its
    connection; its steps all answered, the server exits 0."""
    proc, port, output = _spawn_server(["--workers", "2"])
    try:
        with GatewayClient("127.0.0.1", port) as c:
            for t in range(4):
                resp = c.step(np.zeros(FEATS, np.float32))
                assert resp["ok"]
            proc.send_signal(signal.SIGTERM)
            # the drain evicts the session and closes the connection;
            # further requests fail with a closed connection, not a hang
            with pytest.raises((ConnectionError, OSError)):
                for _ in range(200):
                    c.step(np.zeros(FEATS, np.float32))
                    time.sleep(0.05)
    finally:
        try:
            out = output(timeout=120)
        except subprocess.TimeoutExpired:
            proc.kill()
            pytest.fail("server did not exit after SIGTERM drain")
    assert proc.returncode == 0, out
    # >=4: a step can legitimately race in between the SIGTERM and the
    # drain closing the connection
    m = re.search(r"(\d+) stream-steps over 1 sessions", out)
    assert m and int(m.group(1)) >= 4, out

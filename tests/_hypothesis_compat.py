"""Fallback shim for the ``hypothesis`` property-testing library.

The container image does not ship ``hypothesis``; importing it at module
scope used to crash pytest collection (the seed failure).  When the real
library is available we re-export it unchanged; otherwise a minimal
deterministic stand-in runs each ``@given`` test on ``max_examples``
pseudo-random draws (seeded, so failures reproduce).  Only the strategy
surface the test-suite uses is implemented: ``sampled_from``, ``integers``,
``floats``, ``booleans``, ``lists``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import functools
    import inspect
    import random

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

    st = _Strategies()

    def settings(max_examples: int = 20, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", None) or getattr(
                    fn, "_max_examples", 20
                )
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # pytest must only see the NON-strategy parameters (fixtures);
            # functools.wraps leaks the full signature via __wrapped__.
            del wrapper.__wrapped__
            params = [
                p for name, p in inspect.signature(fn).parameters.items()
                if name not in strategies
            ]
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper

        return deco


strategies = st

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st", "strategies"]

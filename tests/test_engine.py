"""Unified execution-engine API: every registered schedule must produce the
same reconstructions on all four paper configs, the registry must fail
loudly on unknown names, and the AnomalyService lifecycle must hold
together end-to-end."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.core import init_lstm_ae, lstm_ae_sequential
from repro.engine import (
    AnomalyService,
    Engine,
    EngineConfig,
    available_schedules,
    build_engine,
)
from repro.models import build_model

PAPER_ARCHS = ["lstm-ae-f32-d2", "lstm-ae-f32-d6", "lstm-ae-f64-d2", "lstm-ae-f64-d6"]
SCHEDULES = ["sequential", "wavefront", "pipelined", "fused"]


def _setup(arch: str, t: int = 9, b: int = 2):
    cfg = get_config(arch)
    params = init_lstm_ae(jax.random.PRNGKey(0), cfg)
    f = cfg.lstm_ae.input_features
    series = jax.random.normal(jax.random.PRNGKey(1), (b, t, f))
    ref = jnp.swapaxes(lstm_ae_sequential(params, jnp.swapaxes(series, 0, 1)), 0, 1)
    return cfg, params, series, ref


@pytest.mark.parametrize("arch", PAPER_ARCHS)
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_schedule_equivalence(arch, schedule):
    """All schedules agree with the layer-by-layer reference on every paper
    config (the paper's core claim: the schedule changes latency, never
    values — padded-matmul accumulation order allows ~1e-7 float drift)."""
    cfg, params, series, ref = _setup(arch)
    engine = build_engine(cfg, schedule, params=params)
    recon = engine.reconstruct({"series": series})
    np.testing.assert_allclose(
        np.asarray(recon), np.asarray(ref), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_score_is_reconstruction_mse(schedule):
    cfg, params, series, ref = _setup("lstm-ae-f32-d2")
    engine = build_engine(cfg, schedule, params=params)
    scores = engine.score({"series": series})
    expect = jnp.mean(jnp.square(ref - series), axis=(1, 2))
    np.testing.assert_allclose(np.asarray(scores), np.asarray(expect), rtol=1e-5, atol=1e-6)


def test_unknown_schedule_raises():
    cfg = get_config("lstm-ae-f32-d2")
    with pytest.raises(ValueError, match="unknown schedule 'bogus'.*available"):
        build_engine(cfg, "bogus")


def test_registry_lists_builtin_schedules():
    assert set(SCHEDULES) <= set(available_schedules())


def test_engine_rejects_non_lstm_ae():
    cfg = get_config("tinyllama-1.1b")
    with pytest.raises(ValueError, match="lstm_ae"):
        Engine(cfg, "wavefront")


def test_engine_requires_bound_params():
    cfg, params, series, _ = _setup("lstm-ae-f32-d2")
    engine = build_engine(cfg, "wavefront")
    with pytest.raises(ValueError, match="bind"):
        engine.score({"series": series})
    engine.bind(params)
    assert engine.score({"series": series}).shape == (2,)


def test_build_engine_accepts_model_api():
    cfg = get_config("lstm-ae-f32-d2")
    api = build_model(cfg)
    engine = build_engine(api, "sequential")
    assert engine.cfg is cfg


def test_pipelined_single_device_fallback():
    """On one device the pipelined schedule resolves to wavefront (same
    dataflow semantics, no stage axis) instead of failing."""
    cfg = get_config("lstm-ae-f32-d6")
    engine = build_engine(cfg, "pipelined")
    assert engine.schedule.name == "pipelined"
    assert engine.schedule.resolved == "wavefront"
    assert engine.schedule.tag == "pipelined->wavefront"


def test_pipelined_data_parallel_needs_devices():
    """An explicit data-parallel placement must never silently degrade to
    an unsharded single-device run — and the legacy ``data_parallel=N``
    spelling reaches the same check through the deprecation shim."""
    from repro.engine import Placement

    cfg = get_config("lstm-ae-f32-d6")
    with pytest.raises(ValueError, match=r"Placement.data\(2\).*4 devices"):
        build_engine(
            cfg, EngineConfig(schedule="pipelined", placement=Placement.data(2))
        )
    with pytest.warns(DeprecationWarning, match="data_parallel=2"):
        shim = EngineConfig(schedule="pipelined", data_parallel=2)
    assert shim.placement == Placement.data(2)
    with pytest.raises(ValueError, match=r"Placement.data\(2\)"):
        build_engine(cfg, shim)


def test_fused_schedule_uses_pallas_cell():
    """The fused schedule resolves cleanly (interpret fallback off-TPU) and
    keeps the sequential Eq-1 accounting (layer-major walk)."""
    cfg = get_config("lstm-ae-f32-d2")
    engine = build_engine(cfg, "fused")
    assert engine.schedule.resolved == "fused"
    assert engine.schedule.latency_kind == "sequential"


def test_resolve_cache_keyed_and_capped():
    """Regression (ISSUE 2 + ISSUE 4): EngineConfig fields a schedule
    declares it ignores must not split the resolve cache — EXCEPT the
    placement, which is always part of the key so engines differing only
    in device layout never alias one cached program — and resolving many
    distinct configs must stay within the LRU cap instead of leaking
    executors."""
    from repro.engine import (
        Placement,
        Schedule,
        register_schedule,
        resolve_schedule,
        schedule_cache_info,
        unregister_schedule,
    )
    from repro.engine.schedules import SCHEDULE_CACHE_CAPACITY

    cfg = get_config("lstm-ae-f32-d2")
    s0 = resolve_schedule("wavefront", cfg, EngineConfig(schedule="wavefront"))
    s1 = resolve_schedule(
        "wavefront", cfg,
        EngineConfig(schedule="wavefront", n_stages=5, jit=False),
    )
    assert s0 is s1  # wavefront keys on pwl only
    assert s0 is not resolve_schedule(
        "wavefront", cfg, EngineConfig(schedule="wavefront", pwl=True)
    )
    # placement always keys, even for schedules that ignore it (ISSUE 4:
    # sharded and unsharded compiled programs must never collide); no mesh
    # is built at resolve time, so a 3-way layout resolves on one device
    s2 = resolve_schedule(
        "wavefront", cfg,
        EngineConfig(schedule="wavefront", placement=Placement.data(3)),
    )
    assert s2 is not s0
    info = schedule_cache_info()
    assert "placement" in info["always_keyed"]
    assert any("Placement.data(3" in p for p in info["placements"])

    @register_schedule("_cache_probe")  # no config_fields: keys on everything
    def _probe(cfg, ecfg):
        return Schedule("_cache_probe", "_cache_probe", "sequential",
                        lambda p, xs: xs)

    try:
        for i in range(1, 3 * SCHEDULE_CACHE_CAPACITY):
            resolve_schedule(
                "_cache_probe", cfg,
                EngineConfig(schedule="_cache_probe", n_stages=i),
            )
            assert schedule_cache_info()["size"] <= SCHEDULE_CACHE_CAPACITY
    finally:
        unregister_schedule("_cache_probe")
    assert "_cache_probe" not in available_schedules()


def test_stream_matches_batch_reconstruction():
    cfg, params, series, ref = _setup("lstm-ae-f32-d6", t=7, b=3)
    engine = build_engine(cfg, "wavefront", params=params)
    state = engine.init_stream_state(3)
    outs = []
    for t in range(series.shape[1]):
        y_t, state = engine.stream(series[:, t], state)
        outs.append(y_t)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, axis=1)), np.asarray(ref), rtol=1e-5, atol=1e-6
    )


def test_latency_model_per_schedule():
    """Eq-1 accounting follows the bound schedule: dataflow beats
    layer-by-layer cycles for T >> depth (the paper's headline)."""
    cfg = get_config("lstm-ae-f32-d6")
    seq = build_engine(cfg, "sequential").latency_model(64)
    wav = build_engine(cfg, "wavefront").latency_model(64)
    pipe = build_engine(cfg, "pipelined").latency_model(64)
    assert seq.schedule == "sequential"
    assert wav.schedule == "dataflow" and pipe.schedule == "dataflow"
    assert wav.cycles == pipe.cycles
    assert seq.cycles > 2 * wav.cycles


def test_prefill_delegates_to_schedule_registry():
    """ModelAPI.prefill accepts schedule= and routes through the engine."""
    cfg, params, series, ref = _setup("lstm-ae-f32-d2")
    api = build_model(cfg)
    expect = jnp.mean(jnp.square(ref - series), axis=(1, 2))
    for schedule in ("sequential", "wavefront"):
        scores, _ = api.prefill(params, {"series": series}, schedule=schedule)
        np.testing.assert_allclose(
            np.asarray(scores), np.asarray(expect), rtol=1e-5, atol=1e-6
        )
    with pytest.raises(ValueError, match="unknown schedule"):
        api.prefill(params, {"series": series}, schedule="bogus")


def test_anomaly_service_lifecycle():
    """fit -> calibrate -> score/detect/stream on a tiny model; streaming
    running errors equal batch scores."""
    from repro.data import TimeseriesConfig, make_batch

    svc = AnomalyService("lstm-ae-f32-d2", schedule="wavefront")
    dc = TimeseriesConfig(features=32, seq_len=12, batch=16, anomaly_rate=0.0)
    metrics = svc.fit(dc, steps=5)
    assert "mse" in metrics
    thr = svc.calibrate(dc)
    assert svc.threshold == thr > 0
    series, labels = make_batch(
        TimeseriesConfig(features=32, seq_len=12, batch=8, anomaly_rate=0.5, seed=3), 0
    )
    report = svc.detect(series, labels)
    assert 0.0 <= report.anomaly_rate <= 1.0
    sess = svc.stream_start(8)
    for t in range(series.shape[1]):
        errors, sess = svc.stream_step(series[:, t], sess)
    np.testing.assert_allclose(
        np.asarray(errors), np.asarray(svc.score(series)), rtol=1e-5, atol=1e-6
    )


def test_build_score_step_matches_engine():
    """The serving-step builder wraps an engine's scoring under the usual
    mesh-context machinery (the LSTM-AE analogue of build_prefill_step)."""
    from repro.serving import build_score_step

    cfg, params, series, _ = _setup("lstm-ae-f32-d2")
    engine = build_engine(cfg, "wavefront")
    step = build_score_step(engine)
    scores = step(params, {"series": series})
    np.testing.assert_allclose(
        np.asarray(scores),
        np.asarray(engine.bind(params).score({"series": series})),
        rtol=1e-6,
    )


def test_anomaly_service_seed_governs_fit():
    """Two services with different seeds fit different models; same seed is
    deterministic."""
    from repro.data import TimeseriesConfig

    dc = TimeseriesConfig(features=32, seq_len=8, batch=8, anomaly_rate=0.0)
    series = jnp.ones((2, 8, 32))

    def fitted_scores(seed):
        svc = AnomalyService("lstm-ae-f32-d2", seed=seed)
        svc.fit(dc, steps=2)
        return np.asarray(svc.score(series))

    a, b, a2 = fitted_scores(0), fitted_scores(7), fitted_scores(0)
    np.testing.assert_array_equal(a, a2)
    assert np.abs(a - b).max() > 0


def test_anomaly_service_requires_calibration():
    svc = AnomalyService("lstm-ae-f32-d2")
    with pytest.raises(ValueError, match="calibrate"):
        svc.alerts(jnp.zeros((2, 4, 32)))


_MULTI_DEVICE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.config import get_config
from repro.core import init_lstm_ae, lstm_ae_sequential
from repro.engine import EngineConfig, build_engine

cfg = get_config("lstm-ae-f32-d6")
params = init_lstm_ae(jax.random.PRNGKey(0), cfg)
series = jax.random.normal(jax.random.PRNGKey(1), (4, 11, 32))
ref = jnp.swapaxes(lstm_ae_sequential(params, jnp.swapaxes(series, 0, 1)), 0, 1)
for ecfg in (EngineConfig(schedule="pipelined", n_stages=4),
             EngineConfig(schedule="pipelined", n_stages=4, data_parallel=2)):
    e = build_engine(cfg, ecfg, params=params)
    assert e.schedule.resolved == "pipelined", e.schedule
    np.testing.assert_allclose(np.asarray(e.reconstruct({"series": series})),
                               np.asarray(ref), rtol=1e-4, atol=1e-5)
print("ENGINE_PIPELINE_OK")
"""


def test_pipelined_engine_multi_device():
    """The real pipelined path (internal mesh + stage params, incl. 2-way
    data parallelism — the jit-split regression case) on 8 emulated devices
    in a subprocess (device count is process-global)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-c", _MULTI_DEVICE_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ENGINE_PIPELINE_OK" in out.stdout

"""Training substrate: loss decreases, microbatch-accumulation equivalence,
grad compression (error feedback), optimizer behaviour, chunked xent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, reduced_config
from repro.data import LMDataConfig, LMIterator
from repro.models import build_model
from repro.optim import (
    adamw_update,
    compress_grads,
    init_error_feedback,
    init_opt_state,
    lr_schedule,
    quantize_int8,
    dequantize_int8,
)
from repro.training import build_train_step, init_train_state


def test_loss_decreases_tinyllama():
    cfg = reduced_config("tinyllama-1.1b")
    api = build_model(cfg)
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=40,
                     loss_chunk=32, grad_clip=1.0)
    state = init_train_state(api, jax.random.PRNGKey(0), tc)
    step = jax.jit(build_train_step(api, tc))
    it = LMIterator(LMDataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))
    losses = []
    for _ in range(40):
        state, metrics = step(state, next(it))
        losses.append(float(metrics["loss"]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.3, f"loss did not decrease: {first:.3f} -> {last:.3f}"


def test_microbatch_equivalent_gradients():
    """microbatch=2 accumulation == full-batch step (same params out)."""
    cfg = reduced_config("olmo-1b")
    api = build_model(cfg)
    tc1 = TrainConfig(microbatch=1, loss_chunk=16)
    tc2 = TrainConfig(microbatch=2, loss_chunk=16)
    s1 = init_train_state(api, jax.random.PRNGKey(1), tc1)
    s2 = init_train_state(api, jax.random.PRNGKey(1), tc2)
    it = LMIterator(LMDataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8))
    batch = next(it)
    s1n, m1 = jax.jit(build_train_step(api, tc1))(s1, batch)
    s2n, m2 = jax.jit(build_train_step(api, tc2))(s2, batch)
    # microbatch MEAN of per-half losses == full-batch loss (equal halves)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=2e-3)
    for a, b in zip(jax.tree.leaves(s1n.params), jax.tree.leaves(s2n.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)


def test_grad_compression_error_feedback():
    """EF property: quantisation error is carried, so the RUNNING SUM of
    dequantised grads tracks the running sum of true grads."""
    key = jax.random.PRNGKey(2)
    grads = {"w": jax.random.normal(key, (64, 64))}
    err = init_error_feedback(grads)
    total_true = jnp.zeros((64, 64))
    total_deq = jnp.zeros((64, 64))
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (64, 64))}
        deq, err = compress_grads(g, err)
        total_true += g["w"]
        total_deq += deq["w"]
    resid = err["w"]
    np.testing.assert_allclose(
        np.asarray(total_deq + resid), np.asarray(total_true), rtol=1e-4, atol=1e-4
    )
    # and a single quantisation round-trips within its scale
    q, s = quantize_int8(grads["w"])
    np.testing.assert_allclose(
        np.asarray(dequantize_int8(q, s)), np.asarray(grads["w"]),
        atol=float(s) * 0.51,
    )


def test_grad_compression_in_train_step():
    cfg = reduced_config("olmo-1b")
    api = build_model(cfg)
    tc = TrainConfig(grad_compression="int8_ef", loss_chunk=16)
    state = init_train_state(api, jax.random.PRNGKey(3), tc)
    assert state.ef is not None
    step = jax.jit(build_train_step(api, tc))
    it = LMIterator(LMDataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4))
    state, metrics = step(state, next(it))
    assert jnp.isfinite(metrics["loss"])
    ef_norm = sum(float(jnp.abs(e).sum()) for e in jax.tree.leaves(state.ef))
    assert ef_norm > 0  # errors actually carried


def test_lr_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.int32(s), tc)) for s in range(100)]
    assert lrs[0] < lrs[9] <= tc.learning_rate * (1 + 1e-6)  # warmup (f32 eps)
    assert abs(lrs[10] - tc.learning_rate) / tc.learning_rate < 0.02
    assert lrs[-1] < 0.2 * tc.learning_rate              # decayed
    assert lrs[-1] >= 0.09 * tc.learning_rate            # floor 0.1x


def test_adamw_weight_decay_shrinks():
    tc = TrainConfig(learning_rate=1e-2, weight_decay=0.5, grad_clip=0)
    params = {"w": jnp.ones((8, 8))}
    opt = init_opt_state(params)
    grads = {"w": jnp.zeros((8, 8))}
    new, opt, _ = adamw_update(params, grads, opt, tc)
    assert float(jnp.abs(new["w"]).max()) < 1.0  # pure decay shrinks


def test_chunked_xent_matches_dense():
    from repro.layers.embeddings import chunked_xent_loss
    key = jax.random.PRNGKey(4)
    b, s, d, v = 2, 12, 16, 40
    h = jax.random.normal(key, (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(5), (d, v))
    labels = jax.random.randint(jax.random.PRNGKey(6), (b, s), 0, v)
    labels = labels.at[:, -2:].set(-1)  # padding respected
    chunked = chunked_xent_loss(w, h, labels, chunk=5)  # uneven chunk, padded
    logits = (h @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    mask = labels >= 0
    dense = jnp.sum((lse - gold) * mask) / jnp.sum(mask)
    np.testing.assert_allclose(float(chunked), float(dense), rtol=1e-5)

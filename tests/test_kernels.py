"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lstm import init_lstm_cell, lstm_cell
from repro.kernels.lstm_cell import pack_weights
from repro.kernels.ops import flash_attention_op, lstm_cell_op, wkv6_op
from repro.kernels.ref import ref_attention, ref_lstm_cell, ref_wkv6


@pytest.mark.parametrize("in_dim,hidden", [(16, 16), (32, 64), (64, 128), (128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_cell_kernel_sweep(in_dim, hidden, dtype):
    key = jax.random.PRNGKey(in_dim * hidden)
    ks = jax.random.split(key, 4)
    p = init_lstm_cell(ks[0], in_dim, hidden)
    b = 64
    x = jax.random.normal(ks[1], (b, in_dim), dtype)
    h = jax.random.normal(ks[2], (b, hidden), dtype)
    c = jax.random.normal(ks[3], (b, hidden), jnp.float32)
    hk, ck = lstm_cell_op(p, x, h, c, block_b=32, block_h=min(64, hidden), interpret=True)
    wx, wh, bb = pack_weights(p)
    hr, cr = ref_lstm_cell(x, h, c, wx, wh, bb)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(hk, np.float32), np.asarray(hr, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(cr), rtol=tol, atol=tol)


@pytest.mark.parametrize("pwl", [False, True])
def test_lstm_cell_kernel_matches_framework_cell(pwl):
    """Kernel == the framework's lstm_cell (the layer actually deployed)."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    p = init_lstm_cell(ks[0], 32, 64)
    x = jax.random.normal(ks[1], (16, 32))
    h = jax.random.normal(ks[2], (16, 64))
    c = jax.random.normal(ks[3], (16, 64))
    hk, ck = lstm_cell_op(p, x, h, c, block_b=16, block_h=32, pwl=pwl, interpret=True)
    h2, c2 = lstm_cell(p, x, h, c, pwl=pwl)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(h2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(c2), rtol=1e-5, atol=1e-6)


def test_lstm_cell_kernel_block_invariance():
    """block_h is the reuse-factor knob: results must not depend on it."""
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    p = init_lstm_cell(ks[0], 64, 128)
    x = jax.random.normal(ks[1], (32, 64))
    h = jax.random.normal(ks[2], (32, 128))
    c = jax.random.normal(ks[3], (32, 128))
    outs = [
        lstm_cell_op(p, x, h, c, block_b=bb, block_h=bh, interpret=True)
        for bb, bh in [(32, 128), (16, 64), (8, 32), (32, 32)]
    ]
    # block_h never splits the contraction (always full In/H), but different
    # output tile widths change XLA's reduction vectorisation, so results
    # drift by float noise — same tolerance as the kernel-vs-reference tests.
    for hk, ck in outs[1:]:
        np.testing.assert_allclose(np.asarray(hk), np.asarray(outs[0][0]), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ck), np.asarray(outs[0][1]), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("t_len,b,in_dim,hidden", [(4, 4, 16, 16), (12, 8, 32, 64),
                                                   (7, 2, 64, 128)])
@pytest.mark.parametrize("pwl", [False, True])
def test_lstm_seq_kernel_matches_layer_scan(t_len, b, in_dim, hidden, pwl):
    """Sequence-streaming kernel (state VMEM-resident) == lstm_layer scan."""
    from repro.core.lstm import lstm_layer
    from repro.kernels.ops import lstm_seq_op

    key = jax.random.PRNGKey(t_len + hidden)
    ks = jax.random.split(key, 2)
    p = init_lstm_cell(ks[0], in_dim, hidden)
    xs = jax.random.normal(ks[1], (t_len, b, in_dim))
    ys_k, (h_k, c_k) = lstm_seq_op(p, xs, block_b=min(4, b), pwl=pwl, interpret=True)
    ys_r, (h_r, c_r) = lstm_layer(p, xs, pwl=pwl)
    np.testing.assert_allclose(np.asarray(ys_k), np.asarray(ys_r), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_r), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("t_len,hd,h", [(8, 16, 2), (32, 32, 4), (64, 64, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_kernel_sweep(t_len, hd, h, dtype):
    key = jax.random.PRNGKey(t_len + hd)
    ks = jax.random.split(key, 6)
    b = 2
    r = (jax.random.normal(ks[0], (b, t_len, h, hd)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (b, t_len, h, hd)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (b, t_len, h, hd)) * 0.3).astype(dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t_len, h, hd))).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (h, hd)) * 0.1).astype(jnp.float32)
    s0 = jax.random.normal(ks[5], (b, h, hd, hd)) * 0.1
    yk, sk = wkv6_op(r, k, v, w, u, s0, interpret=True)
    yr, sr = ref_wkv6(r, k, v, w, u, s0)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=tol, atol=tol)


def test_wkv6_kernel_chains_across_chunks():
    """Two chunked kernel calls (state passed through) == one long ref run."""
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 5)
    b, t, h, hd = 2, 32, 2, 16
    r = jax.random.normal(ks[0], (b, t, h, hd)) * 0.3
    k = jax.random.normal(ks[1], (b, t, h, hd)) * 0.3
    v = jax.random.normal(ks[2], (b, t, h, hd)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, hd)))
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    s0 = jnp.zeros((b, h, hd, hd))
    y1, s1 = wkv6_op(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, s0, interpret=True)
    y2, s2 = wkv6_op(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, s1, interpret=True)
    yr, sr = ref_wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.concatenate([y1, y2], axis=1), np.asarray(yr),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sr), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("s,d,blocks", [(128, 64, (64, 64)), (256, 64, (64, 128)),
                                        (256, 128, (128, 64))])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, d, blocks, causal, dtype):
    key = jax.random.PRNGKey(s + d)
    ks = jax.random.split(key, 3)
    b, h = 2, 3
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, h, d), dtype)
    v = jax.random.normal(ks[2], (b, s, h, d), dtype)
    out = flash_attention_op(q, k, v, causal=causal, block_q=blocks[0],
                             block_k=blocks[1], interpret=True)
    ref = jnp.swapaxes(
        ref_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                      jnp.swapaxes(v, 1, 2), causal=causal), 1, 2)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)

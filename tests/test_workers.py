"""Multi-worker gateway front (repro.gateway.workers): N worker
processes behind one SO_REUSEPORT port must be value-identical to a
single server, survive worker crashes (respawn + session-loss
accounting), answer stats/recalibrate front-wide, and drain under load
with zero dropped tickets."""
import functools
import os
import signal
import socket
import time

import numpy as np
import pytest

import jax.numpy as jnp

from conftest import (
    GATEWAY_ARCH as ARCH,
    GATEWAY_FEATS as FEATS,
    gateway_series as _series,
    solo_stream_errors as _solo_errors,
)
from repro.engine import AnomalyService
from repro.gateway.client import GatewayClient
from repro.gateway.workers import WorkerFront

pytestmark = pytest.mark.skipif(
    not hasattr(socket, "SO_REUSEPORT"),
    reason="WorkerFront needs SO_REUSEPORT",
)


def _make_gateway(capacity: int = 4, max_batch: int = 4,
                  max_wait_ms: float = 10.0):
    """Per-worker factory (module-level: must pickle under spawn).  Every
    worker builds the same seed-0 service, so workers serve identical
    params — and match this test process's oracle service."""
    svc = AnomalyService(ARCH, schedule="wavefront")
    return svc.open_gateway(capacity=capacity, max_batch=max_batch,
                            max_wait_ms=max_wait_ms)


def _wait_until(predicate, timeout: float = 90.0, interval: float = 0.05):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


@pytest.fixture(scope="module")
def svc():
    """The in-process oracle: same arch/schedule/seed as every worker."""
    return AnomalyService(ARCH, schedule="wavefront")


@pytest.fixture(scope="module")
def front(tmp_path_factory):
    obs_dir = tmp_path_factory.mktemp("obs")
    f = WorkerFront(functools.partial(_make_gateway), n_workers=2,
                    heartbeat_ms=100.0, event_dir=str(obs_dir),
                    metrics_port=0)
    f.start(ready_timeout=180.0)
    yield f
    f.shutdown()


# -- equivalence: the worker tier adds no semantics -------------------------


def test_stream_session_matches_solo_through_front(front, svc):
    """A streaming session through whichever worker the kernel picks is
    value-identical to solo ``stream_step`` — replication is invisible."""
    data = _series(0, 10)
    solo = _solo_errors(svc, data)
    with GatewayClient(front.host, front.port) as client:
        for t in range(len(data)):
            resp = client.step(data[t])
            np.testing.assert_allclose(resp["running_error"], solo[t],
                                       rtol=1e-5, atol=1e-5)
        final = client.end_session()["final"]
    np.testing.assert_allclose(final, solo[-1], rtol=1e-5, atol=1e-5)


def test_one_shot_scores_match_direct(front, svc):
    """One-shot scores over several connections (hashing to different
    workers) match direct in-process ``AnomalyService.score``."""
    windows = [_series(20 + i, L, seed=3)
               for i, L in enumerate([5, 9, 16, 7])]
    for _ in range(3):  # several connections: exercise >1 worker
        with GatewayClient(front.host, front.port) as client:
            scores = client.score_many(windows)
        for w, s in zip(windows, scores):
            direct = float(svc.score(jnp.asarray(w[None]))[0])
            np.testing.assert_allclose(s, direct, rtol=1e-5, atol=1e-5)


# -- aggregated control plane ----------------------------------------------


def test_front_stats_aggregate_sums_workers(front):
    with GatewayClient(front.host, front.port) as client:
        client.score(_series(30, 6))
        agg = client.stats()  # over the wire: one worker asks, all answer
    assert agg["workers"]["count"] == 2
    assert agg["workers"]["configured"] == 2
    assert len(agg["per_worker"]) == 2
    assert agg["capacity"] == sum(w["capacity"] for w in agg["per_worker"])
    total_completed = sum(
        w["counters"].get("queue.completed", 0) for w in agg["per_worker"])
    assert agg["counters"]["queue.completed"] == total_completed >= 1
    # supervisor-side aggregation sees the same totals
    sup = front.stats()
    assert sup["counters"]["queue.completed"] >= total_completed
    assert sup["features"] == FEATS


def test_front_latency_percentiles_are_exact_merge(front):
    """The front's latency percentiles must be BIT-EQUAL to percentiles
    of the merged per-worker histograms — i.e. of the union of all
    workers' samples — not the worst worker's (the PR 5 approximation)."""
    from repro.gateway.telemetry import REQUEST_HIST
    from repro.obs import Histogram

    windows = [_series(40 + i, 6) for i in range(4)]
    for _ in range(3):  # several connections: let the kernel spread load
        with GatewayClient(front.host, front.port) as client:
            client.score_many(windows)
    agg = front.stats()
    merged = Histogram()
    for w in agg["per_worker"]:
        merged.merge_from(Histogram.from_dict(
            (w.get("histograms") or {}).get(REQUEST_HIST)))
    lat = agg["latency_ms"]
    assert merged.count == lat["count"] >= 12
    assert lat["p50"] == merged.percentile(50)
    assert lat["p95"] == merged.percentile(95)
    assert lat["p99"] == merged.percentile(99)
    assert lat["sum_ms"] == pytest.approx(merged.sum)
    assert lat["buckets"] == {str(i): n
                              for i, n in sorted(merged.counts.items())}
    # the merged histograms also travel whole on the aggregate
    assert agg["histograms"][REQUEST_HIST]["count"] == merged.count


def test_front_metrics_endpoints_and_event_logs(front):
    """One /metrics per process: the supervisor serves the front
    aggregate, each worker its own labelled view; every process appended
    a boot event to its JSONL log."""
    import json
    import urllib.request

    assert front.metrics is not None  # metrics_port=0 bound ephemerally
    body = urllib.request.urlopen(
        f"http://{front.host}:{front.metrics.port}/metrics",
        timeout=15).read().decode()
    assert 'repro_workers_count{scope="front"} 2' in body
    assert "repro_queue_completed_total" in body
    assert 'repro_request_ms_bucket{le="+Inf",scope="front"}' in body
    agg = front.stats()
    for w in agg["per_worker"]:
        assert w["metrics_port"]
        wb = urllib.request.urlopen(
            f"http://127.0.0.1:{w['metrics_port']}/metrics",
            timeout=15).read().decode()
        assert f'worker="{w["index"]}"' in wb
    sup = [json.loads(line) for line in
           (open(f"{front.event_dir}/supervisor.jsonl"))]
    assert sup[0]["kind"] == "boot" and sup[0]["workers"] == 2
    for i in range(2):
        rows = [json.loads(line) for line in
                open(f"{front.event_dir}/worker-{i}.jsonl")]
        assert rows[0]["kind"] == "boot" and rows[0]["worker"] == i


def test_recalibrate_fans_out_to_every_worker(front):
    with GatewayClient(front.host, front.port) as client:
        out = client.recalibrate(0.25)
        assert out["threshold"] == pytest.approx(0.25)
        assert out["workers"] == 2
    try:
        per = front.stats()["per_worker"]
        assert [w["threshold"] for w in per] == [0.25, 0.25]
        # alerts flip on whichever worker a later connection lands on
        for _ in range(3):
            with GatewayClient(front.host, front.port) as client:
                resp = client.request("score",
                                      series=_series(31, 6).tolist())
                assert "alert" in resp
    finally:
        front.recalibrate(threshold=None)
        per = front.stats()["per_worker"]
        assert [w["threshold"] for w in per] == [None, None]


# -- crash -> respawn with session-loss accounting --------------------------


def test_worker_crash_respawns_and_accounts_lost_sessions():
    f = WorkerFront(functools.partial(_make_gateway), n_workers=2,
                    heartbeat_ms=50.0)
    host, port = f.start(ready_timeout=180.0)
    victim_client = GatewayClient(host, port)
    try:
        f.recalibrate(threshold=0.125)  # live state a respawn must inherit
        victim_client.step(np.zeros(FEATS, np.float32))

        def _find_victim():
            for w in f.stats()["per_worker"]:
                if w["active_streams"] == 1:
                    return w["pid"]
            return None

        assert _wait_until(lambda: _find_victim() is not None)
        victim_pid = _find_victim()
        os.kill(victim_pid, signal.SIGKILL)
        assert _wait_until(
            lambda: f.restarts == 1 and f.alive_workers == 2, timeout=120.0
        ), f"no respawn: restarts={f.restarts} alive={f.alive_workers}"
        assert f.sessions_lost == 1  # the victim's resident stream
        assert victim_pid not in f.worker_pids()
        # the front keeps serving across the crash window
        with GatewayClient(host, port) as client:
            assert np.isfinite(client.score(_series(40, 6)))
        # the respawned worker rebuilt from the factory; the supervisor
        # must have replayed the live recalibration onto it, or acceptors
        # would now disagree about alerts
        assert _wait_until(
            lambda: [w["threshold"] for w in f.stats()["per_worker"]]
            == [0.125, 0.125], timeout=60.0,
        ), f.stats()["per_worker"]
        summary = f.shutdown()
    finally:
        try:
            victim_client.close()
        except Exception:
            pass
    assert summary["clean_exits"] == 2
    assert summary["dropped_tickets"] == 0
    assert summary["restarts"] == 1 and summary["sessions_lost"] == 1


# -- coordinated drain under load ------------------------------------------


def test_shutdown_drains_pending_tickets_across_workers():
    """Tickets parked in several workers' queues (max_wait too long to
    flush, max_batch too big to trigger) are all answered by the
    coordinated drain; the summary reports zero dropped."""
    f = WorkerFront(
        functools.partial(_make_gateway, max_batch=64, max_wait_ms=1e9),
        n_workers=2, heartbeat_ms=100.0,
    )
    host, port = f.start(ready_timeout=180.0)
    clients = [GatewayClient(host, port) for _ in range(3)]
    try:
        rids = []
        for i, c in enumerate(clients):
            rids.append([c.submit(_series(50 + i, 6)) for _ in range(3)])
            assert c.ping()  # same-connection ordering: submits are in
        assert _wait_until(  # some worker's queue, nothing flushed yet
            lambda: f.stats()["queue_depth"] == 9, timeout=30.0)
        summary = f.shutdown()
        assert summary["clean_exits"] == 2
        assert summary["dropped_tickets"] == 0
        assert summary["counters"]["queue.completed"] == 9
        for c, rs in zip(clients, rids):
            for rid in rs:
                resp = c.collect(rid)  # answered at drain, before close
                assert resp["ok"] and np.isfinite(resp["score"])
    finally:
        for c in clients:
            try:
                c.close()
            except Exception:
                pass


# -- elastic fleet: scale-up replay + zero-drop scale-down ------------------


def test_scale_up_then_scale_down_drains_clean():
    """The autoscaler's actuation path: ``scale_up`` adds a live worker
    on the shared port, ``scale_down`` retires exactly one via the
    coordinated drain — zero dropped tickets, atomic worker accounting —
    and the survivor keeps serving new connections."""
    f = WorkerFront(functools.partial(_make_gateway), n_workers=1,
                    heartbeat_ms=100.0)
    try:
        host, port = f.start(ready_timeout=180.0)
        up = f.scale_up()
        assert up["workers"] == 2
        st = f.stats()["workers"]
        assert st["count"] == 2 and st["target"] == 2
        assert st["scale_ups"] == 1
        with GatewayClient(host, port) as client:
            scores = client.score_many([_series(60 + i, 8) for i in range(8)])
        assert all(np.isfinite(s) for s in scores)
        drain = f.scale_down()
        assert drain["clean"] and drain["exitcode"] == 0
        assert drain["dropped_tickets"] == 0
        assert drain["workers"] == 1
        st = f.stats()["workers"]
        assert st["count"] == 1 and st["target"] == 1
        assert st["scale_downs"] == 1
        with GatewayClient(host, port) as client:  # survivor still serves
            assert np.isfinite(client.score(_series(70, 6)))
        with pytest.raises(RuntimeError, match="below one worker"):
            f.scale_down()  # the floor: never drain the last worker
    finally:
        f.shutdown()

"""The bp1 binary wire protocol: codec round-trips (property-tested),
zero-copy payload decode, negotiation + JSON fallback, pipelined
multi-window frames matching the solo oracle bit-for-bit, and the
durable-resume / priority-admission features riding the new frames."""
import struct

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # hypothesis or fallback shim
from conftest import (
    GATEWAY_ARCH as ARCH,
    GATEWAY_FEATS as FEATS,
    gateway_series as _series,
    solo_stream_errors as _solo_errors,
)
from repro.engine import AnomalyService
from repro.gateway import wire
from repro.gateway.client import GatewayClient, GatewayClientError
from repro.gateway.server import GatewayServer


@pytest.fixture(scope="module")
def svc():
    return AnomalyService(ARCH, schedule="wavefront")


@pytest.fixture
def served(svc):
    gw = svc.open_gateway(capacity=4, max_batch=4, max_wait_ms=10.0)
    server = GatewayServer(gw, port=0, pump_interval_ms=2.0)
    host, port = server.start_in_thread()
    yield host, port, gw
    server.stop_in_thread()


# -- codec ------------------------------------------------------------------


@settings(max_examples=60)
@given(opcode=st.integers(0, 255), flags=st.integers(0, 2 ** 32 - 1),
       rid=st.integers(0, 2 ** 64 - 1), plen=st.integers(0, 2 ** 20))
def test_header_pack_unpack_roundtrip(opcode, flags, rid, plen):
    buf = wire.pack_header(opcode, flags, rid, plen)
    assert len(buf) == wire.HEADER_SIZE
    assert wire.unpack_header(buf) == (opcode, flags, rid, plen)


@settings(max_examples=40)
@given(rid=st.integers(0, 2 ** 32), n=st.integers(0, 64),
       tag=st.integers(0, 2 ** 30))
def test_payload_roundtrip_through_frame_reader(rid, n, tag):
    meta = {"n": n, "tag": str(tag), "nested": {"ok": True}}
    data = bytes((i * 7 + n) % 256 for i in range(n * 3))
    blob = wire.pack_frame(wire.OP_SCORE, rid, meta=meta, data=data)
    reader = wire.FrameReader()
    # split across feeds to exercise reassembly
    frames = reader.feed(blob[:13])
    frames += reader.feed(blob[13:])
    assert len(frames) == 1 and reader.pending_bytes == 0
    frame = frames[0]
    assert (frame.opcode, frame.req_id) == (wire.OP_SCORE, rid)
    got_meta, got_data = wire.split_payload(frame.payload)
    assert got_meta == meta and bytes(got_data) == data


def test_empty_payload_packs_to_empty_bytes():
    blob = wire.pack_frame(wire.OP_PING, 5)
    assert wire.unpack_header(blob)[3] == 0
    meta, data = wire.split_payload(b"")
    assert meta == {} and len(data) == 0


def test_frame_reader_rejects_bad_magic_version_and_oversize():
    good = wire.pack_frame(wire.OP_PING, 1)
    with pytest.raises(wire.WireProtocolError, match="magic"):
        wire.FrameReader().feed(b"zz" + good[2:])
    with pytest.raises(wire.WireProtocolError, match="version"):
        wire.FrameReader().feed(good[:2] + b"\x63" + good[3:])
    # an oversize length field must be rejected from the 20 header bytes
    # alone — before any payload buffering, so a hostile peer can't make
    # the server allocate 4 GiB
    evil = bytearray(good)
    struct.pack_into("<I", evil, 16, 0xFFFFFFFF)
    reader = wire.FrameReader(max_frame_bytes=1 << 20)
    with pytest.raises(wire.WireProtocolError, match="payload"):
        reader.feed(bytes(evil))
    assert reader.pending_bytes <= wire.HEADER_SIZE


def test_split_payload_rejects_corrupt_meta():
    with pytest.raises(wire.WireProtocolError):
        wire.split_payload(struct.pack("<I", 999) + b"{}")  # meta_len > payload
    bad_json = b"{nope"
    with pytest.raises(wire.WireProtocolError):
        wire.split_payload(struct.pack("<I", len(bad_json)) + bad_json)
    with pytest.raises(wire.WireProtocolError):
        wire.split_payload(struct.pack("<I", 4) + b"[10]")  # meta not a dict


def test_decode_f32_is_zero_copy_and_validates_count():
    data = np.arange(24, dtype="<f4").tobytes()
    arr = wire.decode_f32(data, (2, 3, 4))
    assert arr.shape == (2, 3, 4)
    assert np.shares_memory(arr, np.frombuffer(data, "<f4"))
    np.testing.assert_array_equal(arr.ravel(), np.arange(24, dtype=np.float32))
    with pytest.raises(wire.WireProtocolError, match="float32"):
        wire.decode_f32(data, (5, 5))
    with pytest.raises(wire.WireProtocolError):
        wire.decode_f32(data[:-1], (24,))  # not a multiple of 4 bytes


def test_conformance_corpus_decodes_and_is_byte_stable():
    """Every committed golden frame re-packs to its exact committed
    bytes through the live codec (the CI gate's core property)."""
    import os
    import sys

    scripts = os.path.join(os.path.dirname(__file__), "..", "scripts")
    sys.path.insert(0, scripts)
    try:
        import wire_conformance as conf
    finally:
        sys.path.remove(scripts)

    assert conf.check(conf.CORPUS_DIR) == 0


# -- transport equivalence --------------------------------------------------


def test_binary_json_inprocess_scores_bit_equal(served, svc):
    host, port, _ = served
    windows = [_series(200 + i, 12) for i in range(6)]
    direct = [float(svc.score(np.asarray(w)[None])[0]) for w in windows]
    with GatewayClient(host, port, protocol="binary") as cb, \
            GatewayClient(host, port, protocol="json") as cj:
        assert cb.protocol == "bp1" and cj.protocol == "json"
        for w, d in zip(windows, direct):
            sb, sj = cb.score(w), cj.score(w)
            assert sb == sj  # the protocols are bit-identical, not close
            np.testing.assert_allclose(sb, d, rtol=1e-5, atol=1e-6)


def test_pipelined_frames_match_solo_oracle_any_depth(served, svc):
    """score_many at every pipelining depth returns the same scores in
    submission order, equal to one-at-a-time submits."""
    host, port, _ = served
    windows = [_series(300 + i, 8 + (i % 3) * 4) for i in range(10)]
    with GatewayClient(host, port, protocol="binary") as c:
        solo = [c.score(w) for w in windows]
        for depth in (1, 3, 64):
            got = c.score_many(windows, windows_per_frame=depth)
            assert got == solo


def test_pipelined_responses_collected_out_of_order(served):
    """Frames answered out of submission order still match by request
    id — collect the last submit first."""
    host, port, _ = served
    windows = [_series(400 + i, 8) for i in range(4)]
    with GatewayClient(host, port, protocol="binary") as c:
        expect = [c.score(w) for w in windows]
        rids = [c.submit(w) for w in windows]
        got = [c.collect(rid)["score"] for rid in reversed(rids)]
        assert got == expect[::-1]


def test_empty_batch_frame_is_legal(served):
    host, port, _ = served
    with GatewayClient(host, port, protocol="binary") as c:
        assert c.score_many([]) == []


def test_streaming_over_binary_matches_solo(served, svc):
    host, port, _ = served
    data = _series(17, 10)
    solo = _solo_errors(svc, data)
    with GatewayClient(host, port, protocol="binary") as c:
        for t in range(len(data)):
            np.testing.assert_allclose(c.step(data[t])["running_error"],
                                       solo[t], rtol=1e-5, atol=1e-5)
        final = c.end_session()["final"]
    np.testing.assert_allclose(final, solo[-1], rtol=1e-5, atol=1e-5)
    with GatewayClient(host, port, protocol="binary") as c:
        many = c.step_many(data)  # the whole series in one STEP frame
        np.testing.assert_allclose(many, solo, rtol=1e-5, atol=1e-5)
        c.end_session()


def test_typed_errors_cross_binary_frames(served):
    host, port, _ = served
    with GatewayClient(host, port, protocol="binary") as c:
        with pytest.raises(GatewayClientError) as ei:
            c.score(np.zeros((2048, FEATS), np.float32))
        assert ei.value.error == "ValueError" and "max_seq_len" in ei.value.message
        with pytest.raises(GatewayClientError) as ei:
            c.request("definitely_not_an_op")
        assert "unknown opcode" in ei.value.message
        c.ping()  # connection survives payload-level errors


# -- negotiation ------------------------------------------------------------


def test_auto_negotiation_falls_back_to_json(svc):
    """Against a server with the binary path disabled the preamble is
    answered with a JSON error line; an auto client falls back and
    works, a binary-required client raises."""
    gw = svc.open_gateway(capacity=2, max_batch=2, max_wait_ms=5.0)
    server = GatewayServer(gw, port=0, pump_interval_ms=2.0,
                           enable_binary=False)
    host, port = server.start_in_thread()
    try:
        with GatewayClient(host, port) as c:  # default: auto
            assert c.protocol == "json"
            assert c.ping()
            c.score(_series(500, 8))
        with pytest.raises(GatewayClientError) as ei:
            GatewayClient(host, port, protocol="binary")
        assert ei.value.error == "ProtocolError"
    finally:
        server.stop_in_thread()


def test_explicit_json_client_skips_preamble(served):
    """protocol="json" never sends the preamble — its first bytes on the
    wire are a legacy JSON line, byte-identical to pre-bp1 clients."""
    host, port, _ = served
    with GatewayClient(host, port, protocol="json") as c:
        assert c.protocol == "json" and c.server_info == {}
        assert c.ping()


def test_hello_reports_server_limits(served, svc):
    host, port, gw = served
    with GatewayClient(host, port, protocol="binary") as c:
        assert c.server_info["protocol"] == "bp1"
        assert c.server_info["version"] == wire.VERSION
        assert c.server_info["features"] == gw.pool.features
        assert c.server_info["max_frame_bytes"] > 0


# -- PR-6/PR-9 features over binary frames ----------------------------------


def test_durable_resume_over_binary_frames(svc, tmp_path):
    """A durable session stepped over bp1 yields tokens, and a second
    binary client resumes from the token with replay — running errors
    bit-equal to the solo oracle."""
    from repro.gateway.durability import enable_durability

    data = _series(21, 8)
    oracle = _solo_errors(svc, data)
    gw = svc.open_gateway(capacity=4, max_batch=4, max_wait_ms=5.0)
    enable_durability(gw, str(tmp_path / "store"))
    server = GatewayServer(gw, port=0, pump_interval_ms=2.0)
    host, port = server.start_in_thread()
    try:
        with GatewayClient(host, port, protocol="binary") as c1:
            for t in range(5):
                c1.step(data[t])
            c1.request("snapshot")
            token, replay = c1.session_token, c1.replay_buffer()
            assert token and c1.session_seq == 5
        with GatewayClient(host, port, protocol="binary") as c2:
            out = c2.resume(token, replay=replay)
            assert out["seq"] == 5
            errs = [c2.step(data[t])["running_error"] for t in range(5, 8)]
            np.testing.assert_allclose(errs, oracle[5:], rtol=1e-5, atol=1e-6)
    finally:
        server.stop_in_thread()


def test_priority_shed_over_binary_frames(svc):
    """The PR-9 admission controller reads priority/tenant out of bp1
    SCORE frame meta: low-priority traffic sheds first with a typed
    GatewayOverloadedError frame, priority-0 still lands."""
    from repro.control import ControlConfig, enable_control

    gw = svc.open_gateway(capacity=1, max_batch=8, max_queue=3,
                          max_wait_ms=60_000.0)
    enable_control(gw, ControlConfig(priority_classes=3))
    server = GatewayServer(gw, port=0, pump_interval_ms=1000.0)
    host, port = server.start_in_thread()
    try:
        with GatewayClient(host, port, protocol="binary") as c:
            c.submit(_series(600, 6), priority=2, tenant="bulk")
            with pytest.raises(GatewayClientError) as ei:
                c.collect(c.submit(_series(601, 6), priority=2, tenant="bulk"))
            assert ei.value.error == "GatewayOverloadedError"
            c.submit(_series(602, 6), priority=0)  # top class still admitted
            # frames dispatch in order per connection: a ping response
            # proves the p0 submit above has been admitted server-side
            assert c.ping()
            assert gw.batcher.queue_depth == 2
    finally:
        server.stop_in_thread()  # drain answers the two admitted tickets
    assert gw.batcher.queue_depth == 0


# -- resilience -------------------------------------------------------------


def test_garbage_frames_do_not_wedge_the_server(served):
    """A hostile connection (bad preamble, truncated header, oversize
    length field) may lose itself, never the server: fresh well-formed
    clients on both protocols keep getting correct answers."""
    import socket as socketlib

    host, port, _ = served
    window = _series(700, 8)
    with GatewayClient(host, port, protocol="binary") as c:
        expect = c.score(window)
    attacks = [
        b"\xb2Q1\n" + wire.pack_frame(wire.OP_PING, 1),
        wire.PREAMBLE + wire.pack_header(wire.OP_PING, 0, 1, 0)[:9],
        wire.PREAMBLE + wire.pack_header(wire.OP_SCORE, 0, 2, 0xFFFFFFF0),
        wire.PREAMBLE + b"\x00" * 64,
    ]
    for attack in attacks:
        with socketlib.create_connection((host, port), timeout=30) as s:
            s.sendall(attack)
            s.settimeout(30)
            try:
                s.recv(4096)
            except OSError:
                pass
        for proto in ("binary", "json"):
            with GatewayClient(host, port, protocol=proto) as c:
                assert c.score(window) == expect

"""Streaming anomaly gateway: pooled-session semantics must be
indistinguishable from solo streaming, micro-batched scoring must match
direct scoring despite bucketing/padding, and admission control +
telemetry must hold their contracts."""
import numpy as np
import pytest

import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from conftest import (
    GATEWAY_ARCH as ARCH,
    GATEWAY_FEATS as FEATS,
    breaking_score_masked,
    gateway_series as _series,
    solo_stream_errors as _solo_errors,
)
from repro.config import get_config
from repro.engine import AnomalyService, available_schedules
from repro.gateway import (
    AnomalyGateway,
    GatewayOverloadedError,
    PoolFullError,
    UnknownStreamError,
    bucket_for,
)


@pytest.fixture(scope="module")
def svc():
    # untrained service: init params are fine for value-equivalence tests
    return AnomalyService(ARCH, schedule="wavefront")


# -- pool semantics --------------------------------------------------------


def test_pool_admit_evict_capacity(svc):
    gw = AnomalyGateway(svc, capacity=3)
    for i in range(3):
        gw.admit(i)
    with pytest.raises(PoolFullError):
        gw.admit(99)
    with pytest.raises(ValueError, match="already resident"):
        gw.admit(0)
    gw.evict(1)
    gw.admit(99)  # freed slot is reusable
    with pytest.raises(UnknownStreamError):
        gw.step({1: np.zeros(FEATS, np.float32)})
    with pytest.raises(UnknownStreamError):
        gw.evict("never-admitted")


def test_pool_rejects_bad_sample_shape(svc):
    gw = AnomalyGateway(svc, capacity=2)
    gw.admit("a")
    with pytest.raises(ValueError, match="sample shape"):
        gw.step({"a": np.zeros(FEATS + 1, np.float32)})


@pytest.mark.parametrize("schedule", sorted(available_schedules()))
def test_pool_interleaved_matches_solo(schedule):
    """Acceptance: N>=8 streams interleaved through the gateway pool match
    solo ``stream_step`` runs, for every registered schedule.  Streams step
    on irregular subsets of rounds, so slots advance out of lockstep."""
    svc = AnomalyService(ARCH, schedule=schedule)
    n, t_len = 8, 12
    gw = AnomalyGateway(svc, capacity=n)
    data = [_series(i, t_len) for i in range(n)]
    solo = [_solo_errors(svc, data[i]) for i in range(n)]
    cursor = [0] * n
    for i in range(n):
        gw.admit(i)
    round_ = 0
    while any(c < t_len for c in cursor):
        stepping = {
            i: data[i][cursor[i]]
            for i in range(n)
            if cursor[i] < t_len and (round_ + i) % 3 != i % 2
        }
        if stepping:
            running = gw.step(stepping)
            for i in stepping:
                np.testing.assert_allclose(
                    running[i], solo[i][cursor[i]], rtol=1e-5, atol=1e-5
                )
                cursor[i] += 1
        round_ += 1
    for i in range(n):
        assert abs(gw.evict(i) - solo[i][-1]) < 1e-5


@settings(max_examples=5, deadline=None)
@given(
    masks=st.lists(st.integers(0, 255), min_size=3, max_size=6),
    churn=st.lists(st.integers(0, 7), min_size=1, max_size=3),
)
def test_pool_property_any_interleaving(svc, masks, churn):
    """Property: ANY interleaving of admit/step/evict produces per-stream
    running errors identical to running each stream alone through
    ``AnomalyService.stream_step`` (the ISSUE's pool-semantics contract).

    ``masks[r]`` selects which of 8 slots step in round r; after each round
    one slot id drawn from ``churn`` is evicted and re-admitted as a fresh
    logical stream (also validated at eviction time)."""
    n = 8
    gw = AnomalyGateway(svc, capacity=n)
    gen = [0] * n
    consumed: dict = {}

    def sid(i):
        return (i, gen[i])

    for i in range(n):
        gw.admit(sid(i))
        consumed[sid(i)] = []
    for r, mask in enumerate(masks):
        stepping = {}
        for i in range(n):
            if (mask >> i) & 1:
                x = _series(i, seed=100 + gen[i])[len(consumed[sid(i)]) % 16]
                consumed[sid(i)].append(x)
                stepping[sid(i)] = x
        if stepping:
            running = gw.step(stepping)
            for s in stepping:
                expect = _solo_errors(svc, consumed[s])[-1]
                np.testing.assert_allclose(running[s], expect, rtol=1e-5, atol=1e-5)
        i = churn[r % len(churn)]
        final = gw.evict(sid(i))
        if consumed[sid(i)]:
            expect = _solo_errors(svc, consumed[sid(i)])[-1]
            np.testing.assert_allclose(final, expect, rtol=1e-5, atol=1e-5)
        del consumed[sid(i)]
        gen[i] += 1
        gw.admit(sid(i))
        consumed[sid(i)] = []


def test_pool_reset_restarts_error_accumulation(svc):
    gw = AnomalyGateway(svc, capacity=2)
    gw.admit("a")
    data = _series(3, 6)
    for t in range(3):
        gw.step({"a": data[t]})
    gw.reset("a")
    for t in range(3):
        running = gw.step({"a": data[t]})
    np.testing.assert_allclose(
        running["a"], _solo_errors(gw.service, data[:3])[-1], rtol=1e-5, atol=1e-5
    )


def test_drive_stream_churn_accounts_for_all_streams(svc):
    """The demo driver must account for every requested stream: served ones
    return a final error, the rest are reported unserved (never dropped)."""
    from repro.gateway import drive_stream_churn

    gw = AnomalyGateway(svc, capacity=2)
    windows = np.stack([_series(i, 10) for i in range(6)])
    finals, unserved = drive_stream_churn(gw, windows, churn_every=4)
    assert set(finals) | set(unserved) == set(range(6))
    assert not set(finals) & set(unserved)
    assert len(finals) == 4  # 2 slots + 2 churn rotations (t=4, t=8)
    assert gw.pool.active == 0  # driver leaves the pool drained


# -- micro-batching queue --------------------------------------------------


def test_bucket_ladder():
    assert bucket_for(1) == 8
    assert bucket_for(8) == 8
    assert bucket_for(9) == 16
    assert bucket_for(1025) == 2048


def test_batcher_matches_direct_score_across_buckets(svc):
    """Mixed lengths spanning bucket boundaries: padded bucket scoring must
    equal direct (B=1, exact-length) engine scoring per request."""
    gw = AnomalyGateway(svc, capacity=1, max_batch=4, max_wait_ms=0.0)
    lens = [5, 8, 9, 16, 17, 31, 12, 7]
    windows = [_series(i, L, seed=5) for i, L in enumerate(lens)]
    scores = gw.score(windows)
    for w, s in zip(windows, scores):
        direct = float(svc.score(jnp.asarray(w[None]))[0])
        np.testing.assert_allclose(s, direct, rtol=1e-5, atol=1e-5)


def test_batcher_backpressure(svc):
    gw = AnomalyGateway(svc, capacity=1, max_batch=8, max_queue=3,
                        max_wait_ms=1e9)
    for i in range(3):
        gw.submit(_series(i, 6))
    with pytest.raises(GatewayOverloadedError):
        gw.submit(_series(9, 6))
    assert gw.stats()["counters"]["queue.rejected"] == 1
    gw.flush()
    gw.submit(_series(9, 6))  # drained queue admits again


def test_batcher_flush_on_max_batch(svc):
    gw = AnomalyGateway(svc, capacity=1, max_batch=3, max_wait_ms=1e9)
    tickets = [gw.submit(_series(i, 6)) for i in range(3)]
    assert all(t.done for t in tickets)  # size trigger, no pump needed
    assert gw.batcher.queue_depth == 0


def test_batcher_flush_on_max_wait():
    clock_now = [0.0]
    svc = AnomalyService(ARCH, schedule="wavefront")
    gw = AnomalyGateway(svc, capacity=1, max_batch=8, max_wait_ms=50.0,
                        clock=lambda: clock_now[0])
    t = gw.submit(_series(0, 6))
    assert gw.pump() == 0 and not t.done       # too young to flush
    clock_now[0] = 0.049
    assert gw.pump() == 0 and not t.done
    clock_now[0] = 0.051                        # oldest aged past max_wait
    assert gw.pump() == 1 and t.done
    with pytest.raises(RuntimeError, match="pump"):
        AnomalyGateway(svc, capacity=1).submit(_series(0, 6)).score  # noqa: B018


def test_batcher_rejects_bad_shapes(svc):
    gw = AnomalyGateway(svc, capacity=1)
    with pytest.raises(ValueError, match="window"):
        gw.submit(np.zeros((4, FEATS + 1), np.float32))
    with pytest.raises(ValueError, match="window"):
        gw.submit(np.zeros((FEATS,), np.float32))


def test_batcher_rejects_oversized_windows(svc):
    """max_seq_len is an admission limit: windows past the bucket ladder
    are a ValueError, not a fresh compiled shape per power of two."""
    gw = AnomalyGateway(svc, capacity=1, max_seq_len=32)
    gw.submit(_series(0, 32))  # at the limit: admitted
    with pytest.raises(ValueError, match="max_seq_len"):
        gw.submit(_series(1, 33))
    assert gw.batcher.queue_depth == 1  # rejection did not touch the queue
    # the default limit is the end of the bucket ladder
    assert AnomalyGateway(svc, capacity=1).batcher.max_seq_len == 1024


# -- flush failure (future-style error completion) -------------------------


class _Boom(RuntimeError):
    pass


def _breaking_score_masked(engine, fail_times: list):
    return breaking_score_masked(
        engine, fail_times, lambda: _Boom("engine exploded mid-flush")
    )


def test_flush_failure_fails_tickets_and_recovers(svc, monkeypatch):
    """The depth-leak regression: an engine exception mid-flush must fail
    the taken tickets (error state + queue.failed), return depth to 0, and
    leave the queue serving — not wedge it into permanent overload."""
    gw = AnomalyGateway(svc, capacity=1, max_batch=4, max_queue=4,
                        max_wait_ms=1e9)
    fail = [1]
    monkeypatch.setattr(svc.engine, "score_masked",
                        _breaking_score_masked(svc.engine, fail))
    tickets = [gw.submit(_series(i, 6)) for i in range(4)]  # size-trigger flush
    assert all(t.done and t.failed for t in tickets)
    assert isinstance(tickets[0].exception(), _Boom)
    with pytest.raises(_Boom):
        tickets[0].score  # noqa: B018
    assert gw.batcher.queue_depth == 0  # depth decremented on the error path
    s = gw.stats()
    assert s["counters"]["queue.failed"] == 4
    assert s["counters"].get("queue.completed", 0) == 0
    # the queue is still usable: submissions are admitted (no overload
    # wedge) and the next flush scores normally
    fresh = [gw.submit(_series(i, 6, seed=2)) for i in range(4)]
    assert all(t.done and not t.failed for t in fresh)
    direct = float(svc.score(jnp.asarray(_series(0, 6, seed=2)[None]))[0])
    np.testing.assert_allclose(fresh[0].score, direct, rtol=1e-5, atol=1e-5)
    assert gw.stats()["counters"]["queue.completed"] == 4


def test_flush_failure_via_pump_keeps_queue_usable(svc, monkeypatch):
    """Same regression through the pump path: pump() reports 0 completed,
    fails the bucket's tickets, and later pumps flush fine."""
    clock_now = [0.0]
    gw = AnomalyGateway(svc, capacity=1, max_batch=8, max_wait_ms=10.0,
                        clock=lambda: clock_now[0])
    fail = [1]
    monkeypatch.setattr(svc.engine, "score_masked",
                        _breaking_score_masked(svc.engine, fail))
    dead = gw.submit(_series(0, 6))
    clock_now[0] = 0.02
    assert gw.pump() == 0 and dead.failed
    assert gw.batcher.queue_depth == 0
    live = gw.submit(_series(1, 6))
    clock_now[0] = 0.04
    assert gw.pump() == 1 and live.done and not live.failed


def test_ticket_callbacks_fire_on_success_and_error(svc, monkeypatch):
    """Future-style completion: callbacks run exactly once on resolve AND
    on fail, immediately when registered after completion, and a raising
    callback cannot break its batchmates' completion."""
    gw = AnomalyGateway(svc, capacity=1, max_batch=2, max_wait_ms=1e9)
    seen = []
    t1 = gw.submit(_series(0, 6))
    t1.add_done_callback(lambda t: seen.append(("a", t.failed)))
    t1.add_done_callback(lambda t: 1 / 0)  # must not block t2's callback
    t2 = gw.submit(_series(1, 6))          # completes the pair (size trigger)
    t2.add_done_callback(lambda t: seen.append(("b", t.failed)))  # post-hoc
    assert seen == [("a", False), ("b", False)]

    fail = [1]
    monkeypatch.setattr(svc.engine, "score_masked",
                        _breaking_score_masked(svc.engine, fail))
    t3 = gw.submit(_series(2, 6))
    t3.add_done_callback(lambda t: seen.append(("c", t.failed)))
    gw.submit(_series(3, 6))
    assert seen[-1] == ("c", True)


# -- live recalibration ----------------------------------------------------


def test_recalibrate_under_resident_streams(svc):
    """Threshold swaps apply to subsequent detections without evicting
    resident streams or perturbing their pooled running errors."""
    gw = AnomalyGateway(svc, capacity=2, max_batch=2, max_wait_ms=0.0)
    gw.admit("a")
    data = _series(0, 8)
    for t in range(4):
        running = gw.step({"a": data[t]})
    before = running["a"]
    assert gw.threshold is None  # untrained service: no threshold yet

    out = gw.recalibrate(threshold=0.25)
    assert out == {"threshold": 0.25, "params_swapped": False}
    assert gw.threshold == 0.25 and svc.threshold == 0.25  # shared view
    assert gw.pool.active == 1  # no eviction
    np.testing.assert_allclose(gw.pool.error_of("a"), before, rtol=0, atol=0)

    # the resident stream keeps its carried state: subsequent steps match
    # the solo run as if nothing happened
    for t in range(4, 8):
        running = gw.step({"a": data[t]})
    np.testing.assert_allclose(
        running["a"], _solo_errors(svc, data)[-1], rtol=1e-5, atol=1e-5
    )
    # new threshold applies to subsequent detections
    assert bool(svc.alerts(jnp.asarray(data[None]))[0]) == (running["a"] > 0.25)
    gw.recalibrate(threshold=None)  # live disable
    assert gw.threshold is None
    gw.evict("a")


def test_recalibrate_swaps_params_atomically(svc):
    """A param swap rebinds the engine for every serving path (pool steps
    and one-shot flushes) without draining; the service view stays
    consistent."""
    other = AnomalyService(ARCH, schedule="wavefront", seed=123)
    gw = AnomalyGateway(svc, capacity=2, max_batch=1, max_wait_ms=0.0)
    old_params = svc.params
    try:
        gw.admit("a")
        data = _series(5, 6)
        gw.step({"a": data[0]})
        out = gw.recalibrate(params=other.params, threshold=0.5)
        assert out["params_swapped"] and svc.params is other.params
        assert gw.pool.active == 1  # resident through the swap
        # one-shot scoring now runs the swapped model
        w = _series(6, 8)
        np.testing.assert_allclose(
            gw.score([w])[0],
            float(other.score(jnp.asarray(w[None]))[0]),
            rtol=1e-5, atol=1e-5,
        )
    finally:
        gw.recalibrate(params=old_params, threshold=None)


def test_service_recalibrate_threshold_and_benign():
    svc = AnomalyService(ARCH, schedule="wavefront")
    assert svc.recalibrate(threshold=0.0) == 0.0
    # a legitimate 0.0 threshold must alert (the serve.py truthiness bug)
    assert bool(svc.alerts(jnp.asarray(_series(0, 8)[None]))[0])
    benign = jnp.asarray(np.stack([_series(i, 8) for i in range(8)]))
    thr = svc.recalibrate(benign)
    assert thr == svc.threshold and thr > 0.0
    # explicit None disables alerting (same semantics as the gateway);
    # omitting threshold leaves it untouched
    assert svc.recalibrate(threshold=None) is None and svc.threshold is None
    assert svc.recalibrate() is None


def test_gateway_over_bare_engine_owns_threshold(svc):
    """Fronting a bare Engine (no service), the gateway keeps its own
    threshold so transport-level alerting still works."""
    gw = AnomalyGateway(svc.engine, capacity=1)
    assert gw.service is None and gw.threshold is None
    gw.recalibrate(threshold=1.5)
    assert gw.threshold == 1.5 and gw.stats()["threshold"] == 1.5


# -- telemetry + wiring ----------------------------------------------------


def test_telemetry_stats(svc):
    gw = AnomalyGateway(svc, capacity=4, max_batch=4, max_wait_ms=0.0)
    gw.admit("a")
    gw.admit("b")
    for t in range(4):
        gw.step({"a": _series(0, 8)[t], "b": _series(1, 8)[t]})
    gw.score([_series(2, 10), _series(3, 10)])
    s = gw.stats()
    assert s["schedule"] == "wavefront"
    assert s["capacity"] == 4 and s["active_streams"] == 2
    assert s["counters"]["pool.stream_steps"] == 8
    assert s["counters"]["queue.completed"] == 2
    assert 0.0 < s["batch_fill_ratio"] <= 1.0
    assert s["latency_ms"]["count"] == 2
    assert s["latency_ms"]["p50"] <= s["latency_ms"]["p95"]
    assert s["gauges"]["pool.occupancy"] == 0.5  # 2 resident / 4 slots
    assert s["gauges"]["pool.step_fill"] == 0.5  # 2 stepped / 4 slots
    assert s["stream_steps_per_s"] > 0


def test_service_open_gateway_binds_engine(svc):
    gw = svc.open_gateway(capacity=2, max_batch=4)
    assert gw.engine is svc.engine
    assert gw.service is svc
    assert gw.pool.capacity == 2 and gw.batcher.max_batch == 4


def test_gateway_requires_bound_params():
    from repro.engine import build_engine

    engine = build_engine(get_config(ARCH), "wavefront")  # no params bound
    with pytest.raises(ValueError, match="bind"):
        AnomalyGateway(engine, capacity=2)


def test_gateway_rejects_non_engine():
    with pytest.raises(TypeError, match="AnomalyService or Engine"):
        AnomalyGateway(object(), capacity=2)

"""Durable sessions (repro.gateway.durability + tokens + claims).

The contract under test, layer by layer:

* tokens — HMAC-signed resumption tokens round-trip; tampering,
  expiry and unknown sessions are distinct, deliberate failures.
* claims — per-worker device claims are enforced disjoint, with dead
  owners reaped and overlaps named in the error.
* in-process — snapshot -> restore -> replay reproduces an
  uninterrupted run BIT-EXACTLY (the pool step is deterministic), and
  suspended (parked) sessions resume with zero loss.
* over the wire — SIGKILL the worker serving a live stream, resume by
  token on the respawned front, and the full score trajectory equals a
  solo oracle; drain migrates residents (``sessions_lost == 0``) and a
  NEW front on the same store still resumes them.
* control plane — ``recalibrate(params=...)`` fans out over the worker
  pipes and survives a respawn (the supervisor replays it).
"""
import functools
import os
import signal
import socket
import time

import numpy as np
import pytest

import jax

from _hypothesis_compat import given, settings, st
from conftest import (
    GATEWAY_ARCH as ARCH,
    GATEWAY_FEATS as FEATS,
    gateway_series as _series,
    solo_stream_errors as _solo_errors,
)
from repro.engine import AnomalyService
from repro.gateway.claims import (
    DeviceClaimError,
    DeviceClaimRegistry,
    validate_disjoint,
)
from repro.gateway.client import GatewayClient, GatewayClientError
from repro.gateway.durability import enable_durability
from repro.gateway.tokens import (
    ExpiredTokenError,
    TamperedTokenError,
    TokenSigner,
    load_or_create_secret,
)
from repro.gateway.workers import WorkerFront

needs_reuseport = pytest.mark.skipif(
    not hasattr(socket, "SO_REUSEPORT"),
    reason="WorkerFront needs SO_REUSEPORT",
)


def _make_gateway(capacity: int = 4, max_batch: int = 4,
                  max_wait_ms: float = 10.0):
    """Per-worker factory (module-level: must pickle under spawn)."""
    svc = AnomalyService(ARCH, schedule="wavefront")
    return svc.open_gateway(capacity=capacity, max_batch=max_batch,
                            max_wait_ms=max_wait_ms)


def _wait_until(predicate, timeout: float = 120.0, interval: float = 0.05):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


@pytest.fixture(scope="module")
def svc():
    return AnomalyService(ARCH, schedule="wavefront")


# -- resumption tokens ------------------------------------------------------


def test_token_roundtrip(tmp_path):
    signer = TokenSigner(load_or_create_secret(tmp_path))
    tok = signer.issue("s-abc", 17, epoch=3)
    claim = signer.verify(tok)
    assert (claim.sid, claim.seq, claim.epoch) == ("s-abc", 17, 3)


@settings(max_examples=25)
@given(
    seq=st.integers(0, 2**40),
    epoch=st.integers(0, 1000),
    flip=st.integers(5, 40),
)
def test_token_any_payload_roundtrips_and_any_tamper_fails(seq, epoch, flip):
    signer = TokenSigner(b"k" * 32)
    tok = signer.issue("s-prop", seq, epoch)
    claim = signer.verify(tok)
    assert claim.seq == seq and claim.epoch == epoch
    # flip one character anywhere in payload or signature: must not verify
    i = min(flip, len(tok) - 1)
    if tok[i] == ".":
        i += 1
    bad = tok[:i] + ("A" if tok[i] != "A" else "B") + tok[i + 1:]
    with pytest.raises(TamperedTokenError):
        signer.verify(bad)


def test_token_wrong_secret_and_malformed_rejected():
    a, b = TokenSigner(b"a" * 32), TokenSigner(b"b" * 32)
    tok = a.issue("s-x", 1)
    with pytest.raises(TamperedTokenError):
        b.verify(tok)
    for junk in ("", "rt1", "rt9.x.y", "not-a-token", None, 42):
        with pytest.raises(TamperedTokenError):
            a.verify(junk)


def test_token_expiry_uses_injected_clock():
    now = [1000.0]
    signer = TokenSigner(b"k" * 32, ttl_s=60.0, clock=lambda: now[0])
    tok = signer.issue("s-ttl", 5)
    assert signer.verify(tok).seq == 5
    now[0] += 61.0
    with pytest.raises(ExpiredTokenError):
        signer.verify(tok)
    forever = TokenSigner(b"k" * 32, ttl_s=None, clock=lambda: now[0])
    now[0] += 1e9
    assert forever.verify(forever.issue("s-ttl", 6)).seq == 6


def test_secret_file_is_created_once_and_private(tmp_path):
    s1 = load_or_create_secret(tmp_path)
    s2 = load_or_create_secret(tmp_path)
    assert s1 == s2 and len(s1) >= 16
    mode = os.stat(tmp_path / "token.secret").st_mode & 0o777
    assert mode == 0o600
    (tmp_path / "other").mkdir()
    assert load_or_create_secret(tmp_path / "other") != s1


# -- device-claim registry --------------------------------------------------


def test_validate_disjoint_names_both_owners():
    ok = {"worker-0": ("device:0",), "worker-1": ("device:1",)}
    validate_disjoint(ok)
    bad = {"worker-0": ("device:0", "device:1"), "worker-1": ("device:1",)}
    with pytest.raises(DeviceClaimError) as ei:
        validate_disjoint(bad)
    assert "worker-0" in str(ei.value) and "worker-1" in str(ei.value)
    assert "device:1" in str(ei.value)


def test_registry_conflict_and_release(tmp_path):
    reg = DeviceClaimRegistry(tmp_path)
    reg.claim("worker-0", [0, 1])
    with pytest.raises(DeviceClaimError) as ei:
        reg.claim("worker-1", [1])
    assert "worker-0" in str(ei.value)
    reg.release("worker-0")
    reg.claim("worker-1", [1])  # freed by release
    assert set(reg.claims()) == {"worker-1"}


def test_registry_reaps_dead_owner(tmp_path):
    reg = DeviceClaimRegistry(tmp_path)
    # a claim left behind by a PID that no longer exists must not block
    reg.claim("worker-ghost", [2], pid=2 ** 22 + 12345)
    reg.claim("worker-0", [2])  # reaps the ghost instead of raising
    assert set(reg.claims()) == {"worker-0"}
    # but the SAME owner re-claiming (respawn, same name, new pid) is fine
    reg.claim("worker-0", [2], pid=os.getpid())


@needs_reuseport
def test_front_rejects_overlapping_device_claims(tmp_path):
    with pytest.raises(DeviceClaimError):
        WorkerFront(
            functools.partial(_make_gateway), n_workers=2,
            device_claims={0: [0], 1: [0]}, claims_dir=str(tmp_path),
        )


# -- in-process: snapshot / restore / replay is bit-exact -------------------


def test_snapshot_resume_replay_is_bit_equal(svc, tmp_path):
    """Worker A dies after step 8 with its last snapshot at step 5; worker
    B (same store, different shard) restores from the snapshot and the
    client replays 6..8.  Every score must equal an uninterrupted run —
    bit-equal, not allclose: both paths run the same compiled step on
    the same state."""
    T = 12
    rng = np.random.default_rng(1)
    data = rng.standard_normal((T, FEATS)).astype(np.float32)

    gw_o = svc.open_gateway(capacity=4)
    dur_o = enable_durability(gw_o, str(tmp_path / "oracle"), shard="oracle")
    sid_o, _ = dur_o.admit()
    oracle = [dur_o.step(sid_o, data[t])[0] for t in range(T)]

    store = str(tmp_path / "store")
    gw_a = svc.open_gateway(capacity=4)
    dur_a = enable_durability(gw_a, store, shard="worker-0")
    sid, token = dur_a.admit()
    errs, tokens = [], {0: token}
    for t in range(8):
        e, seq, tokens[seq] = dur_a.step(sid, data[t])
        errs.append(e)
        if t == 4:
            dur_a.snapshot_now(wait=True)
    # gw_a "dies" here with steps 6..8 existing only client-side

    gw_b = svc.open_gateway(capacity=4)
    dur_b = enable_durability(gw_b, store, shard="worker-1")
    out = dur_b.resume(tokens[8])
    assert out["sid"] == sid and out["seq"] == 5  # snapshot position
    errs_b = [dur_b.step(sid, data[t])[0] for t in range(5, T)]
    np.testing.assert_array_equal(np.asarray(oracle),
                                  np.asarray(errs[:5] + errs_b))

    # parked handoff: suspend on B, snapshot, resume on a fresh C at the
    # EXACT position (zero replay needed)
    last_tok = dur_b.step(sid, np.zeros(FEATS, np.float32))[2]
    dur_b.suspend(sid)
    dur_b.snapshot_now(wait=True)
    gw_c = svc.open_gateway(capacity=4)
    dur_c = enable_durability(gw_c, store, shard="worker-2")
    out_c = dur_c.resume(last_tok)
    assert out_c["seq"] == T + 1


def test_step_tokens_amortize_but_resume_anywhere(svc, tmp_path):
    """Tokens are re-minted every ``token_refresh_steps`` (an epoch bump
    forces it); the cached in-between token resumes just as well because
    replay position comes from the snapshot + client buffer."""
    gw = svc.open_gateway(capacity=4)
    dur = enable_durability(gw, str(tmp_path), shard="w0")
    dur.token_refresh_steps = 4
    sid, tok0 = dur.admit()
    x = np.zeros(FEATS, np.float32)
    toks = [dur.step(sid, x)[2] for _ in range(8)]
    assert toks[0] == toks[1] == toks[2] == tok0   # cached (seq 1..3)
    assert toks[3] != tok0                         # re-mint at seq 4
    assert toks[3] == toks[4] == toks[5] == toks[6]
    assert toks[7] != toks[3]                      # re-mint at seq 8
    gw.recalibrate(threshold=0.5)                  # bumps the epoch ...
    tok_e = dur.step(sid, x)[2]
    assert tok_e not in toks                       # ... forcing a re-mint
    dur.snapshot_now(wait=True)
    gw2 = svc.open_gateway(capacity=4)
    dur2 = enable_durability(gw2, str(tmp_path), shard="w1")
    assert dur2.resume(toks[1])["seq"] == 9        # stale-seq token: fine


def test_unknown_session_and_double_resume_rejected(svc, tmp_path):
    from repro.gateway.durability import SessionActiveError
    from repro.gateway.tokens import UnknownSessionError

    gw = svc.open_gateway(capacity=4)
    dur = enable_durability(gw, str(tmp_path), shard="w0")
    sid, tok = dur.admit()
    dur.step(sid, np.zeros(FEATS, np.float32))
    with pytest.raises(SessionActiveError):
        dur.resume(tok)  # still live on this worker
    ghost = dur.store.signer.issue("s-0000000000000000", 3)
    with pytest.raises(UnknownSessionError):
        dur.resume(ghost)  # validly signed, exists in no snapshot


# -- over the wire: SIGKILL -> token resume -> drain handoff ----------------


@needs_reuseport
def test_sigkill_resume_matches_oracle_and_drain_migrates(svc, tmp_path):
    """The ISSUE-6 acceptance path end to end: kill the worker serving a
    stream, resume by token on the respawned front (scores bit-equal
    within the replay window vs a solo oracle), then drain with the
    session resident — it must be MIGRATED, not lost — and resume it
    once more on a brand-new front over the same store."""
    T, kill_at, snap_at = 16, 9, 6
    data = _series(7, T)
    oracle = _solo_errors(svc, data)
    store = str(tmp_path / "store")
    f = WorkerFront(functools.partial(_make_gateway), n_workers=2,
                    heartbeat_ms=50.0, store_dir=store,
                    snapshot_interval_ms=200.0)
    host, port = f.start(ready_timeout=180.0)
    c1 = GatewayClient(host, port)
    summary = None
    try:
        scores = []
        for t in range(kill_at):
            scores.append(c1.step(data[t])["running_error"])
            if t + 1 == snap_at:
                c1.request("snapshot")  # deterministic snapshot barrier
        token, replay = c1.session_token, c1.replay_buffer()
        assert token and c1.session_seq == kill_at

        victim = next(w["pid"] for w in f.stats()["per_worker"]
                      if w["active_streams"] == 1)
        os.kill(victim, signal.SIGKILL)
        assert _wait_until(lambda: f.restarts == 1 and f.alive_workers == 2)
        # durable front: the killed worker's stream is recoverable, NOT
        # counted as lost (contrast test_workers.py without a store)
        assert f.sessions_lost == 0
        try:
            c1.close()
        except Exception:
            pass

        with GatewayClient(host, port) as c2:
            out = c2.resume(token, replay=replay)
            # the forced snapshot pins seq >= snap_at; the 200ms auto
            # cadence may have taken a later one, shrinking the replay
            assert out["seq"] == kill_at
            assert 0 <= out["replayed"] <= kill_at - snap_at
            for t in range(kill_at, T):
                scores.append(c2.step(data[t])["running_error"])
            # in-process pool vs worker pool: identical compiled step on
            # identical state, modulo one float32 wire round-trip per score
            np.testing.assert_allclose(scores, oracle, rtol=1e-5, atol=1e-6)
            c2.request("snapshot")
            mig_token = c2.session_token
            summary = f.shutdown()  # session still resident on some worker
        assert summary["sessions_migrated"] == 1
        assert summary["sessions_lost"] == 0
        assert summary["clean_exits"] == 2 and summary["dropped_tickets"] == 0
    finally:
        if summary is None:
            f.shutdown()

    # a brand-new front over the same store adopts the handoff snapshot
    f2 = WorkerFront(functools.partial(_make_gateway), n_workers=1,
                     heartbeat_ms=100.0, store_dir=store)
    host2, port2 = f2.start(ready_timeout=180.0)
    try:
        with GatewayClient(host2, port2) as c3:
            out = c3.resume(mig_token)
            assert out["seq"] == T
            np.testing.assert_allclose(out["running_error"], oracle[-1],
                                       rtol=1e-5, atol=1e-6)
    finally:
        f2.shutdown()


@needs_reuseport
def test_wire_rejects_tampered_expired_unknown_tokens(tmp_path):
    store = str(tmp_path / "store")
    f = WorkerFront(functools.partial(_make_gateway), n_workers=1,
                    heartbeat_ms=100.0, store_dir=store)
    host, port = f.start(ready_timeout=180.0)
    try:
        with GatewayClient(host, port) as c:
            c.step(np.zeros(FEATS, np.float32))
            good = c.session_token
        secret = load_or_create_secret(store)

        def resume_error(token) -> str:
            with GatewayClient(host, port) as c2:
                with pytest.raises(GatewayClientError) as ei:
                    c2.request("resume", token=token)
            return ei.value.error

        mid = len(good) // 2
        flipped = good[:mid] + ("A" if good[mid] != "A" else "B") + good[mid + 1:]
        assert resume_error(flipped) == "TamperedTokenError"
        assert resume_error("garbage") == "TamperedTokenError"
        expired = TokenSigner(
            secret, ttl_s=3600.0, clock=lambda: time.time() - 7200.0
        ).issue("s-feedfacefeedface", 3)
        assert resume_error(expired) == "ExpiredTokenError"
        unknown = TokenSigner(secret).issue("s-feedfacefeedface", 3)
        assert resume_error(unknown) == "UnknownSessionError"
    finally:
        f.shutdown()


# -- control plane: param swap over the pipes + respawn replay --------------


@needs_reuseport
def test_recalibrate_params_fans_out_and_survives_respawn(svc):
    scaled = jax.tree.map(lambda p: p * 1.25, svc.params)
    oracle = AnomalyService(ARCH, schedule="wavefront")
    oracle._bind(jax.tree.map(np.asarray, scaled))
    window = _series(55, 8)
    import jax.numpy as jnp
    want = float(oracle.score(jnp.asarray(window[None]))[0])
    base = float(svc.score(jnp.asarray(window[None]))[0])
    assert abs(want - base) > 1e-9  # the swap must be observable

    f = WorkerFront(functools.partial(_make_gateway), n_workers=2,
                    heartbeat_ms=50.0)
    host, port = f.start(ready_timeout=180.0)
    summary = None
    try:
        out = f.recalibrate(params=scaled)
        assert out["workers"] == 2 and out["params_swapped"]
        for _ in range(3):  # several connections: exercise both workers
            with GatewayClient(host, port) as c:
                np.testing.assert_allclose(c.score(window), want,
                                           rtol=1e-5, atol=1e-6)
        # kill either worker: the supervisor must replay the param swap
        # onto the respawn or acceptors would serve different models
        victim = f.stats()["per_worker"][0]["pid"]
        os.kill(victim, signal.SIGKILL)
        assert _wait_until(lambda: f.restarts == 1 and f.alive_workers == 2)

        def respawn_caught_up() -> bool:
            for _ in range(4):
                with GatewayClient(host, port) as c:
                    if abs(c.score(window) - want) > 1e-4:
                        return False
            return True

        assert _wait_until(respawn_caught_up, timeout=90.0)
        summary = f.shutdown()
        assert summary["clean_exits"] == 2
    finally:
        if summary is None:
            f.shutdown()

"""Protocol-conformance gate for the bp1 wire format.

The golden frame corpus under ``tests/fixtures/wire/`` is the committed,
byte-exact definition of what every opcode's frames look like on the
wire — one file per opcode × edge case (empty batch, pipelined
multi-window frame, max-size payload, each typed error).  This script:

* rebuilds every corpus case with the live codec
  (:mod:`repro.gateway.wire`) and fails on ANY byte difference against
  the committed files — an unacknowledged wire-format change cannot pass
  CI;
* decodes every committed file back and asserts the round-trip
  (header fields, meta dict, raw data) matches the case spec exactly;
* fails on corpus files that no case claims (stale fixtures) and cases
  with no committed file.

Changing the wire format on purpose follows the same committed-baseline
workflow as ``benchmarks/check.py`` and ``analysis/baseline.json``:

    PYTHONPATH=src python scripts/wire_conformance.py \
        --update --reason "bp1: added <field> because <why>"

which rewrites the corpus and records the reason in
``tests/fixtures/wire/MANIFEST.json`` — the reason string is the audit
trail reviewers read.  Stdlib-only (struct + json; no numpy/jax), so the
CI ``lint`` job runs this in seconds before any dependency install.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import struct
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def _load_wire():
    """Import the codec without dragging in the full gateway package
    (whose ``__init__`` needs numpy) — the CI lint job runs this script
    on a bare interpreter, so fall back to loading wire.py by path."""
    try:
        from repro.gateway import wire
        return wire
    except ImportError:
        import importlib.util

        path = (Path(__file__).resolve().parent.parent
                / "src" / "repro" / "gateway" / "wire.py")
        spec = importlib.util.spec_from_file_location("repro_gateway_wire", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod


wire = _load_wire()

CORPUS_DIR = Path(__file__).resolve().parent.parent / "tests" / "fixtures" / "wire"
MANIFEST = "MANIFEST.json"


def f32s(n: int, salt: int = 0) -> bytes:
    """n deterministic float32 values, every one an exact k/128 fraction
    so the byte pattern is stable across platforms and numpy versions."""
    return b"".join(
        struct.pack("<f", (((i * 37 + salt) % 256) - 128) / 128.0)
        for i in range(n)
    )


RESP = wire.FLAG_RESPONSE
ERR = wire.FLAG_RESPONSE | wire.FLAG_ERROR

#: name -> (opcode, flags, req_id, meta, data).  Names are the corpus
#: file stems; keep them sorted roughly by opcode, requests then
#: responses then typed errors.
CASES: dict[str, tuple] = {
    # hello: the server's greeting after preamble negotiation
    "hello_resp": (wire.OP_HELLO, RESP, wire.NO_REQUEST_ID,
                   {"ok": True, "op": "hello", "protocol": "bp1",
                    "version": 1, "max_frame_bytes": 16 << 20,
                    "features": 32}, b""),
    # ping round-trip
    "ping_req": (wire.OP_PING, 0, 1, None, b""),
    "ping_resp": (wire.OP_PING, RESP, 1, {"ok": True, "op": "ping"}, b""),
    # score: single window, pipelined multi-window, empty batch,
    # priority/tenant admission fields, max-size payload (capped
    # representative: 32 KiB of raw float32 — the format is
    # length-prefixed, so size only moves the header's payload_len)
    "score_req_single": (wire.OP_SCORE, 0, 2,
                         {"n": 1, "t": 16, "f": 32}, f32s(16 * 32)),
    "score_req_pipelined": (wire.OP_SCORE, 0, 3,
                            {"n": 4, "t": 8, "f": 32}, f32s(4 * 8 * 32, 1)),
    "score_req_empty_batch": (wire.OP_SCORE, 0, 4,
                              {"n": 0, "t": 8, "f": 32}, b""),
    "score_req_priority": (wire.OP_SCORE, 0, 5,
                           {"n": 1, "t": 8, "f": 32,
                            "priority": 2, "tenant": "acme"},
                           f32s(8 * 32, 2)),
    "score_req_max_payload": (wire.OP_SCORE, 0, 6,
                              {"n": 8, "t": 32, "f": 32},
                              f32s(8 * 32 * 32, 3)),
    "score_resp_single": (wire.OP_SCORE, RESP, 2,
                          {"ok": True, "op": "score", "n": 1}, f32s(1, 4)),
    "score_resp_pipelined_alert": (wire.OP_SCORE, RESP, 3,
                                   {"ok": True, "op": "score", "n": 4,
                                    "alert": [True, False, True, False]},
                                   f32s(4, 5)),
    "score_resp_empty_batch": (wire.OP_SCORE, RESP, 4,
                               {"ok": True, "op": "score", "n": 0}, b""),
    # step: single sample, pipelined samples, durable response (seq+token)
    "step_req_single": (wire.OP_STEP, 0, 7, {"t": 1}, f32s(32, 6)),
    "step_req_pipelined": (wire.OP_STEP, 0, 8, {"t": 16}, f32s(16 * 32, 7)),
    "step_resp_durable": (wire.OP_STEP, RESP, 7,
                          {"ok": True, "op": "step", "t": 1,
                           "running_error": 0.25, "seq": 41,
                           "token": "rt1.2hGVsAmVkY2FmZQ"}, f32s(1, 8)),
    # control ops (generic meta frames)
    "close_req": (wire.OP_CLOSE, 0, 9, None, b""),
    "close_resp": (wire.OP_CLOSE, RESP, 9,
                   {"ok": True, "op": "close", "final": 0.125}, b""),
    "resume_req": (wire.OP_RESUME, 0, 10, {"token": "rt1.2hGVsAmVkY2FmZQ"}, b""),
    "recalibrate_req": (wire.OP_RECALIBRATE, 0, 11, {"threshold": 0.5}, b""),
    "stats_req": (wire.OP_STATS, 0, 12, None, b""),
    "snapshot_req": (wire.OP_SNAPSHOT, 0, 13, None, b""),
    # typed errors: each class the server answers over the wire
    "error_overloaded": (wire.OP_SCORE, ERR, 20,
                         {"ok": False, "op": "score",
                          "error": "GatewayOverloadedError",
                          "message": "queue full (1024 pending); pump() or shed load"},
                         b""),
    "error_pool_full": (wire.OP_STEP, ERR, 21,
                        {"ok": False, "op": "step", "error": "PoolFullError",
                         "message": "session pool full"}, b""),
    "error_oversized_window": (wire.OP_SCORE, ERR, 22,
                               {"ok": False, "op": "score",
                                "error": "ValueError",
                                "message": "window length 2048 exceeds max_seq_len=1024"},
                               b""),
    "error_shed": (wire.OP_SCORE, ERR, 23,
                   {"ok": False, "op": "score",
                    "error": "GatewayOverloadedError",
                    "message": "priority 2 shed under load"}, b""),
    "error_tampered_token": (wire.OP_RESUME, ERR, 24,
                             {"ok": False, "op": "resume",
                              "error": "TamperedTokenError",
                              "message": "resumption token signature mismatch"},
                             b""),
    "error_expired_token": (wire.OP_RESUME, ERR, 25,
                            {"ok": False, "op": "resume",
                             "error": "ExpiredTokenError",
                             "message": "token older than every retained snapshot"},
                            b""),
    "error_unknown_op": (0x7F, ERR, 26,
                         {"ok": False, "op": "?", "error": "ValueError",
                          "message": "unknown opcode 0x7f"}, b""),
    "error_framing": (0x00, ERR, wire.NO_REQUEST_ID,
                      {"ok": False, "op": "?", "error": "WireProtocolError",
                       "message": "bad magic b'zz'"}, b""),
}


def build(name: str) -> bytes:
    opcode, flags, rid, meta, data = CASES[name]
    return wire.pack_frame(opcode, rid, meta=meta, data=data, flags=flags)


def roundtrip(name: str, blob: bytes) -> list:
    """Decode ``blob`` and compare every field against the case spec;
    returns a list of problems (empty when conformant)."""
    opcode, flags, rid, meta, data = CASES[name]
    problems = []
    try:
        got_op, got_flags, got_rid, payload_len = wire.unpack_header(blob)
        payload = blob[wire.HEADER_SIZE:]
        if payload_len != len(payload):
            problems.append(f"{name}: header says {payload_len} payload "
                            f"bytes, file carries {len(payload)}")
        got_meta, got_data = wire.split_payload(payload)
    except wire.WireProtocolError as exc:
        return [f"{name}: does not decode: {exc}"]
    if (got_op, got_flags, got_rid) != (opcode, flags, rid):
        problems.append(
            f"{name}: header (op=0x{got_op:02x}, flags={got_flags}, "
            f"id={got_rid}) != spec (op=0x{opcode:02x}, flags={flags}, id={rid})"
        )
    if got_meta != (meta or {}):
        problems.append(f"{name}: meta {got_meta} != spec {meta or {}}")
    if bytes(got_data) != data:
        problems.append(f"{name}: data differs from spec "
                        f"({len(got_data)} vs {len(data)} bytes)")
    return problems


def check(corpus_dir: Path) -> int:
    problems: list = []
    if not corpus_dir.is_dir():
        print(f"wire-conformance: corpus dir {corpus_dir} missing — "
              f"run with --update --reason '...' to create it")
        return 1
    on_disk = {p.name for p in corpus_dir.iterdir() if p.suffix == ".bin"}
    for name in sorted(CASES):
        path = corpus_dir / f"{name}.bin"
        if not path.is_file():
            problems.append(f"{name}: corpus file missing ({path.name})")
            continue
        committed = path.read_bytes()
        rebuilt = build(name)
        if committed != rebuilt:
            i = next((k for k, (a, b) in enumerate(zip(committed, rebuilt))
                      if a != b), min(len(committed), len(rebuilt)))
            problems.append(
                f"{name}: byte mismatch at offset {i} "
                f"(committed {len(committed)}B, live codec {len(rebuilt)}B) — "
                f"the wire format changed; if intentional, re-run with "
                f"--update --reason '...'"
            )
        problems.extend(roundtrip(name, committed))
    stale = on_disk - {f"{n}.bin" for n in CASES}
    for extra in sorted(stale):
        problems.append(f"{extra}: on disk but no conformance case claims it")
    if problems:
        print(f"wire-conformance: {len(problems)} problem(s)")
        for p in problems:
            print(f"  FAIL {p}")
        return 1
    print(f"wire-conformance: {len(CASES)} frames byte-exact "
          f"(corpus {corpus_dir})")
    return 0


def update(corpus_dir: Path, reason: str) -> int:
    corpus_dir.mkdir(parents=True, exist_ok=True)
    for p in corpus_dir.iterdir():
        if p.suffix == ".bin":
            p.unlink()
    manifest: dict = {"format": "bp1", "version": wire.VERSION,
                      "reason": reason, "cases": {}}
    for name in sorted(CASES):
        blob = build(name)
        (corpus_dir / f"{name}.bin").write_bytes(blob)
        manifest["cases"][name] = {
            "bytes": len(blob),
            "sha256": hashlib.sha256(blob).hexdigest(),
        }
    (corpus_dir / MANIFEST).write_text(
        json.dumps(manifest, indent=2, sort_keys=True) + "\n"
    )
    print(f"wire-conformance: wrote {len(CASES)} frames to {corpus_dir}")
    print(f"  reason: {reason}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", type=Path, default=CORPUS_DIR,
                    help=f"corpus directory (default {CORPUS_DIR})")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the corpus from the live codec "
                         "(requires --reason)")
    ap.add_argument("--reason", default="",
                    help="why the wire format changed (recorded in the "
                         "manifest; required with --update)")
    args = ap.parse_args(argv)
    if args.update:
        if not args.reason.strip():
            ap.error("--update requires --reason '<why the format changed>'")
        return update(args.dir, args.reason.strip())
    return check(args.dir)


if __name__ == "__main__":
    raise SystemExit(main())

"""Deterministic frame fuzzer for the bp1 binary transport.

Two tiers, both seeded (``--seed``; same seed → same byte stream, so a
CI failure reproduces locally with one command):

``--codec``
    Pure-codec tier, stdlib + :mod:`repro.gateway.wire` only (no
    numpy/jax — runs in the CI ``lint`` job).  Feeds a
    :class:`~repro.gateway.wire.FrameReader` mutated garbage — truncated
    headers, oversize length fields, bad magic/version, corrupted meta —
    in adversarial chunk sizes and asserts the codec either parses or
    raises :class:`~repro.gateway.wire.WireProtocolError`; anything else
    (wrong exception, hang, giant allocation) is a bug.  Interleaved
    valid frames must still round-trip byte-exactly after every
    poisoning, using a fresh reader (a framing error is connection-fatal
    by design).

``--live``
    Boots a real :class:`~repro.gateway.server.GatewayServer` over a
    tiny model and throws the same garbage at the socket — before the
    preamble (JSON-lines path), after it (binary path), and mid-stream.
    After every attack the invariant is: a *fresh, well-formed*
    connection still gets correct answers (ping + score + step).  A
    malformed peer may lose its own connection; it must never wedge the
    server.

Usage (CI runs both)::

    PYTHONPATH=src python scripts/wire_fuzz.py --codec --iters 400
    PYTHONPATH=src python scripts/wire_fuzz.py --live  --iters 60
"""
from __future__ import annotations

import argparse
import random
import socket
import struct
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def _load_wire():
    """Import the codec without the full gateway package (whose
    ``__init__`` needs numpy): the --codec tier runs in the CI lint job
    on a bare interpreter, so fall back to loading wire.py by path."""
    try:
        from repro.gateway import wire
        return wire
    except ImportError:
        import importlib.util

        path = (Path(__file__).resolve().parent.parent
                / "src" / "repro" / "gateway" / "wire.py")
        spec = importlib.util.spec_from_file_location("repro_gateway_wire", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod


wire = _load_wire()

MAX_FRAME = 1 << 20  # small cap so an alloc bug would be loud, not slow


def _valid_frame(rng: random.Random) -> bytes:
    """One well-formed frame with randomized opcode/meta/data."""
    opcode = rng.choice(list(wire.NAME_BY_OPCODE))
    rid = rng.randrange(0, 1 << 32)
    meta = None
    if rng.random() < 0.7:
        meta = {"n": rng.randrange(0, 8), "t": rng.randrange(1, 32),
                "tag": "x" * rng.randrange(0, 16)}
    data = bytes(rng.randrange(256) for _ in range(rng.randrange(0, 256)))
    return wire.pack_frame(opcode, rid, meta=meta, data=data)


def _mutate(rng: random.Random, blob: bytes) -> bytes:
    """One adversarial transformation of a valid frame."""
    kind = rng.randrange(8)
    b = bytearray(blob)
    if kind == 0:                      # truncated header
        return bytes(b[: rng.randrange(0, wire.HEADER_SIZE)])
    if kind == 1:                      # truncated payload
        return bytes(b[: wire.HEADER_SIZE + rng.randrange(0, max(1, len(b) - wire.HEADER_SIZE))])
    if kind == 2:                      # bad magic
        b[0] = rng.randrange(256) ^ b[0] | 1
        b[1] ^= 0xFF
        return bytes(b)
    if kind == 3:                      # bad version
        b[2] = rng.choice([0, 2, 0x7F, 0xFF])
        return bytes(b)
    if kind == 4:                      # oversize length field (alloc bomb)
        struct.pack_into("<I", b, 16, rng.choice([MAX_FRAME + 1, 0x7FFFFFFF, 0xFFFFFFFF]))
        return bytes(b)
    if kind == 5:                      # meta_len beyond payload
        if len(b) > wire.HEADER_SIZE + 4:
            struct.pack_into("<I", b, wire.HEADER_SIZE, 0xFFFFFF)
        return bytes(b)
    if kind == 6:                      # corrupt meta JSON bytes
        if len(b) > wire.HEADER_SIZE + 8:
            b[wire.HEADER_SIZE + 4] ^= 0xFF
        return bytes(b)
    return bytes(rng.randrange(256) for _ in range(rng.randrange(1, 64)))  # pure noise


def _feed_chunked(rng: random.Random, reader, blob: bytes) -> list:
    """Feed ``blob`` in random-sized chunks, collecting parsed frames."""
    frames = []
    i = 0
    while i < len(blob):
        k = rng.randrange(1, 40)
        frames.extend(reader.feed(blob[i:i + k]))
        i += k
    return frames


def fuzz_codec(seed: int, iters: int) -> int:
    rng = random.Random(seed)
    parsed = rejected = 0
    for i in range(iters):
        valid = _valid_frame(rng)
        evil = _mutate(rng, valid)
        reader = wire.FrameReader(max_frame_bytes=MAX_FRAME)
        try:
            _feed_chunked(rng, reader, evil)
            # stuck partial frames are fine; silent giant buffering is not
            assert reader.pending_bytes <= MAX_FRAME + wire.HEADER_SIZE, (
                f"iter {i}: reader buffered {reader.pending_bytes} bytes"
            )
            parsed += 1
        except wire.WireProtocolError:
            rejected += 1
        # the codec must stay correct after poisoning: a FRESH reader
        # (framing errors are connection-fatal) round-trips valid frames
        clean = wire.FrameReader(max_frame_bytes=MAX_FRAME)
        got = _feed_chunked(rng, clean, valid + valid)
        assert len(got) == 2, f"iter {i}: {len(got)} frames from 2 valid"
        for f in got:
            assert wire.pack_frame(f.opcode, f.req_id, flags=f.flags) \
                .startswith(wire.pack_header(f.opcode, f.flags, f.req_id, 0)[:16]), \
                f"iter {i}: header fields did not survive round-trip"
            meta, data = wire.split_payload(f.payload)
            re_packed = wire.pack_frame(f.opcode, f.req_id,
                                        meta=meta or None,
                                        data=bytes(data), flags=f.flags)
            header = wire.pack_header(f.opcode, f.flags, f.req_id,
                                      len(f.payload))
            assert re_packed == header + bytes(f.payload), \
                f"iter {i}: payload not byte-stable"
    print(f"wire-fuzz codec: {iters} iterations "
          f"({rejected} rejected, {parsed} tolerated), seed={seed}")
    return 0


# -- live tier -------------------------------------------------------------


def _attack_bytes(rng: random.Random) -> bytes:
    """Garbage to throw at a live socket."""
    choice = rng.randrange(6)
    if choice == 0:      # binary preamble then mutated frame
        return wire.PREAMBLE + _mutate(rng, _valid_frame(rng))
    if choice == 1:      # preamble then truncated header, then hang up
        return wire.PREAMBLE + wire.pack_header(wire.OP_PING, 0, 1, 0)[
            : rng.randrange(1, wire.HEADER_SIZE)]
    if choice == 2:      # preamble then oversize length field
        return wire.PREAMBLE + wire.pack_header(wire.OP_SCORE, 0, 2, 0xFFFFFFF0)
    if choice == 3:      # raw garbage straight at the JSON-lines reader
        return bytes(rng.randrange(256) for _ in range(rng.randrange(1, 128))) + b"\n"
    if choice == 4:      # bad magic where the preamble would go
        return b"\xb2Q1\n" + _valid_frame(rng)
    return wire.PREAMBLE + wire.PREAMBLE + _valid_frame(rng)  # double preamble


def fuzz_live(seed: int, iters: int) -> int:
    # heavyweight imports gated here so --codec stays stdlib-fast
    from repro.engine import AnomalyService
    from repro.gateway.client import GatewayClient
    from repro.gateway.server import GatewayServer

    import numpy as np

    rng = random.Random(seed)
    svc = AnomalyService("lstm-ae-f32-d2", schedule="wavefront")
    gw = svc.open_gateway(capacity=4, max_batch=4, max_wait_ms=5.0)
    server = GatewayServer(gw, port=0, pump_interval_ms=2.0)
    host, port = server.start_in_thread()
    feats = gw.pool.features
    window = np.linspace(0.0, 1.0, 8 * feats, dtype=np.float32).reshape(8, feats)
    try:
        # oracle once, before any attack
        with GatewayClient(host, port, protocol="binary") as c:
            oracle_score = c.score(window)
        for i in range(iters):
            attack = _attack_bytes(rng)
            with socket.create_connection((host, port), timeout=10) as s:
                s.settimeout(10)
                try:
                    s.sendall(attack)
                    # half of the time, linger to read whatever the
                    # server answers (error frame / JSON error line)
                    if rng.random() < 0.5:
                        s.recv(4096)
                except OSError:
                    pass  # server hanging up on us is a legal response
            if i % 10 == 9:
                # the invariant: fresh well-formed connections still work
                proto = "binary" if rng.random() < 0.5 else "json"
                with GatewayClient(host, port, protocol=proto) as c:
                    assert c.request("ping")["ok"], f"iter {i}: ping failed"
                    score = c.score(window)
                    assert score == oracle_score, (
                        f"iter {i}: score drifted after fuzzing "
                        f"({score} != {oracle_score})"
                    )
                    c.step(window[0])
        # final end-to-end check on both protocols
        for proto in ("binary", "json"):
            with GatewayClient(host, port, protocol=proto) as c:
                assert c.score(window) == oracle_score
    finally:
        server.stop_in_thread()
    print(f"wire-fuzz live: survived {iters} attacks, "
          f"scores bit-stable on both protocols, seed={seed}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--codec", action="store_true", help="codec tier (stdlib-only)")
    ap.add_argument("--live", action="store_true", help="live-server tier")
    ap.add_argument("--seed", type=int, default=1302, help="PRNG seed")
    ap.add_argument("--iters", type=int, default=200, help="iterations")
    args = ap.parse_args(argv)
    if not (args.codec or args.live):
        ap.error("pick a tier: --codec and/or --live")
    rc = 0
    if args.codec:
        rc |= fuzz_codec(args.seed, args.iters)
    if args.live:
        rc |= fuzz_live(args.seed, max(10, args.iters))
    return rc


if __name__ == "__main__":
    raise SystemExit(main())

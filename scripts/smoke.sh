#!/usr/bin/env bash
# Tier-1 smoke: the checks every PR must keep green.
#   1. the full pytest suite
#   2. the quickstart example (train -> calibrate -> detect via AnomalyService)
#   3. the serving launcher on the reduced paper model
#   4. the streaming gateway (session pool + micro-batched queue)
#   5. the async transport: server up, client round-trip (one streaming
#      session + a batch of one-shot scores), SIGTERM -> clean drain
#   6. the same transport on a sharded placement (--mesh data=2 over two
#      forced host devices): pool slots + micro-batch rows shard 2-way
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# run one --http server (extra args in $2...) until the client example has
# driven it, then SIGTERM and assert a clean drain
run_transport_smoke() {
  local log="$1"; shift
  python -m repro.launch.serve --arch lstm-ae-f32-d2 --http --port 0 \
    --train-steps 0 --capacity 8 --max-batch 8 "$@" >"$log" 2>&1 &
  local pid=$!
  trap 'kill "'"$pid"'" 2>/dev/null || true' EXIT
  for _ in $(seq 1 150); do
    grep -q "listening on" "$log" && break
    kill -0 "$pid" 2>/dev/null || { cat "$log"; exit 1; }
    sleep 0.2
  done
  local port
  port=$(sed -n 's/.*listening on [^:]*:\([0-9]*\).*/\1/p' "$log" | head -1)
  [ -n "$port" ] || { echo "server never reported its port"; cat "$log"; exit 1; }

  python examples/gateway_client.py --port "$port" --timesteps 16 --requests 12

  kill -TERM "$pid"
  wait "$pid"   # non-zero (or hang) here == unclean shutdown, smoke fails
  trap - EXIT
  grep -q "drained" "$log" || { echo "server did not drain"; cat "$log"; exit 1; }
  cat "$log"
}

python -m pytest -x -q

python examples/quickstart.py

python -m repro.launch.serve --arch lstm-ae-f32-d2 \
  --requests 3 --batch 4 --seq-len 16 --schedule wavefront

python -m repro.launch.serve --arch lstm-ae-f32-d2 --gateway --train-steps 0 \
  --capacity 8 --max-batch 8 --seq-len 24 --requests 20

run_transport_smoke "$(mktemp)"

# sharded placement over the wire: two forced host devices, pool slots and
# micro-batch rows 2-way data-parallel, same client, same clean drain bar
SHARDED_LOG=$(mktemp)
(
  export XLA_FLAGS="--xla_force_host_platform_device_count=2"
  run_transport_smoke "$SHARDED_LOG" --mesh data=2
)
grep -q "mesh=2xdata" "$SHARDED_LOG" || {
  echo "sharded server did not report its mesh"; cat "$SHARDED_LOG"; exit 1; }

echo "smoke OK"

#!/usr/bin/env bash
# Tier-1 smoke: the checks every PR must keep green.
#   1. the full pytest suite
#   2. the quickstart example (train -> calibrate -> detect via AnomalyService)
#   3. the serving launcher on the reduced paper model
#   4. the streaming gateway (session pool + micro-batched queue)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

python examples/quickstart.py

python -m repro.launch.serve --arch lstm-ae-f32-d2 \
  --requests 3 --batch 4 --seq-len 16 --schedule wavefront

python -m repro.launch.serve --arch lstm-ae-f32-d2 --gateway --train-steps 0 \
  --capacity 8 --max-batch 8 --seq-len 24 --requests 20

echo "smoke OK"

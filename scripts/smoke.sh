#!/usr/bin/env bash
# Tier-1 smoke: the checks every PR must keep green.
#   1. the full pytest suite
#   2. the quickstart example (train -> calibrate -> detect via AnomalyService)
#   3. the serving launcher on the reduced paper model
#   4. the streaming gateway (session pool + micro-batched queue)
#   5. the async transport: server up, client round-trip (one streaming
#      session + a batch of one-shot scores), SIGTERM -> clean drain
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

python examples/quickstart.py

python -m repro.launch.serve --arch lstm-ae-f32-d2 \
  --requests 3 --batch 4 --seq-len 16 --schedule wavefront

python -m repro.launch.serve --arch lstm-ae-f32-d2 --gateway --train-steps 0 \
  --capacity 8 --max-batch 8 --seq-len 24 --requests 20

SERVER_LOG=$(mktemp)
python -m repro.launch.serve --arch lstm-ae-f32-d2 --http --port 0 \
  --train-steps 0 --capacity 8 --max-batch 8 >"$SERVER_LOG" 2>&1 &
SERVER_PID=$!
trap 'kill "$SERVER_PID" 2>/dev/null || true' EXIT
for _ in $(seq 1 150); do
  grep -q "listening on" "$SERVER_LOG" && break
  kill -0 "$SERVER_PID" 2>/dev/null || { cat "$SERVER_LOG"; exit 1; }
  sleep 0.2
done
PORT=$(sed -n 's/.*listening on [^:]*:\([0-9]*\).*/\1/p' "$SERVER_LOG" | head -1)
[ -n "$PORT" ] || { echo "server never reported its port"; cat "$SERVER_LOG"; exit 1; }

python examples/gateway_client.py --port "$PORT" --timesteps 16 --requests 12

kill -TERM "$SERVER_PID"
wait "$SERVER_PID"   # non-zero (or hang) here == unclean shutdown, smoke fails
trap - EXIT
grep -q "drained" "$SERVER_LOG" || { echo "server did not drain"; cat "$SERVER_LOG"; exit 1; }
cat "$SERVER_LOG"

echo "smoke OK"

#!/usr/bin/env bash
# Tier-1 smoke: the checks every PR must keep green.
#   1. the full pytest suite (skipped when SMOKE_SKIP_TESTS is set — CI
#      runs pytest in its own `tests` job with junit/timing artifacts)
#   2. the quickstart example (train -> calibrate -> detect via AnomalyService)
#   3. the serving launcher on the reduced paper model
#   4. the streaming gateway (session pool + micro-batched queue)
#   5. the async transport: server up, client round-trip (one streaming
#      session + a batch of one-shot scores), SIGTERM -> clean drain
#   6. the same transport on a sharded placement (--mesh data=2 over two
#      forced host devices): pool slots + micro-batch rows shard 2-way
#   7. the multi-worker front (--workers 2): two concurrent clients over
#      one SO_REUSEPORT port — one legacy JSON-lines, one binary bp1
#      (cross-protocol interop) — a live GET /metrics scrape of the
#      front-aggregated Prometheus view, then SIGTERM -> every worker
#      exits cleanly with zero dropped tickets
#  10. the wire-protocol gates: byte-exact bp1 conformance corpus, the
#      seeded codec fuzzer, and the live-server fuzzer (garbage frames
#      must never wedge the server for well-formed clients)
#   8. durable sessions: SIGKILL a worker mid-stream, resume on the
#      respawned front with the signed token + client replay buffer —
#      scores must be bit-equal to an uninterrupted oracle, and the
#      final drain must migrate the resident session (sessions_lost=0)
#   9. the static-analysis gate (python -m repro.analysis): exit 0 on
#      the tree with the committed baseline AND nonzero on a
#      deliberately-bad temp file, so the gate is smoke-tested too
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# run one --http server (extra args in $2...) until the client example has
# driven it, then SIGTERM and assert a clean drain
run_transport_smoke() {
  local log="$1"; shift
  python -m repro.launch.serve --arch lstm-ae-f32-d2 --http --port 0 \
    --train-steps 0 --capacity 8 --max-batch 8 "$@" >"$log" 2>&1 &
  local pid=$!
  trap 'kill "'"$pid"'" 2>/dev/null || true' EXIT
  for _ in $(seq 1 150); do
    grep -q "listening on" "$log" && break
    kill -0 "$pid" 2>/dev/null || { cat "$log"; exit 1; }
    sleep 0.2
  done
  local port
  port=$(sed -n 's/.*listening on [^:]*:\([0-9]*\).*/\1/p' "$log" | head -1)
  [ -n "$port" ] || { echo "server never reported its port"; cat "$log"; exit 1; }

  python examples/gateway_client.py --port "$port" --timesteps 16 --requests 12

  kill -TERM "$pid"
  wait "$pid"   # non-zero (or hang) here == unclean shutdown, smoke fails
  trap - EXIT
  grep -q "drained" "$log" || { echo "server did not drain"; cat "$log"; exit 1; }
  cat "$log"
}

if [ -z "${SMOKE_SKIP_TESTS:-}" ]; then
  python -m pytest -x -q
fi

# the static-analysis gate itself: clean on the repo with the committed
# baseline, and — so the gate is provably still a gate — nonzero on a
# deliberately-bad temp file (event-loop-blocking sleep in an async def)
python -m repro.analysis --baseline analysis/baseline.json
ANALYSIS_BAD=$(mktemp --suffix=.py)
printf 'import time\n\n\nasync def f():\n    time.sleep(1)\n' >"$ANALYSIS_BAD"
if python -m repro.analysis "$ANALYSIS_BAD" --baseline '' >/dev/null 2>&1; then
  echo "analysis gate FAILED to flag a known-bad file"; exit 1
fi
rm -f "$ANALYSIS_BAD"
echo "analysis gate OK (clean tree passes, known-bad file fails)"

# wire-protocol gates: golden-corpus conformance (byte-exact against the
# live codec), then the seeded fuzzers — pure codec first, then a live
# GatewayServer that must keep serving well-formed clients through the
# garbage
python scripts/wire_conformance.py
python scripts/wire_fuzz.py --codec --iters 200
python scripts/wire_fuzz.py --live --iters 30

python examples/quickstart.py

python -m repro.launch.serve --arch lstm-ae-f32-d2 \
  --requests 3 --batch 4 --seq-len 16 --schedule wavefront

python -m repro.launch.serve --arch lstm-ae-f32-d2 --gateway --train-steps 0 \
  --capacity 8 --max-batch 8 --seq-len 24 --requests 20

run_transport_smoke "$(mktemp)"

# sharded placement over the wire: two forced host devices, pool slots and
# micro-batch rows 2-way data-parallel, same client, same clean drain bar
SHARDED_LOG=$(mktemp)
(
  export XLA_FLAGS="--xla_force_host_platform_device_count=2"
  run_transport_smoke "$SHARDED_LOG" --mesh data=2
)
grep -q "mesh=2xdata" "$SHARDED_LOG" || {
  echo "sharded server did not report its mesh"; cat "$SHARDED_LOG"; exit 1; }

# multi-worker front: two worker processes behind one SO_REUSEPORT port,
# driven by two clients at once; SIGTERM must drain BOTH workers cleanly
# (every pending ticket answered, zero dropped) before the exit line
WORKERS_LOG=$(mktemp)
python -m repro.launch.serve --arch lstm-ae-f32-d2 --http --workers 2 \
  --mesh data=1 --port 0 --train-steps 0 --capacity 8 --max-batch 8 \
  --metrics-port 0 >"$WORKERS_LOG" 2>&1 &
WPID=$!
trap 'kill "'"$WPID"'" 2>/dev/null || true' EXIT
for _ in $(seq 1 300); do
  grep -q "listening on" "$WORKERS_LOG" && break
  kill -0 "$WPID" 2>/dev/null || { cat "$WORKERS_LOG"; exit 1; }
  sleep 0.2
done
grep -q "workers=2 mesh=1xdata" "$WORKERS_LOG" || {
  echo "worker front did not report workers/mesh"; cat "$WORKERS_LOG"; exit 1; }
WPORT=$(sed -n 's/.*listening on [^:]*:\([0-9]*\).*/\1/p' "$WORKERS_LOG" | head -1)
[ -n "$WPORT" ] || { echo "worker front never reported its port"; cat "$WORKERS_LOG"; exit 1; }

# cross-protocol interop: one legacy JSON-lines client and one binary
# bp1 client drive the same front concurrently; the drain line below
# proves neither protocol dropped a ticket
python examples/gateway_client.py --port "$WPORT" --timesteps 12 --requests 10 &
WC1=$!
python examples/gateway_client.py --port "$WPORT" --timesteps 12 --requests 10 \
  --seed 1 --protocol binary &
WC2=$!
wait "$WC1" && wait "$WC2" || { echo "worker-front client failed"; cat "$WORKERS_LOG"; exit 1; }

# scrape the live front-aggregated /metrics view (Prometheus text): the
# supervisor endpoint must report both workers and traffic the two
# clients just pushed through the merged request histograms
MPORT=$(sed -n 's/.*metrics_port=\([0-9]*\).*/\1/p' "$WORKERS_LOG" | head -1)
[ -n "$MPORT" ] || { echo "worker front never reported metrics_port"; cat "$WORKERS_LOG"; exit 1; }
python - "$MPORT" <<'PYEOF'
import sys, urllib.request
body = urllib.request.urlopen(
    f"http://127.0.0.1:{sys.argv[1]}/metrics", timeout=30).read().decode()
for needle in ('repro_workers_count{scope="front"} 2',
               "repro_queue_completed_total",
               'repro_request_ms_bucket{le="+Inf",scope="front"}'):
    assert needle in body, f"missing {needle!r} in /metrics:\n{body}"
print("metrics scrape OK:", len(body.splitlines()), "lines")
PYEOF

kill -TERM "$WPID"
wait "$WPID"   # non-zero (or hang) here == unclean shutdown, smoke fails
trap - EXIT
grep -q "drained: 2/2 workers exited cleanly, 0 dropped tickets" "$WORKERS_LOG" || {
  echo "worker front did not drain every worker cleanly"; cat "$WORKERS_LOG"; exit 1; }
cat "$WORKERS_LOG"

# durable sessions: the script boots its own 2-worker front with a
# snapshot store, SIGKILLs the worker serving a live stream, resumes by
# token on the respawned front and checks bit-equality + drain handoff
python examples/durable_resume.py
echo "kill-worker-resume OK"

echo "smoke OK"

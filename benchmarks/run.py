"""Benchmark harness — one function per paper table/figure + roofline.

Output convention: ``name,us_per_call,derived`` CSV rows (derived carries
the table-specific payload, ';'-separated).

  table1_resources   — paper Table 1: RH_m, balanced reuse factors,
                       multiplier (DSP) demand, steady-state utilization
  table2_latency     — paper Table 2: measured CPU (this machine, jitted
                       JAX) vs the calibrated Eq-1 FPGA model, T=1..64
  table3_energy      — paper Table 3: energy/timestep from the same runs
  schedule_compare   — dataflow (wavefront) vs layer-by-layer on the
                       paper's own cycle model — isolates the temporal-
                       parallelism win from platform effects
  engine_throughput  — every registered execution schedule through the
                       unified Engine API: wall time + Eq-1 accounting
  gateway_throughput — pooled streaming through repro.gateway vs the
                       one-stream-per-call baseline: stream-steps/sec per
                       pool size and schedule (``--json`` writes the rows
                       to a BENCH_gateway.json-style file for trending)
  gateway_transport  — the asyncio socket transport (auto-negotiated,
                       so bp1 binary frames) vs in-process gateway
                       calls: per-request wire overhead for one-shot
                       scoring and session stepping
                       (``--json BENCH_transport.json`` in CI)
  gateway_binary     — bp1 binary frames vs the legacy JSON-lines
                       protocol vs in-process on the same windows, plus
                       a pipelining depth sweep (1/8/64 windows per
                       frame) and the pipelined streaming path
                       (``--json BENCH_binary.json`` in CI)
  gateway_sharding   — pooled gateway throughput vs data-mesh size 1/2/4
                       on forced host devices, fixed slots per device
                       (``--json BENCH_sharding.json`` in CI); each mesh
                       size re-execs in a subprocess
  gateway_workers    — one-shot score throughput through the multi-worker
                       SO_REUSEPORT front vs worker count 1/2/4
                       (``benchmarks/workers_bench.py`` per count;
                       ``--json BENCH_workers.json`` in CI).  Scaling
                       needs cores: on a >=4-core box ``w4`` should beat
                       the single-loop ``w1`` by >=2x; on the 2-core CI
                       class the client+server pipeline saturates first
                       and the table trends regression, not speedup
  gateway_durability — the durability tax: per-step cost of resident
                       durable sessions (seq + HMAC token per step,
                       periodic async pool snapshots) vs the same
                       per-session stepping on a plain gateway, plus
                       cold resume-from-snapshot latency on a second
                       gateway sharing the store
                       (``--json BENCH_durability.json`` in CI)
  obs_overhead       — the observability tax: the same pooled-streaming
                       and micro-batch score traffic with per-stage
                       histograms + span tracing ON (obs_detail=True,
                       the default) vs OFF; ``vs_off`` must stay within
                       5% of 1.0 (``--json BENCH_obs.json`` in CI)
  gateway_adaptive   — the control plane (repro.control) vs static
                       serving configs on seeded bursty/diurnal/
                       adversarial traces through the virtual-clock
                       simulator in ``benchmarks/traces.py`` (results are
                       bit-deterministic: no wall clock anywhere), plus
                       one REAL 2->1-worker scale-down drain.  Gated
                       claims: adaptive meets the declared p95 SLO on the
                       bursty trace at >=1.2x the goodput of the best
                       static arm; priority-0 traffic is never shed while
                       priority-2 absorbs the flood; the drain reports
                       zero dropped tickets
                       (``--json BENCH_adaptive.json`` in CI)
  roofline_cells     — §Roofline summary over experiments/dryrun artifacts

``--tables`` selects a subset; ``--json PATH`` additionally dumps the
selected rows as a JSON list of {name, us_per_call, derived} objects
(written atomically — temp file + rename — so a killed run can't leave a
truncated table for CI to upload; rows whose payload is an error also
carry a top-level "error" field).  ``benchmarks/check.py`` gates the
tables against ``benchmarks/baselines/``.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time
from pathlib import Path

import jax
import jax.numpy as jnp


def _timeit(fn, *args, iters: int = 50, warmup: int = 5) -> float:
    """Median wall time per call in microseconds (post-warmup, jitted)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(times)


def table1_resources() -> list[str]:
    from repro.config import get_config
    from repro.core.balancing import balance_model, total_multipliers, utilization
    from repro.core.latency import PAPER_RH_M

    rows = []
    for name, rh_m in PAPER_RH_M.items():
        bal = balance_model(get_config(name).lstm_ae, rh_m)
        rhs = "/".join(str(b.rh) for b in bal)
        rows.append(
            f"table1.{name},0.0,"
            f"RH_m={rh_m};RH_i={rhs};multipliers={total_multipliers(bal):.0f};"
            f"utilization={utilization(bal):.3f};Lat_t={bal[0].lat_t}"
        )
    return rows


_T_STEPS = (1, 2, 4, 6, 16, 64)


def _measure_cpu_lstm_ae(name: str) -> dict[int, float]:
    """Median jitted CPU latency (us) of the full LSTM-AE forward per T."""
    from repro.config import get_config
    from repro.core import init_lstm_ae, lstm_ae_sequential

    cfg = get_config(name)
    params = init_lstm_ae(jax.random.PRNGKey(0), cfg)
    f = cfg.lstm_ae.input_features
    out = {}
    fwd = jax.jit(lambda p, xs: lstm_ae_sequential(p, xs))
    for t in _T_STEPS:
        xs = jax.random.normal(jax.random.PRNGKey(1), (t, 1, f))
        out[t] = _timeit(fwd, params, xs, iters=30, warmup=3)
    return out


def table2_latency() -> list[str]:
    from repro.config import get_config
    from repro.core.latency import PAPER_RH_M, fpga_latency_ms

    rows = []
    for name, rh_m in PAPER_RH_M.items():
        cfg = get_config(name).lstm_ae
        cpu = _measure_cpu_lstm_ae(name)
        for t in _T_STEPS:
            fpga_ms = fpga_latency_ms(cfg, t, rh_m).ms
            cpu_ms = cpu[t] / 1e3
            rows.append(
                f"table2.{name}.T{t},{cpu[t]:.1f},"
                f"fpga_model_ms={fpga_ms:.4f};cpu_ms={cpu_ms:.4f};"
                f"speedup_vs_cpu={cpu_ms / fpga_ms:.1f}x"
            )
    return rows


def table3_energy() -> list[str]:
    from repro.config import get_config
    from repro.core.latency import PAPER_RH_M, energy_per_timestep_mj, fpga_latency_ms

    rows = []
    for name, rh_m in PAPER_RH_M.items():
        cfg = get_config(name).lstm_ae
        cpu = _measure_cpu_lstm_ae(name)
        for t in (1, 64):
            fpga_ms = fpga_latency_ms(cfg, t, rh_m).ms
            e_fpga = energy_per_timestep_mj(fpga_ms, t, "fpga")
            e_cpu = energy_per_timestep_mj(cpu[t] / 1e3, t, "cpu")
            rows.append(
                f"table3.{name}.T{t},{cpu[t]:.1f},"
                f"fpga_mj={e_fpga:.4f};cpu_mj={e_cpu:.3f};"
                f"reduction={e_cpu / e_fpga:.0f}x"
            )
    return rows


def schedule_compare() -> list[str]:
    from repro.config import get_config
    from repro.core.latency import PAPER_RH_M, speedup_table

    rows = []
    for name, rh_m in PAPER_RH_M.items():
        for r in speedup_table(get_config(name).lstm_ae, rh_m, timesteps=(1, 16, 64)):
            rows.append(
                f"schedule.{name}.T{r['timesteps']},0.0,"
                f"dataflow_cyc={r['dataflow_cycles']};seq_cyc={r['sequential_cycles']};"
                f"temporal_speedup={r['speedup']:.2f}x"
            )
    return rows


def engine_throughput() -> list[str]:
    """Every registered schedule through the unified Engine API: batched
    scoring wall time + the schedule's own Eq-1 cycle accounting.  On a
    single device "pipelined" resolves to its wavefront fallback (the
    ``resolved=`` field records it)."""
    from repro.config import get_config
    from repro.core import init_lstm_ae
    from repro.engine import available_schedules, build_engine

    t_len, batch = 64, 256
    rows = []
    for name in ("lstm-ae-f32-d6", "lstm-ae-f64-d6"):
        cfg = get_config(name)
        params = init_lstm_ae(jax.random.PRNGKey(0), cfg)
        f = cfg.lstm_ae.input_features
        series = jax.random.normal(jax.random.PRNGKey(1), (batch, t_len, f))
        batch_d = {"series": series}
        baseline_us = None
        # sequential first so the other schedules can report speedup vs it
        scheds = ["sequential"] + [s for s in available_schedules() if s != "sequential"]
        for sched in scheds:
            engine = build_engine(cfg, sched, params=params)
            us = _timeit(engine.score, batch_d, iters=10, warmup=2)
            if sched == "sequential":
                baseline_us = us
            est = engine.latency_model(t_len)
            ratio = f";vs_sequential={baseline_us / us:.2f}" if (
                baseline_us is not None and sched != "sequential") else ""
            rows.append(
                f"engine.{name}.{sched},{us:.1f},"
                f"resolved={engine.schedule.resolved};eq1_cycles={est.cycles};"
                f"eq1_ms={est.ms:.4f}{ratio}"
            )
    return rows


def gateway_throughput() -> list[str]:
    """Two serving paths through repro.gateway vs their one-request-per-call
    baselines:

    ``gateway.stream.*`` — pooled streaming (one compiled masked step over
    the whole slot block) vs a B=1 ``AnomalyService.stream_step`` dispatch
    per stream per step, swept over pool sizes.  Streaming is schedule-
    independent (every schedule shares the decode cell loop), so this
    sweep runs once.  Acceptance bar: speedup > 2x at pool size 32 on CPU.

    ``gateway.score.*`` — micro-batched one-shot scoring (shape-bucketed,
    padded, via ``Engine.score_masked``) vs one B=1 ``score`` dispatch per
    request, per registered schedule (the forward IS schedule-dependent).
    """
    import numpy as np

    from repro.engine import AnomalyService, available_schedules

    arch = "lstm-ae-f32-d2"
    rounds, pool_sizes = 32, (1, 8, 32)
    feats = 32
    rows = []
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((rounds, max(pool_sizes), feats)).astype(np.float32)
    svc = AnomalyService(arch, schedule="wavefront")

    def solo_sps(n: int) -> float:
        sessions = [svc.stream_start(1) for _ in range(n)]
        for j in range(n):  # warmup/compile
            svc.stream_step(jnp.asarray(xs[0, j][None]), sessions[j])
        t0 = time.perf_counter()
        for r in range(rounds):
            for j in range(n):
                errs, sessions[j] = svc.stream_step(
                    jnp.asarray(xs[r, j][None]), sessions[j])
        jax.block_until_ready(errs)
        return n * rounds / (time.perf_counter() - t0)

    for n in pool_sizes:
        solo = solo_sps(n)
        gw = svc.open_gateway(capacity=n, max_batch=n)
        ids = list(range(n))
        for sid in ids:
            gw.admit(sid)
        gw.step({sid: xs[0, i] for i, sid in enumerate(ids)})  # compile
        t0 = time.perf_counter()
        for r in range(rounds):
            gw.step({sid: xs[r, i] for i, sid in enumerate(ids)})
        dt = time.perf_counter() - t0
        pooled = n * rounds / dt
        rows.append(
            f"gateway.stream.{arch}.pool{n},{dt / rounds * 1e6:.1f},"
            f"pooled_sps={pooled:.0f};solo_sps={solo:.0f};"
            f"speedup={pooled / solo:.2f}x;"
            f"step_fill={gw.stats()['gauges'].get('pool.step_fill', 0.0):.2f}"
        )

    t_len, n_req, max_batch = 32, 64, 16
    windows = rng.standard_normal((n_req, t_len, feats)).astype(np.float32)
    for sched in available_schedules():
        s = AnomalyService(arch, schedule=sched)
        gw = s.open_gateway(capacity=1, max_batch=max_batch)
        gw.score(list(windows[:max_batch]))  # compile the bucket
        t0 = time.perf_counter()
        gw.score(list(windows))
        batched_rps = n_req / (time.perf_counter() - t0)
        jax.block_until_ready(s.score(jnp.asarray(windows[:1])))  # compile B=1
        t0 = time.perf_counter()
        for i in range(n_req):
            jax.block_until_ready(s.score(jnp.asarray(windows[i:i + 1])))
        solo_rps = n_req / (time.perf_counter() - t0)
        rows.append(
            f"gateway.score.{arch}.{sched},{1e6 / batched_rps:.1f},"
            f"batched_rps={batched_rps:.0f};solo_rps={solo_rps:.0f};"
            f"speedup={batched_rps / solo_rps:.2f}x;"
            f"fill={gw.stats()['batch_fill_ratio']:.2f}"
        )
    return rows


def gateway_transport() -> list[str]:
    """Per-request overhead of the asyncio socket transport vs
    in-process gateway calls (``--json BENCH_transport.json`` in CI).

    The client is constructed with the default ``protocol="auto"`` so
    this table prices what real callers get: the negotiated bp1 binary
    protocol with pipelined submits (the JSON-lines fallback is priced
    separately in ``gateway_binary``).  ``transport.score.*`` — one-shot
    scoring: a client submits ``n_req`` mixed windows over a real socket
    (server-side micro-batching + background pump) vs the same windows
    through ``gateway.score`` in process.  ``transport.stream.*`` —
    per-timestep session stepping over the wire vs in-process
    ``gateway.step``.  ``overhead_us`` is the added wire+framing cost per
    request — the price of not needing a caller-driven pump loop.
    """
    import numpy as np

    from repro.engine import AnomalyService
    from repro.gateway.client import GatewayClient
    from repro.gateway.server import GatewayServer

    arch, feats = "lstm-ae-f32-d2", 32
    n_req, t_len, max_batch, n_steps = 64, 32, 16, 128
    rng = np.random.default_rng(0)
    windows = rng.standard_normal((n_req, t_len, feats)).astype(np.float32)
    samples = rng.standard_normal((n_steps, feats)).astype(np.float32)
    svc = AnomalyService(arch, schedule="wavefront")
    rows = []

    # -- in-process baselines (gateway API called directly) ----------------
    gw_local = svc.open_gateway(capacity=4, max_batch=max_batch, max_wait_ms=2.0)
    gw_local.score(list(windows[:max_batch]))  # compile the bucket
    t0 = time.perf_counter()
    gw_local.score(list(windows))
    local_score_rps = n_req / (time.perf_counter() - t0)
    gw_local.admit("bench")
    gw_local.step({"bench": samples[0]})  # compile the pool step
    t0 = time.perf_counter()
    for t in range(n_steps):
        gw_local.step({"bench": samples[t]})
    local_sps = n_steps / (time.perf_counter() - t0)
    gw_local.evict("bench")

    # -- the same traffic over the socket transport ------------------------
    gw_wire = svc.open_gateway(capacity=4, max_batch=max_batch, max_wait_ms=2.0)
    server = GatewayServer(gw_wire, port=0, pump_interval_ms=1.0)
    host, port = server.start_in_thread()
    try:
        with GatewayClient(host, port) as client:
            client.score_many(list(windows[:max_batch]))  # warm wire + pool
            t0 = time.perf_counter()
            client.score_many(list(windows))
            wire_score_rps = n_req / (time.perf_counter() - t0)
            client.step(samples[0])
            t0 = time.perf_counter()
            for t in range(n_steps):
                client.step(samples[t])
            wire_sps = n_steps / (time.perf_counter() - t0)
            client.end_session()
    finally:
        server.stop_in_thread()

    score_overhead = 1e6 / wire_score_rps - 1e6 / local_score_rps
    step_overhead = 1e6 / wire_sps - 1e6 / local_sps
    rows.append(
        f"transport.score.{arch},{1e6 / wire_score_rps:.1f},"
        f"wire_rps={wire_score_rps:.0f};local_rps={local_score_rps:.0f};"
        f"overhead_us={score_overhead:.1f};"
        f"relative={wire_score_rps / local_score_rps:.2f}x"
    )
    rows.append(
        f"transport.stream.{arch},{1e6 / wire_sps:.1f},"
        f"wire_sps={wire_sps:.0f};local_sps={local_sps:.0f};"
        f"overhead_us={step_overhead:.1f};"
        f"relative={wire_sps / local_sps:.2f}x"
    )
    return rows


def gateway_binary() -> list[str]:
    """The bp1 binary framed protocol vs the legacy JSON-lines protocol
    vs in-process gateway calls (``--json BENCH_binary.json`` in CI).

    Same windows, same server, three transports: ``binary.score.*``
    holds one-shot scoring throughput for bp1 (raw-float32 frames,
    pipelined 64 windows per frame), the JSON-lines fallback, and the
    in-process gateway; ``vs_json`` is the headline protocol win and
    ``relative`` (bp1 vs in-process) is the residual wire tax.
    ``binary.pipeline.*`` sweeps frames-per-submit depth 1/8/64 on the
    same bp1 connection — the depth-1 arm prices framing alone, the
    deep arms price what request pipelining buys on top.
    ``binary.stream.*`` compares per-timestep session stepping:
    one-frame-per-step bp1 vs JSON vs the pipelined ``step_many`` path
    (many timesteps per frame).
    """
    import numpy as np

    from repro.engine import AnomalyService
    from repro.gateway.client import GatewayClient
    from repro.gateway.server import GatewayServer

    arch, feats = "lstm-ae-f32-d2", 32
    n_req, t_len, max_batch, n_steps = 64, 32, 16, 128
    rng = np.random.default_rng(0)
    windows = rng.standard_normal((n_req, t_len, feats)).astype(np.float32)
    samples = rng.standard_normal((n_steps, feats)).astype(np.float32)
    svc = AnomalyService(arch, schedule="wavefront")
    rows = []

    # in-process floor: the gateway API called directly, no socket
    gw_local = svc.open_gateway(capacity=4, max_batch=max_batch,
                                max_wait_ms=2.0)
    gw_local.score(list(windows[:max_batch]))  # compile the bucket
    t0 = time.perf_counter()
    gw_local.score(list(windows))
    local_rps = n_req / (time.perf_counter() - t0)

    gw_wire = svc.open_gateway(capacity=4, max_batch=max_batch,
                               max_wait_ms=2.0)
    server = GatewayServer(gw_wire, port=0, pump_interval_ms=1.0)
    host, port = server.start_in_thread()
    try:
        with GatewayClient(host, port, protocol="json") as client:
            client.score_many(list(windows[:max_batch]))  # warm wire + pool
            t0 = time.perf_counter()
            client.score_many(list(windows))
            json_rps = n_req / (time.perf_counter() - t0)
            client.step(samples[0])
            t0 = time.perf_counter()
            for t in range(n_steps):
                client.step(samples[t])
            json_sps = n_steps / (time.perf_counter() - t0)
            client.end_session()

        with GatewayClient(host, port, protocol="binary") as client:
            client.score_many(list(windows[:max_batch]))
            depth_rps = {}
            for depth in (1, 8, 64):
                t0 = time.perf_counter()
                client.score_many(list(windows), windows_per_frame=depth)
                depth_rps[depth] = n_req / (time.perf_counter() - t0)
            bp1_rps = depth_rps[64]
            client.step(samples[0])
            t0 = time.perf_counter()
            for t in range(n_steps):
                client.step(samples[t])
            bp1_sps = n_steps / (time.perf_counter() - t0)
            t0 = time.perf_counter()
            client.step_many(samples)
            many_sps = n_steps / (time.perf_counter() - t0)
            client.end_session()
    finally:
        server.stop_in_thread()

    rows.append(
        f"binary.score.{arch},{1e6 / bp1_rps:.1f},"
        f"bp1_rps={bp1_rps:.0f};json_rps={json_rps:.0f};"
        f"local_rps={local_rps:.0f};"
        f"vs_json={bp1_rps / json_rps:.2f}x;"
        f"relative={bp1_rps / local_rps:.2f}x"
    )
    rows.append(
        f"binary.pipeline.{arch},{1e6 / depth_rps[64]:.1f},"
        f"d1_rps={depth_rps[1]:.0f};d8_rps={depth_rps[8]:.0f};"
        f"d64_rps={depth_rps[64]:.0f};"
        f"d64_vs_d1={depth_rps[64] / depth_rps[1]:.2f}x"
    )
    rows.append(
        f"binary.stream.{arch},{1e6 / bp1_sps:.1f},"
        f"bp1_sps={bp1_sps:.0f};json_sps={json_sps:.0f};"
        f"many_sps={many_sps:.0f};"
        f"vs_json={bp1_sps / json_sps:.2f}x;"
        f"many_vs_solo={many_sps / bp1_sps:.2f}x"
    )
    return rows


def _marker_subprocess(cmd: list, marker: str, env: dict,
                       timeout: float = 900.0) -> tuple:
    """Run one sweep subprocess and scan its stdout for the ``marker``
    line; returns ``(kv_dict, None)`` on success or ``(None, detail)``
    on failure — ``detail`` is stripped of commas/newlines so error rows
    survive the ``key,value,payload`` CSV format.  Shared by the
    sharding and workers sweeps so failure handling can't drift between
    them (partial results with an error row, never a truncated table)."""
    import subprocess

    try:
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             timeout=timeout)
        line = next(
            (l for l in out.stdout.splitlines() if l.startswith(marker)),
            None,
        )
        detail = (None if line is not None and out.returncode == 0
                  else out.stderr[-200:] if out.returncode
                  else f"no {marker.strip()} line")
    except subprocess.TimeoutExpired:
        line, detail = None, f"timeout after {timeout:.0f}s"
    if detail is not None:
        return None, detail.replace(",", ";").replace("\n", " ")
    return dict(part.split("=", 1) for part in line.split()[1:]), None


_SHARDING_SCRIPT = r"""
import os, sys, time
mesh = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={mesh}"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
from repro.engine import AnomalyService, EngineConfig, Placement

arch, feats = "lstm-ae-f32-d2", 32
spd, rounds, n_req, t_len, max_batch = 16, 32, 64, 32, 16
cap = spd * mesh
svc = AnomalyService(arch, schedule=EngineConfig(
    schedule="wavefront", placement=Placement.data(mesh)))
gw = svc.open_gateway(capacity=cap, max_batch=max_batch, max_wait_ms=1e9)
rng = np.random.default_rng(0)
xs = rng.standard_normal((rounds, cap, feats)).astype(np.float32)
for i in range(cap):
    gw.admit(i)
gw.step({i: xs[0, i] for i in range(cap)})  # compile the pooled step
t0 = time.perf_counter()
for r in range(rounds):
    gw.step({i: xs[r, i] for i in range(cap)})
sps = cap * rounds / (time.perf_counter() - t0)
windows = rng.standard_normal((n_req, t_len, feats)).astype(np.float32)
gw.score(list(windows[:max_batch]))  # compile the bucket
t0 = time.perf_counter()
gw.score(list(windows))
rps = n_req / (time.perf_counter() - t0)
s = gw.stats()
da = s["placement"]["device_active"] if mesh > 1 else [cap]
print(f"SHARDING mesh={mesh} capacity={cap} pooled_sps={sps:.0f} "
      f"score_rps={rps:.0f} "
      f"device_active={'/'.join(str(int(a)) for a in da)}")
"""


def gateway_sharding() -> list[str]:
    """Pooled gateway throughput vs data-mesh size 1/2/4 on forced host
    devices (``--json BENCH_sharding.json`` in CI).

    Each mesh size runs in its own subprocess (XLA device count is
    process-global) with a fixed 16 slots per device, so capacity scales
    with the mesh — the ISSUE-4 claim under test is that the sharded slot
    block serves ``slots_per_device x mesh_size`` streams through one
    compiled masked step.  On a single physical CPU the forced host
    devices share cores, so this table trends *correct scaling shape and
    regression*, not real multi-chip speedup.
    """
    import sys

    src = str(Path(__file__).resolve().parent.parent / "src")
    rows = []
    base_sps = None
    for mesh in (1, 2, 4):
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env.pop("JAX_PLATFORMS", None)
        kv, detail = _marker_subprocess(
            [sys.executable, "-c", _SHARDING_SCRIPT, str(mesh)],
            "SHARDING ", env,
        )
        if detail is not None:
            # same row key as the success path (trending consumers see the
            # row flip to an error state, not vanish)
            rows.append(
                f"sharding.lstm-ae-f32-d2.mesh{mesh},0.0,error={detail!r}"
            )
            continue
        sps = float(kv["pooled_sps"])
        if mesh == 1:
            base_sps = sps
        scaling = f";vs_mesh1={sps / base_sps:.2f}x" if base_sps else ""
        rows.append(
            f"sharding.lstm-ae-f32-d2.mesh{mesh},{1e6 / sps:.1f},"
            f"capacity={kv['capacity']};pooled_sps={kv['pooled_sps']};"
            f"score_rps={kv['score_rps']};device_active={kv['device_active']}"
            f"{scaling}"
        )
    return rows


def gateway_workers() -> list[str]:
    """One-shot score throughput through the multi-worker front
    (``repro.gateway.workers``) vs worker count 1/2/4 (``--json
    BENCH_workers.json`` in CI).

    Each count runs ``benchmarks/workers_bench.py`` in a subprocess (the
    spawn start method must re-import ``__main__`` for the factory
    pickles): a ``WorkerFront`` at N workers, 4 client processes driving
    pre-serialized score waves over fresh connections.  The claim under
    test is the ISSUE-5 one — the single asyncio loop, not the compiled
    step, is the throughput ceiling, and replicating the transport tier
    lifts it.  ``vs_w1`` only shows >1 when the box has spare cores
    (>=4); a subprocess failure reports an ``error=`` row under the same
    key instead of truncating the table.
    """
    import sys

    script = Path(__file__).resolve().parent / "workers_bench.py"
    src = str(Path(__file__).resolve().parent.parent / "src")
    rows = []
    base_rps = None
    for n in (1, 2, 4):
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        kv, detail = _marker_subprocess(
            [sys.executable, str(script), "--workers", str(n)],
            "WORKERS ", env,
        )
        if detail is not None:
            rows.append(
                f"workers.lstm-ae-f32-d2.w{n},0.0,error={detail!r}"
            )
            continue
        rps = float(kv["score_rps"])
        if n == 1:
            base_rps = rps
        scaling = f";vs_w1={rps / base_rps:.2f}x" if base_rps else ""
        rows.append(
            f"workers.lstm-ae-f32-d2.w{n},{1e6 / rps:.1f},"
            f"score_rps={kv['score_rps']};clients={kv['clients']};"
            f"requests={kv['requests']};clean={kv['clean']};"
            f"dropped={kv['dropped']}{scaling}"
        )
    return rows


def gateway_durability() -> list[str]:
    """The durability tax on the streaming hot loop, and resume latency
    (``--json BENCH_durability.json`` in CI).

    ``durability.stream.*`` — ``n`` resident sessions stepped round-robin
    the way the wire path steps them (one ``step`` per request), plain
    gateway vs the same gateway behind :class:`DurableSessions` at a
    200 ms snapshot interval — 5x the default cadence, so several async
    pool snapshots land inside the timed window while staying a
    configuration someone would actually serve at.  ``vs_plain`` is the
    gated claim — the seq bookkeeping + per-step HMAC token + off-loop
    snapshot copies must cost <=10% of pooled streaming throughput (the
    tax scales with cadence: the device->host block copy is the whole
    cost, so halving the interval doubles it).

    ``durability.resume.*`` — cold token resume on a SECOND gateway
    sharing the store: snapshot lookup from disk + slot restore + fresh
    token, averaged over every session (the SIGKILL-failover latency a
    reconnecting client pays before replay).
    """
    import tempfile

    import numpy as np

    from repro.engine import AnomalyService
    from repro.gateway.durability import enable_durability

    arch, feats = "lstm-ae-f32-d2", 32
    n, rounds = 16, 128
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((rounds, n, feats)).astype(np.float32)
    svc = AnomalyService(arch, schedule="wavefront")
    rows = []

    # Two gateways, SAME per-session traffic, measured in alternating
    # blocks (plain / durable / plain / ...) so slow drift in the box's
    # effective clock lands on both sides instead of on whichever path
    # happened to run second.
    gw = svc.open_gateway(capacity=n)
    ids = [f"p{i}" for i in range(n)]
    for sid in ids:
        gw.admit(sid)
    store = tempfile.mkdtemp(prefix="bench-durability-")
    gw_d = svc.open_gateway(capacity=n)
    dur = enable_durability(gw_d, store, shard="bench-0",
                            snapshot_interval_ms=200.0)
    sids, tokens = [], {}
    for _ in range(n):
        sid, tok = dur.admit()
        sids.append(sid)
        tokens[sid] = tok
    gw.step({ids[0]: xs[0, 0]})   # compile both pools' masked step
    dur.step(sids[0], xs[0, 0])
    plain_t = durable_t = 0.0
    block = 16
    for start in range(0, rounds, block):
        t0 = time.perf_counter()
        for r in range(start, start + block):
            for i, sid in enumerate(ids):
                gw.step({sid: xs[r, i]})
        plain_t += time.perf_counter() - t0
        t0 = time.perf_counter()
        for r in range(start, start + block):
            for i, sid in enumerate(sids):
                _, _, tokens[sid] = dur.step(sid, xs[r, i])
            dur.maybe_snapshot()  # what the server pump does between flushes
        durable_t += time.perf_counter() - t0
    plain_sps = n * rounds / plain_t
    durable_sps = n * rounds / durable_t
    d = dur.describe()
    rows.append(
        f"durability.stream.{arch}.pool{n},{1e6 / durable_sps:.1f},"
        f"durable_sps={durable_sps:.0f};plain_sps={plain_sps:.0f};"
        f"vs_plain={durable_sps / plain_sps:.2f}x;"
        f"snapshots={d['snapshots']};snapshot_bytes={d['snapshot_bytes']}"
    )

    # -- cold resume on a second gateway sharing the store -----------------
    dur.snapshot_now(wait=True)
    gw2 = svc.open_gateway(capacity=n)
    dur2 = enable_durability(gw2, store, shard="bench-1")
    dur2.resume(tokens[sids[0]])  # compile the slot-restore program
    lat = []
    for sid in sids[1:]:
        t0 = time.perf_counter()
        out = dur2.resume(tokens[sid])
        lat.append(time.perf_counter() - t0)
        assert out["seq"] == rounds
    mean_us = statistics.mean(lat) * 1e6
    rows.append(
        f"durability.resume.{arch},{mean_us:.1f},"
        f"resume_us={mean_us:.1f};p50_us={statistics.median(lat) * 1e6:.1f};"
        f"sessions={len(sids) - 1};from_disk=1"
    )
    return rows


def obs_overhead() -> list[str]:
    """The observability tax on both serving hot paths (``--json
    BENCH_obs.json`` in CI).

    Prices the plane AS SHIPPED: the ON arm runs ``obs_detail=True``
    (per-stage histograms at every instrumented site), a live JSONL
    event log, and traced spans at the documented 1-in-16 sampled
    cadence — spans are per-request opt-in, so tracing every request
    would price a workload the stack never runs.  The OFF arm runs
    ``obs_detail=False``, no spans, no log (the request-latency
    histogram stays on in both: it is the product surface, not
    overhead).

    Methodology: ONE gateway serves both arms (a two-gateway A/B on a
    one-core box showed ~4% identity bias between IDENTICAL gateways,
    swamping the real cost), rounds run in adjacent ON/OFF PAIRS with
    the within-pair order alternating, and ``vs_off`` is the MEDIAN of
    per-pair off/on time ratios — drift cancels inside each pair,
    position bias cancels across pairs, and the median rejects
    scheduler outliers.  An A/A placebo of this design reads 1.00
    +/- 0.01 where block-averaged designs read 0.92-1.07.  ``vs_off``
    is the gated claim: histogram-bucket arithmetic + sampled-span
    bookkeeping must cost <=5% on either path.
    """
    import statistics
    import tempfile

    import numpy as np

    from repro.engine import AnomalyService

    arch, feats = "lstm-ae-f32-d2", 32
    sample = 16  # trace every 16th round (the sampled-tracing cadence)
    svc = AnomalyService(arch, schedule="wavefront")
    rows = []
    log_path = Path(tempfile.mkdtemp(prefix="obs_bench_")) / "events.jsonl"

    # -- pooled streaming: wire-style one step per request -----------------
    # 3 independent sweeps of 48 pairs; the reported ratio is the median
    # of per-sweep medians, so one load spike degrades one sweep, not the
    # claim
    n, pairs, sweeps = 16, 48, 3
    rng = np.random.default_rng(0)
    xs = rng.standard_normal((pairs * 2, n, feats)).astype(np.float32)
    gw = svc.open_gateway(capacity=n, obs_detail=True)
    gw.attach_event_log(log_path)
    ids = [f"s{i}" for i in range(n)]
    for sid in ids:
        gw.admit(sid)
    gw.step({ids[0]: xs[0, 0]})  # compile the masked step

    def stream_round(r: int, on: bool, traced: bool) -> float:
        gw.telemetry.detail = on
        t0 = time.perf_counter()
        if traced:
            for i, sid in enumerate(ids):
                span = gw.tracer.start("step")
                gw.step({sid: xs[r, i]})
                span.mark("compute")
                gw.tracer.finish(span)
        else:
            for i, sid in enumerate(ids):
                gw.step({sid: xs[r, i]})
        return time.perf_counter() - t0

    sweep_ratios, on_times, off_times = [], [], []
    for s in range(sweeps):
        ratios = []
        for p in range(pairs):
            traced = p % sample == 0  # 1-in-16 ON rounds carry spans
            r = 2 * (s * pairs + p) % (pairs * 2)
            if p % 2 == 0:  # alternate within-pair order: ON / OFF first
                t_on = stream_round(r, True, traced)
                t_off = stream_round(r + 1, False, False)
            else:
                t_off = stream_round(r, False, False)
                t_on = stream_round(r + 1, True, traced)
            ratios.append(t_off / t_on)
            on_times.append(t_on)
            off_times.append(t_off)
        sweep_ratios.append(statistics.median(ratios))
    on_sps = n / statistics.median(on_times)
    off_sps = n / statistics.median(off_times)
    rows.append(
        f"obs.stream.{arch}.pool{n},{1e6 / on_sps:.1f},"
        f"on_sps={on_sps:.0f};off_sps={off_sps:.0f};"
        f"vs_off={statistics.median(sweep_ratios):.2f}x"
    )

    # -- micro-batch one-shot scoring --------------------------------------
    # one score call is ~50-70us, too small to pair cleanly against
    # timer + scheduler noise; each arm runs a GROUP of calls per pair
    b, score_pairs, group = 16, 24, 8
    windows = rng.standard_normal((b, 16, feats)).astype(np.float32)
    batch = list(windows)
    gw.score(batch)  # compile the score bucket

    def score_group(on: bool) -> float:
        gw.telemetry.detail = on
        t0 = time.perf_counter()
        for g in range(group):
            if on and g == 0:  # 1-in-`group` calls traced: ~the cadence
                span = gw.tracer.start("score")
                gw.score(batch)
                span.mark("compute")
                gw.tracer.finish(span)
            else:
                gw.score(batch)
        return time.perf_counter() - t0

    sweep_ratios, on_times, off_times = [], [], []
    for s in range(sweeps):
        ratios = []
        for p in range(score_pairs):
            if p % 2 == 0:
                t_on = score_group(True)
                t_off = score_group(False)
            else:
                t_off = score_group(False)
                t_on = score_group(True)
            ratios.append(t_off / t_on)
            on_times.append(t_on)
            off_times.append(t_off)
        sweep_ratios.append(statistics.median(ratios))
    on_rps = b * group / statistics.median(on_times)
    off_rps = b * group / statistics.median(off_times)
    rows.append(
        f"obs.score.{arch}.b{b},{1e6 / on_rps:.1f},"
        f"on_rps={on_rps:.0f};off_rps={off_rps:.0f};"
        f"vs_off={statistics.median(sweep_ratios):.2f}x"
    )
    gw.attach_event_log(None)
    return rows


def _scaledown_row() -> str:
    """One REAL 2->1-worker scale-down: a live :class:`WorkerFront`
    serves scores before and after ``scale_down()``; the drain summary
    must report zero dropped tickets (satellite-f accounting)."""
    import functools
    import socket

    import numpy as np

    if not hasattr(socket, "SO_REUSEPORT"):
        return "adaptive.scaledown.w2to1,0.0,error='no SO_REUSEPORT'"

    from repro.gateway.client import GatewayClient
    from repro.gateway.workers import WorkerFront, default_gateway_factory

    front = WorkerFront(
        functools.partial(default_gateway_factory, "lstm-ae-f32-d2",
                          "wavefront", capacity=8, max_batch=8,
                          max_wait_ms=2.0, warm_seq_len=16),
        n_workers=2, port=0,
    )
    try:
        host, port = front.start()
        rng = np.random.default_rng(0)
        windows = rng.standard_normal((16, 16, 32)).astype(np.float32)
        with GatewayClient(host, port) as client:
            client.score_many(list(windows))
        drain = front.scale_down()
        # the surviving worker keeps serving new connections
        with GatewayClient(host, port) as client:
            client.score_many(list(windows))
        workers_after = front.stats()["workers"]["count"]
    except Exception as e:
        detail = str(e).replace(",", ";").replace("\n", " ")[:160]
        return f"adaptive.scaledown.w2to1,0.0,error={detail!r}"
    finally:
        summary = front.shutdown()
    problems = []
    if drain["dropped_tickets"] != 0:
        problems.append(f"drain dropped {drain['dropped_tickets']} tickets")
    if not drain["clean"]:
        problems.append("drain was not clean")
    if workers_after != 1:
        problems.append(f"fleet at {workers_after} workers after drain")
    if summary["dropped_tickets"] != 0:
        problems.append(f"shutdown dropped {summary['dropped_tickets']}")
    if problems:
        detail = "; ".join(problems).replace(",", ";")
        return f"adaptive.scaledown.w2to1,0.0,error={detail!r}"
    return (
        f"adaptive.scaledown.w2to1,0.0,"
        f"dropped=0;clean=1;migrated={drain['sessions_migrated']};"
        f"lost={drain['sessions_lost']};workers_after={workers_after};"
        f"shutdown_clean={summary['clean_exits']}"
    )


def gateway_adaptive() -> list[str]:
    """The control plane vs static serving on seeded traces (``--json
    BENCH_adaptive.json`` in CI).

    All ``adaptive.bursty.*`` / ``adaptive.diurnal.*`` /
    ``adaptive.priority.*`` rows come from the virtual-clock simulator
    (``benchmarks/traces.py``) running the REAL ``repro.control``
    controllers: time is simulated, so every number is bit-identical
    across runs and machines and the gate trends behaviour, not the CI
    box.  Capacity is scaled (one worker = 400 req/s at full fill) so a
    60 s trace holds ~5e4 events; the controller's whole world is the
    slo/floor ratio and utilization, both preserved (service = 1.2x
    floor, SLO = 5x floor — the shape ``serving_floor_ms`` feeds the
    live plane).

    Acceptance claims, asserted in-table (violations become ``error=``
    rows, which ``check.py`` fails):

    * bursty: adaptive (batching + autoscale 2:5) meets the p95 SLO and
      beats the BEST static arm's goodput by >=1.2x at comparable mean
      provisioning (static arms run the 2-worker fleet you'd provision
      for the mean; ``worker_s`` reports what adaptive actually used).
    * priority: under a priority-2 tenant flood, class 0 sheds NOTHING
      while class 2 absorbs all shedding; a per-tenant token bucket
      moves the shedding to ``rate_limited`` without touching the
      background tenants.
    * scaledown: a real 2->1 ``WorkerFront`` drain drops zero tickets.
    """
    import sys

    sys.path.insert(0, str(Path(__file__).resolve().parent))
    import traces

    from repro.control import Autoscaler, BatchingController

    lanes, unit = 16, 400.0
    service = lanes * 1e3 / unit          # ms per flush (scaled time)
    floor = service / 1.2                 # feedforward floor the plane sees
    slo = 5.0 * floor
    max_queue = 64
    sim = dict(lanes=lanes, service_ms=service, slo_ms=slo,
               max_queue=max_queue)

    def controllers():
        return (
            BatchingController(slo_p95_ms=slo, floor_ms=floor, lanes=lanes,
                               min_wait_ms=0.05 * floor, patience=1,
                               cooldown_ticks=1),
            Autoscaler(min_workers=2, max_workers=5, worker_rps=0.8 * unit,
                       patience=1, cooldown_ticks=1),
        )

    rows = []

    # -- bursty: SLO compliance + goodput vs the best static arm -----------
    bursty = traces.make_trace("bursty", unit_rps=unit, seed=0,
                               duration_s=60.0)
    statics = {}
    for arm, mb, wait in (("tight", 16, 0.25 * floor),
                          ("eager", 4, 0.25 * floor),
                          ("patient", 16, 3.0 * floor)):
        r = traces.simulate(bursty, workers=2, max_batch=mb,
                            max_wait_ms=wait, **sim)
        statics[arm] = r
        rows.append(
            f"adaptive.bursty.static_{arm},{1e6 / max(r['goodput_rps'], 1e-9):.1f},"
            f"goodput_rps={r['goodput_rps']:.1f};p95_ms={r['p95_ms']:.2f};"
            f"slo_ms={slo:.2f};shed={r['shed']};fill={r['mean_fill']:.2f};"
            f"worker_s={r['worker_s']:.0f}"
        )
    bat, aut = controllers()
    a = traces.simulate(bursty, workers=2, max_batch=16,
                        max_wait_ms=0.25 * floor, batching=bat,
                        autoscaler=aut, tick_s=0.5, spawn_delay_s=1.0, **sim)
    best = max(r["goodput_rps"] for r in statics.values())
    ratio = a["goodput_rps"] / best
    problems = []
    if a["p95_ms"] > slo:
        problems.append(f"p95 {a['p95_ms']:.2f}ms over SLO {slo:.2f}ms")
    if ratio < 1.2:
        problems.append(f"goodput only {ratio:.2f}x best static (< 1.2x)")
    if problems:
        detail = "; ".join(problems).replace(",", ";")
        rows.append(f"adaptive.bursty.adaptive,0.0,error={detail!r}")
    else:
        rows.append(
            f"adaptive.bursty.adaptive,{1e6 / a['goodput_rps']:.1f},"
            f"goodput_rps={a['goodput_rps']:.1f};vs_best_static={ratio:.2f}x;"
            f"p95_ms={a['p95_ms']:.2f};slo_ms={slo:.2f};met_slo=1;"
            f"shed={a['shed']};worker_s={a['worker_s']:.0f};"
            f"scale_ups={a['scale_ups']};scale_downs={a['scale_downs']};"
            f"knob_actions={a['batching_actions']}"
        )

    # -- diurnal: slow swing — adaptive sheds nothing, static sheds peaks --
    diurnal = traces.make_trace("diurnal", unit_rps=unit, seed=2,
                                duration_s=60.0)
    s = traces.simulate(diurnal, workers=2, max_batch=16,
                        max_wait_ms=0.25 * floor, **sim)
    bat, aut = controllers()
    d = traces.simulate(diurnal, workers=2, max_batch=16,
                        max_wait_ms=0.25 * floor, batching=bat,
                        autoscaler=aut, tick_s=0.5, spawn_delay_s=1.0, **sim)
    rows.append(
        f"adaptive.diurnal.static,{1e6 / max(s['goodput_rps'], 1e-9):.1f},"
        f"goodput_rps={s['goodput_rps']:.1f};p95_ms={s['p95_ms']:.2f};"
        f"shed={s['shed']}"
    )
    rows.append(
        f"adaptive.diurnal.adaptive,{1e6 / d['goodput_rps']:.1f},"
        f"goodput_rps={d['goodput_rps']:.1f};vs_static="
        f"{d['goodput_rps'] / s['goodput_rps']:.2f}x;p95_ms={d['p95_ms']:.2f};"
        f"shed={d['shed']};worker_s={d['worker_s']:.0f}"
    )

    # -- adversarial: shed fairness under a priority-2 tenant flood --------
    adv = traces.make_trace("adversarial", unit_rps=unit, seed=1,
                            duration_s=30.0)
    p = traces.simulate(adv, workers=2, max_batch=16,
                        max_wait_ms=0.25 * floor, classes=3, **sim)
    shed = p["shed_by_class"]
    if shed["0"] != 0 or shed["2"] <= 0:
        detail = f"shed_p0={shed['0']} shed_p2={shed['2']}"
        rows.append(f"adaptive.priority.classes3,0.0,error={detail!r}")
    else:
        rows.append(
            f"adaptive.priority.classes3,{1e6 / p['goodput_rps']:.1f},"
            f"goodput_rps={p['goodput_rps']:.1f};shed_p0={shed['0']};"
            f"shed_p1={shed['1']};shed_p2={shed['2']};"
            f"p95_ms={p['p95_ms']:.2f}"
        )
    t = traces.simulate(adv, workers=2, max_batch=16,
                        max_wait_ms=0.25 * floor, classes=3,
                        tenant_rate=0.5 * unit, **sim)
    rows.append(
        f"adaptive.priority.tenant_bucket,{1e6 / max(t['goodput_rps'], 1e-9):.1f},"
        f"goodput_rps={t['goodput_rps']:.1f};rate_limited={t['rate_limited']};"
        f"shed_p2={t['shed_by_class']['2']};shed_p0={t['shed_by_class']['0']}"
    )

    # -- one real drain-based scale-down -----------------------------------
    rows.append(_scaledown_row())
    return rows


def roofline_cells(dryrun_dir: str = "experiments/dryrun") -> list[str]:
    rows = []
    d = Path(dryrun_dir)
    if not d.exists():
        return ["roofline.missing,0.0,run `python -m repro.launch.dryrun` first"]
    for f in sorted(d.glob("*__single_pod_16x16.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        frac = r["compute_s"] / total if total else 0.0
        rows.append(
            f"roofline.{r['arch']}.{r['shape']},0.0,"
            f"dominant={r['dominant']};compute_s={r['compute_s']:.3g};"
            f"memory_s={r['memory_s']:.3g};collective_s={r['collective_s']:.3g};"
            f"compute_frac={frac:.3f};flops_ratio={r['flops_ratio']:.3f}"
        )
    return rows


_TABLES = {
    "table1_resources": table1_resources,
    "table2_latency": table2_latency,
    "table3_energy": table3_energy,
    "schedule_compare": schedule_compare,
    "engine_throughput": engine_throughput,
    "gateway_throughput": gateway_throughput,
    "gateway_transport": gateway_transport,
    "gateway_binary": gateway_binary,
    "gateway_sharding": gateway_sharding,
    "gateway_workers": gateway_workers,
    "gateway_durability": gateway_durability,
    "gateway_adaptive": gateway_adaptive,
    "obs_overhead": obs_overhead,
    "roofline_cells": roofline_cells,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", nargs="*", choices=sorted(_TABLES),
                    help="subset of tables to run (default: all)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON (e.g. BENCH_gateway.json)")
    args = ap.parse_args()

    names = args.tables or list(_TABLES)
    print("name,us_per_call,derived")
    all_rows: list[str] = []
    for name in names:
        for row in _TABLES[name]():
            print(row, flush=True)
            all_rows.append(row)

    if args.json:
        records = []
        for row in all_rows:
            name, us, derived = row.split(",", 2)
            rec = {"name": name, "us_per_call": float(us), "derived": derived}
            if derived.startswith("error="):
                # subprocess sweeps degrade to partial results; surface
                # the failure as a first-class field so trending/gating
                # consumers need not parse the payload to notice
                rec["error"] = derived[len("error="):]
            records.append(rec)
        # atomic write: a killed/crashed run must never leave a truncated
        # BENCH_*.json behind for the CI upload step to publish
        target = Path(args.json)
        tmp = target.with_name(target.name + ".tmp")
        tmp.write_text(json.dumps(records, indent=2) + "\n")
        os.replace(tmp, target)
        print(f"# wrote {len(records)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()

"""Seeded arrival traces + a virtual-clock serving simulator for the
control-plane benchmark (``gateway_adaptive`` in ``benchmarks/run.py``).

The adaptive-vs-static comparison needs load shapes a wall-clock
micro-benchmark can't reproduce deterministically (bursts, diurnal
swings, an adversarial tenant flood), so this module separates the two
halves the same way the control plane itself does:

* **Traces** — :func:`make_trace` draws arrival offsets, priority
  classes and tenant ids from ``np.random.default_rng(seed)``, so every
  run of a ``(kind, seed)`` pair replays the identical workload on any
  machine.  Rates are expressed relative to ``unit_rps`` (one worker's
  full-fill capacity), so the shapes stay meaningful when the latency
  model recalibrates.
* **Simulator** — :func:`simulate` is a discrete-event loop over a
  virtual clock: admitted requests queue, idle workers flush up to
  ``max_batch`` rows when the batch fills or the oldest request has
  waited ``max_wait_ms``, and every flush occupies its worker for the
  model-derived ``service_ms`` (the compiled step is padded to the full
  lane count, so flush cost is row-independent — exactly the
  ``MicroBatcher`` contract).  The REAL controllers from
  ``repro.control`` run against it unmodified: the admission controller
  gates arrivals (virtual clock injected), the batching controller and
  autoscaler tick on windowed sensors, and scale-down retires a worker
  only after its in-flight flush completes (the zero-drop drain,
  modeled).  No wall-clock time is read anywhere, so results are
  bit-identical across runs and machines — the committed
  ``BENCH_adaptive.json`` baseline gates real behaviour changes, not
  scheduler noise.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

_INTERVAL_S = 0.1  # rate-profile resolution for arrival generation


def _draw_arrivals(rng, rate_fn, duration_s: float) -> np.ndarray:
    """Piecewise-Poisson arrival offsets for a time-varying rate."""
    times = []
    t = 0.0
    while t < duration_s:
        rate = max(0.0, float(rate_fn(t)))
        n = rng.poisson(rate * _INTERVAL_S)
        if n:
            times.append(t + rng.uniform(0.0, _INTERVAL_S, n))
        t += _INTERVAL_S
    if not times:
        return np.empty(0, np.float64)
    return np.sort(np.concatenate(times))


def make_trace(kind: str, *, unit_rps: float, seed: int = 0,
               duration_s: float = 60.0, classes: int = 3) -> dict:
    """One named workload: ``{"t", "klass", "tenant", "kind",
    "duration_s"}`` arrays sorted by arrival time.

    ``bursty``      — base load of 1.0 unit with 4.0-unit bursts for 6 s
                      of every 24 s period (the SLO-compliance arm).
    ``diurnal``     — sinusoidal 0.3..2.1 units over a 30 s period.
    ``adversarial`` — steady 0.8 units of priority-0/1 traffic from four
                      tenants plus a 3.0-unit priority-2 flood from one
                      tenant ("mallory") — the shed-fairness arm.
    """
    rng = np.random.default_rng(seed)
    u = float(unit_rps)
    if kind == "bursty":
        def rate(t):
            return 4.0 * u if (t % 24.0) < 6.0 else 1.0 * u
        t = _draw_arrivals(rng, rate, duration_s)
        klass = rng.choice(classes, size=t.size, p=_class_weights(classes))
        tenant = np.array([f"t{i}" for i in rng.integers(0, 4, t.size)])
    elif kind == "diurnal":
        def rate(t):
            return u * (1.2 + 0.9 * np.sin(2.0 * np.pi * t / 30.0))
        t = _draw_arrivals(rng, rate, duration_s)
        klass = rng.choice(classes, size=t.size, p=_class_weights(classes))
        tenant = np.array([f"t{i}" for i in rng.integers(0, 4, t.size)])
    elif kind == "adversarial":
        tb = _draw_arrivals(rng, lambda t: 0.8 * u, duration_s)
        kb = rng.choice([0, 1], size=tb.size, p=[0.6, 0.4])
        nb = np.array([f"t{i}" for i in rng.integers(0, 4, tb.size)])
        tf = _draw_arrivals(rng, lambda t: 3.0 * u, duration_s)
        kf = np.full(tf.size, classes - 1)
        nf = np.full(tf.size, "mallory")
        order = np.argsort(np.concatenate([tb, tf]), kind="stable")
        t = np.concatenate([tb, tf])[order]
        klass = np.concatenate([kb, kf])[order]
        tenant = np.concatenate([nb, nf])[order]
    else:
        raise ValueError(f"unknown trace kind {kind!r}")
    return {"kind": kind, "duration_s": float(duration_s), "t": t,
            "klass": klass.astype(np.int64), "tenant": tenant}


def _class_weights(classes: int) -> list:
    if classes == 1:
        return [1.0]
    # a small high-priority head over a best-effort tail
    w = [0.2] + [0.8 / (classes - 1)] * (classes - 1)
    return [x / sum(w) for x in w]


class _VirtualClock:
    __slots__ = ("now",)

    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now


def simulate(
    trace: dict,
    *,
    lanes: int,
    service_ms: float,
    slo_ms: float,
    workers: int = 1,
    max_batch: Optional[int] = None,
    max_wait_ms: float = 0.25,
    max_queue: int = 64,
    classes: int = 1,
    tenant_rate: Optional[float] = None,
    batching=None,
    autoscaler=None,
    tick_s: float = 0.5,
    spawn_delay_s: float = 1.0,
) -> dict:
    """Run one arm over a trace; returns the scoreboard.

    ``batching`` / ``autoscaler`` are pre-built ``repro.control``
    controllers (None = static knobs / fixed fleet).  Admission always
    runs — ``classes=1`` is exactly the flat gateway limit.  Goodput
    counts completions within ``slo_ms`` that finish inside the trace
    window; ``worker_s`` integrates the fleet size over time, so
    efficiency (goodput per worker-second) is comparable across arms.
    """
    from repro.control import AdmissionController

    clock = _VirtualClock()
    admission = AdmissionController(classes=classes, tenant_rate=tenant_rate,
                                    clock=clock)
    arr_t = trace["t"]
    arr_k = trace["klass"]
    arr_n = trace["tenant"]
    duration = trace["duration_s"]
    mb = int(max_batch if max_batch is not None else lanes)
    mb = min(max(1, mb), lanes)
    wait_ms = float(max_wait_ms)
    svc_s = service_ms / 1e3

    queue: list = []          # (arrival_t, klass) FIFO
    busy: list = [0.0] * int(workers)  # per-worker busy-until (<= t -> idle)
    retiring = 0              # scale-downs pending a drained worker
    lat_done: list = []       # (completion_t, latency_ms, klass)
    tick_lat: list = []       # latencies completing since the last tick
    n_shed = n_admitted = 0
    flushes = rows_flushed = 0
    scale_ups = scale_downs = 0
    worker_s = 0.0
    last_t = 0.0
    next_tick = tick_s
    i = 0
    n = arr_t.size
    INF = float("inf")

    def dispatch(t: float) -> None:
        nonlocal flushes, rows_flushed
        for w in range(len(busy)):
            if busy[w] > t or not queue:
                continue
            full = len(queue) >= mb
            aged = (t - queue[0][0]) * 1e3 >= wait_ms
            if not (full or aged):
                continue
            take = queue[:mb]
            del queue[:mb]
            done = t + svc_s
            busy[w] = done
            flushes += 1
            rows_flushed += len(take)
            for (a, k) in take:
                lat = (done - a) * 1e3
                lat_done.append((done, lat, k))
                tick_lat.append((done, lat))

    seen_prev = 0  # arrivals observed up to the previous tick
    while True:
        # next event: arrival, wait-deadline flush, worker free, tick.
        # Only FUTURE deadlines count — an already-aged queue head is
        # waiting on a worker, whose completion is the real next event.
        now = clock.now
        candidates = [next_tick]
        if i < n:
            candidates.append(arr_t[i])
        if queue:
            deadline = queue[0][0] + wait_ms / 1e3
            if deadline > now:
                candidates.append(deadline)
        pending = [b for b in busy if b > now and b != INF]
        if pending:
            candidates.append(min(pending))
        t = min(candidates)
        if t > duration and i >= n and not queue:
            break
        t = min(t, duration + 10.0 * svc_s)  # bounded drain after the window
        worker_s += (t - last_t) * sum(1 for b in busy if b != INF)
        last_t = t
        clock.now = t

        while i < n and arr_t[i] <= t:
            try:
                admission.admit(depth=len(queue), max_queue=max_queue,
                                priority=int(arr_k[i]), tenant=str(arr_n[i]))
                queue.append((float(arr_t[i]), int(arr_k[i])))
                n_admitted += 1
            except Exception:
                n_shed += 1
            i += 1
        # zero-drop drain: an idle worker leaves instead of taking more
        # work (its in-flight flush, if any, already completed)
        while retiring > 0 and sum(1 for b in busy if b != INF) > 1:
            idle = next((w for w in range(len(busy))
                         if busy[w] <= t and busy[w] != INF), None)
            if idle is None:
                break
            busy[idle] = INF
            retiring -= 1
        while INF in busy:
            busy.remove(INF)
            scale_downs += 1
        dispatch(t)

        if t >= next_tick:
            next_tick += tick_s
            done_now = [l for (c, l) in tick_lat if c <= t]
            tick_lat = [(c, l) for (c, l) in tick_lat if c > t]
            p95 = float(np.percentile(done_now, 95)) if done_now else 0.0
            fill = (rows_flushed / (flushes * mb)) if flushes else 0.0
            seen = n_admitted + n_shed
            arrival_rps = (seen - seen_prev) / tick_s  # last-tick window
            seen_prev = seen
            if batching is not None:
                d = batching.decide(p95_ms=p95, fill=fill, depth=len(queue),
                                    arrival_rps=arrival_rps,
                                    max_batch=mb, max_wait_ms=wait_ms)
                if d["knobs"]:
                    mb = min(max(1, int(d["knobs"].get("max_batch", mb))),
                             lanes)
                    wait_ms = max(0.0,
                                  float(d["knobs"].get("max_wait_ms",
                                                       wait_ms)))
            if autoscaler is not None and t <= duration:
                live = len(busy) - retiring
                a = autoscaler.decide(arrival_rps=arrival_rps,
                                      workers=max(1, live),
                                      queue_depth=len(queue),
                                      max_queue=max_queue)
                if a["delta"] > 0:
                    # model compile warm-up: the new worker joins late
                    busy.append(t + spawn_delay_s)
                    scale_ups += 1
                elif a["delta"] < 0 and live > 1:
                    retiring += 1
            dispatch(t)

    lats = np.array([l for (c, l, k) in lat_done]) if lat_done else \
        np.empty(0)
    in_window = [(c, l, k) for (c, l, k) in lat_done if c <= duration]
    good = sum(1 for (c, l, k) in in_window if l <= slo_ms)
    shed_by_class = admission.describe()["shed_by_class"]
    return {
        "arrivals": int(n),
        "admitted": int(n_admitted),
        "shed": int(n_shed),
        "completed": len(lat_done),
        "good": int(good),
        "goodput_rps": good / duration,
        "p95_ms": float(np.percentile(lats, 95)) if lats.size else 0.0,
        "mean_fill": (rows_flushed / (flushes * lanes)) if flushes else 0.0,
        "flushes": int(flushes),
        "worker_s": worker_s,
        "scale_ups": int(scale_ups),
        "scale_downs": int(scale_downs),
        "shed_by_class": {k: int(v) for k, v in shed_by_class.items()},
        "rate_limited": int(admission.describe()["rate_limited"]),
        "final_max_batch": mb,
        "final_max_wait_ms": wait_ms,
        "batching_actions": batching.actions if batching is not None else 0,
    }

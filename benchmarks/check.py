"""Benchmark regression gate: compare BENCH_*.json tables against
committed baselines and fail CI on hot-path regressions.

The BENCH_*.json tables trend the serving stack (gateway, transport,
the bp1 binary protocol, sharding, workers, durability, control plane,
observability); until this gate they were produced on every CI run and never
compared, so a regression in the pooled step, the wire path, the sharded
flush or the worker tier could land silently.  This script reads each
current table, pairs it with ``benchmarks/baselines/<same name>``, and
compares every *directional* metric:

* higher-is-better — keys ending in ``_rps`` / ``_sps``, plus
  ``speedup`` / ``relative`` / ``vs_*`` ratios (trailing ``x`` stripped):
  FAIL when ``current < baseline - tol * max(|baseline|, 1)``
* lower-is-better — the ``us_per_call`` column and keys ending in
  ``_us``: FAIL when ``current > baseline + tol * max(|baseline|, 1)``

Everything else in the payload (capacities, fills, device vectors,
counts) is informational and not gated.  A row carrying an ``error``
field in the CURRENT table fails outright; an error row in the BASELINE
is skipped (the baseline itself was bad — re-baseline).  A row present
in the baseline but missing from the current table fails; a new current
row in a GATED table (one with a committed baseline) also fails until a
baseline entry exists — run with ``--update`` to admit it, so new rows
can never ride ungated through a table CI already trusts.

Usage::

    python benchmarks/check.py BENCH_gateway.json BENCH_workers.json \
        [--baseline-dir benchmarks/baselines] [--tol 0.30]

Tolerance is fractional (default ±30%); CI passes a looser value because
hosted runners vary machine-to-machine — see .github/workflows/ci.yml.

Re-baselining (after an intentional perf change, on a quiet machine)::

    PYTHONPATH=src python benchmarks/run.py --tables gateway_throughput \
        --json BENCH_gateway.json     # ... and the other three tables
    python benchmarks/check.py BENCH_*.json --update

``--update`` copies the current tables over the baselines instead of
comparing; commit the result with a note on what moved and why.
"""
from __future__ import annotations

import argparse
import json
import re
import shutil
import sys
from pathlib import Path

_HIGHER_RE = re.compile(r"(_rps|_sps)$")
_LOWER_RE = re.compile(r"_us$")


def _parse_derived(derived: str) -> dict[str, float]:
    """``k1=v1;k2=v2`` -> numeric fields (trailing ``x`` ratios included;
    non-numeric payload entries are dropped)."""
    out: dict[str, float] = {}
    for part in derived.split(";"):
        key, sep, val = part.partition("=")
        if not sep:
            continue
        val = val.strip()
        if val.endswith("x"):
            val = val[:-1]
        try:
            out[key.strip()] = float(val)
        except ValueError:
            continue
    return out


def _direction(key: str) -> int:
    """+1 higher-is-better, -1 lower-is-better, 0 not gated."""
    if _HIGHER_RE.search(key) or key in ("speedup", "relative") \
            or key.startswith("vs_"):
        return +1
    if _LOWER_RE.search(key) or key == "us_per_call":
        return -1
    return 0


def _load(path: Path) -> dict[str, dict]:
    rows = json.loads(path.read_text())
    return {r["name"]: r for r in rows}


def _metrics(row: dict) -> dict[str, float]:
    out = {"us_per_call": float(row.get("us_per_call", 0.0))}
    out.update(_parse_derived(row.get("derived", "")))
    return out


def check_file(current_path: Path, baseline_path: Path, tol: float) -> list:
    """Compare one table; returns the printed comparison lines as
    ``(status, line)`` tuples where status is PASS/FAIL/NOTE."""
    current = _load(current_path)
    baseline = _load(baseline_path)
    lines: list[tuple[str, str]] = []
    for name, base_row in baseline.items():
        if "error" in base_row or base_row.get(
                "derived", "").startswith("error="):
            lines.append(("NOTE", f"{name}: baseline is an error row; "
                          f"skipped (re-baseline)"))
            continue
        cur_row = current.get(name)
        if cur_row is None:
            lines.append(("FAIL", f"{name}: row missing from "
                          f"{current_path.name}"))
            continue
        if "error" in cur_row or cur_row.get(
                "derived", "").startswith("error="):
            lines.append(("FAIL", f"{name}: current run errored: "
                          f"{cur_row.get('error', cur_row.get('derived'))}"))
            continue
        base_m, cur_m = _metrics(base_row), _metrics(cur_row)
        for key, base_val in sorted(base_m.items()):
            direction = _direction(key)
            if direction == 0:
                continue
            if key not in cur_m:
                # a gated key that vanished (renamed metric, partial
                # payload) must not silently disable its gate
                lines.append(("FAIL", f"{name} {key}: gated key missing "
                              f"from current row (renamed? re-baseline)"))
                continue
            cur_val = cur_m[key]
            slack = tol * max(abs(base_val), 1.0)
            regressed = (cur_val < base_val - slack if direction > 0
                         else cur_val > base_val + slack)
            delta = ((cur_val - base_val) / abs(base_val) * 100.0
                     if base_val else float("inf"))
            arrow = "^" if direction > 0 else "v"
            lines.append((
                "FAIL" if regressed else "PASS",
                f"{name} {key}[{arrow}]: baseline={base_val:.4g} "
                f"current={cur_val:.4g} ({delta:+.1f}%, tol ±{tol:.0%})",
            ))
    for name in current:
        if name not in baseline:
            # this table IS gated (a baseline exists for it) — a brand-new
            # row must not slip through ungated; --update admits it
            lines.append(("FAIL", f"{name}: new row in a gated table has "
                          f"no baseline entry; re-baseline with --update "
                          f"to admit it"))
    return lines


def main() -> int:
    ap = argparse.ArgumentParser(
        description="gate BENCH_*.json tables against committed baselines")
    ap.add_argument("tables", nargs="+", metavar="BENCH_*.json",
                    help="current benchmark tables to check")
    ap.add_argument("--baseline-dir",
                    default=str(Path(__file__).resolve().parent / "baselines"),
                    help="directory of committed baseline tables")
    ap.add_argument("--tol", type=float, default=0.30,
                    help="fractional tolerance on gated keys (default 0.30)")
    ap.add_argument("--update", action="store_true",
                    help="copy the current tables over the baselines "
                         "instead of comparing (re-baseline)")
    args = ap.parse_args()

    baseline_dir = Path(args.baseline_dir)
    if args.update:
        baseline_dir.mkdir(parents=True, exist_ok=True)
        for t in args.tables:
            src = Path(t)
            shutil.copyfile(src, baseline_dir / src.name)
            print(f"re-baselined {baseline_dir / src.name}")
        return 0

    failures = 0
    for t in args.tables:
        current_path = Path(t)
        baseline_path = baseline_dir / current_path.name
        print(f"== {current_path.name} vs {baseline_path} ==")
        if not current_path.exists():
            print(f"  FAIL  current table {current_path} missing "
                  f"(benchmark step did not produce it)")
            failures += 1
            continue
        if not baseline_path.exists():
            print(f"  NOTE  no baseline committed for {current_path.name}; "
                  f"run with --update to create one")
            continue
        for status, line in check_file(current_path, baseline_path, args.tol):
            print(f"  {status:4s}  {line}")
            if status == "FAIL":
                failures += 1
    if failures:
        print(f"\n{failures} benchmark regression(s) beyond tolerance — "
              f"if intentional, re-baseline (see benchmarks/check.py "
              f"docstring)")
        return 1
    print("\nbenchmark gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

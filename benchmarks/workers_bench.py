"""One multi-worker-front throughput measurement (the ``gateway_workers``
table's inner harness).

Boots a :class:`repro.gateway.workers.WorkerFront` at ``--workers N``,
drives it with ``--clients`` concurrent load-generator PROCESSES (the
load they generate is pre-serialized bp1 binary frames — preamble plus
one pipelined SCORE frame per window — pumped over raw sockets, so
client-side CPU never caps the measurement — the thing under test is
the worker tier), and prints one machine-readable line::

    WORKERS n=2 score_rps=1234 clients=4 requests=768 wall_s=0.62 \
clean=2/2 dropped=0

``benchmarks/run.py gateway_workers`` invokes this script once per
worker count.  It is a standalone file rather than a ``python -c``
string because the ``spawn`` start method must be able to re-import
``__main__`` to unpickle the worker factory and client drivers.
"""
from __future__ import annotations

import argparse
import functools
import multiprocessing as mp
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

ARCH, FEATS, T_LEN = "lstm-ae-f32-d2", 32, 16


def drive(host: str, port: int, waves: int, wave_size: int, seed: int,
          out_q) -> None:
    """One load-generator process: submit ``wave_size`` one-shot scores
    per wave (pre-serialized once), read the responses, repeat.

    Each wave runs on a FRESH connection: the kernel balances
    ``SO_REUSEPORT`` listeners by hashing the connection 4-tuple, and a
    handful of long-lived localhost connections hash badly enough to pile
    onto one worker — reconnecting per wave (cheap on loopback) gives the
    hash many draws, so load evens out across workers the way a real
    many-client population would."""
    import socket

    import numpy as np

    from repro.gateway import wire

    rng = np.random.default_rng(seed)
    windows = (rng.standard_normal((wave_size, T_LEN, FEATS)) * 0.1)
    # the whole wave as one pre-serialized byte string: negotiation
    # preamble, then wave_size pipelined SCORE frames (raw float32, one
    # window per frame) — the server answers them in submission order
    payload = wire.PREAMBLE + b"".join(
        wire.pack_frame(wire.OP_SCORE, i,
                        meta={"n": 1, "t": T_LEN, "f": FEATS},
                        data=np.ascontiguousarray(w, "<f4").tobytes())
        for i, w in enumerate(windows)
    )

    def read_frame(rfile):
        header = rfile.read(wire.HEADER_SIZE)
        if len(header) < wire.HEADER_SIZE:
            raise ConnectionError("server closed mid-wave")
        _, flags, _, plen = wire.unpack_header(header)
        body = rfile.read(plen) if plen else b""
        if len(body) < plen:
            raise ConnectionError("server closed mid-frame")
        if flags & wire.FLAG_ERROR:
            meta, _ = wire.split_payload(body)
            raise RuntimeError(f"score failed: {meta}")

    def one_wave() -> None:
        sock = socket.create_connection((host, port), timeout=120)
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            rfile = sock.makefile("rb")
            sock.sendall(payload)
            read_frame(rfile)  # the server's HELLO greeting
            for _ in range(wave_size):
                read_frame(rfile)
        finally:
            sock.close()

    one_wave()  # warm this client's path end to end
    t0 = time.perf_counter()
    for _ in range(waves):
        one_wave()
    dt = time.perf_counter() - t0
    out_q.put((waves * wave_size, dt))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, required=True)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--waves", type=int, default=16)
    ap.add_argument("--wave-size", type=int, default=16)
    args = ap.parse_args()

    from repro.gateway.workers import WorkerFront, default_gateway_factory

    # warm_seq_len pre-compiles the score bucket in every worker before
    # ready, so kernel connection balancing cannot land measurement
    # traffic on a cold engine
    factory = functools.partial(
        default_gateway_factory, ARCH, "wavefront",
        capacity=8, max_batch=args.wave_size, max_wait_ms=2.0,
        max_queue=4096, warm_seq_len=T_LEN,
    )
    # one XLA thread per worker: the point of the table is transport-tier
    # scaling, and letting each worker's XLA fan a tiny flush out over
    # every core oversubscribes the box as the worker count grows
    env = {"XLA_FLAGS": "--xla_cpu_multi_thread_eigen=false "
                        "intra_op_parallelism_threads=1"}
    front = WorkerFront(factory, n_workers=args.workers, env=env)
    host, port = front.start()
    ctx = mp.get_context("spawn")
    out_q = ctx.Queue()
    procs = [
        ctx.Process(target=drive,
                    args=(host, port, args.waves, args.wave_size,
                          100 + i, out_q))
        for i in range(args.clients)
    ]
    for p in procs:
        p.start()
    results = [out_q.get(timeout=600) for _ in procs]
    for p in procs:
        p.join(30)
    summary = front.shutdown()
    total = sum(n for n, _ in results)
    wall = max(dt for _, dt in results)
    print(f"WORKERS n={args.workers} score_rps={total / wall:.0f} "
          f"clients={args.clients} requests={total} wall_s={wall:.2f} "
          f"clean={summary['clean_exits']}/{summary['workers']} "
          f"dropped={summary['dropped_tickets']}", flush=True)


if __name__ == "__main__":
    main()

"""Quickstart: the paper's full pipeline in ~2 minutes on CPU.

1. Train an LSTM-AE (the paper's F32-D2 model) on benign synthetic
   multivariate time-series.
2. Calibrate an anomaly threshold on a benign validation split.
3. Serve a mixed stream on the TEMPORAL-PARALLEL wavefront engine and
   report detection quality.

The whole lifecycle runs through ``repro.engine.AnomalyService``; swap
``schedule="wavefront"`` for ``"sequential"`` or ``"pipelined"`` to run
the same model on a different execution schedule.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.config import TrainConfig
from repro.data import TimeseriesConfig, make_batch
from repro.engine import AnomalyService


def main():
    svc = AnomalyService("lstm-ae-f32-d2", schedule="wavefront")
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=10, total_steps=150)

    print(f"== training {svc.cfg.name} on benign series ==")
    data_cfg = TimeseriesConfig(features=32, seq_len=32, batch=64, anomaly_rate=0.0)
    svc.fit(data_cfg, steps=tc.total_steps, train_cfg=tc, log_every=25)

    print("== calibrating threshold on benign validation ==")
    val, _ = make_batch(data_cfg, 10_000)
    thr = svc.calibrate(val, k_sigma=3.0)
    print(f"threshold = {thr:.4f}")

    print("== serving a mixed stream (40% anomalous) ==")
    test_cfg = TimeseriesConfig(features=32, seq_len=32, batch=256,
                                anomaly_rate=0.4, seed=123)
    series, labels = make_batch(test_cfg, 0)
    report = svc.detect(series, labels)
    print(f"precision={report.precision:.3f} recall={report.recall:.3f} "
          f"f1={report.f1:.3f} auroc={report.auroc:.3f}")
    assert report.auroc > 0.8, "detection quality regression"
    print("quickstart OK")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's full pipeline in ~2 minutes on CPU.

1. Train an LSTM-AE (the paper's F32-D2 model) on benign synthetic
   multivariate time-series.
2. Calibrate an anomaly threshold on a benign validation split.
3. Serve a mixed stream on the TEMPORAL-PARALLEL wavefront engine and
   report detection quality.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.config import TrainConfig, get_config
from repro.core.anomaly import calibrate_threshold, evaluate_detection
from repro.data import TimeseriesConfig, make_batch
from repro.models import build_model
from repro.training import build_train_step, init_train_state


def main():
    model_cfg = get_config("lstm-ae-f32-d2")
    api = build_model(model_cfg)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=10, total_steps=150)

    print(f"== training {model_cfg.name} on benign series ==")
    state = init_train_state(api, jax.random.PRNGKey(0), tc)
    step = jax.jit(build_train_step(api, tc))
    data_cfg = TimeseriesConfig(features=32, seq_len=32, batch=64, anomaly_rate=0.0)
    for i in range(tc.total_steps):
        series, _ = make_batch(data_cfg, i)
        state, metrics = step(state, {"series": series})
        if i % 25 == 0 or i == tc.total_steps - 1:
            print(f"step {i:4d}  mse={float(metrics['loss']):.4f}")

    print("== calibrating threshold on benign validation ==")
    score = jax.jit(lambda p, b: api.prefill(p, b)[0])  # wavefront engine
    val, _ = make_batch(data_cfg, 10_000)
    thr = calibrate_threshold(score(state.params, {"series": val}), k_sigma=3.0)
    print(f"threshold = {thr:.4f}")

    print("== serving a mixed stream (40% anomalous) ==")
    test_cfg = TimeseriesConfig(features=32, seq_len=32, batch=256,
                                anomaly_rate=0.4, seed=123)
    series, labels = make_batch(test_cfg, 0)
    errors = score(state.params, {"series": series})
    report = evaluate_detection(errors, labels, thr)
    print(f"precision={report.precision:.3f} recall={report.recall:.3f} "
          f"f1={report.f1:.3f} auroc={report.auroc:.3f}")
    assert report.auroc > 0.8, "detection quality regression"
    print("quickstart OK")


if __name__ == "__main__":
    main()

"""Durable sessions end to end: SIGKILL a worker mid-stream, resume by token.

Self-contained demo (and the smoke-test driver) of the durability
subsystem (``repro.gateway.durability``):

1. boots a 2-worker :class:`WorkerFront` with a shared snapshot store,
2. streams a session through whichever worker the kernel picked,
   collecting the signed resumption token each ``step`` response carries,
3. forces a snapshot, steps a few more times (those steps exist ONLY in
   the client's replay buffer), then SIGKILLs the serving worker,
4. reconnects — the kernel may land the new connection on either the
   surviving worker or the respawn — and ``resume(token)``s: the server
   restores the ``(h, c)`` row from the latest snapshot and the client
   replays its buffered steps past the snapshot position,
5. asserts every post-resume score is bit-equal to an uninterrupted
   in-process oracle run of the same samples, and
6. drains the front, asserting the handoff snapshot migrated the live
   session (``sessions_lost == 0``).

Run:  PYTHONPATH=src python examples/durable_resume.py
"""
import argparse
import functools
import os
import signal
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import numpy as np

ARCH = "lstm-ae-f32-d2"


def wait_until(predicate, timeout=120.0, interval=0.05):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--timesteps", type=int, default=24)
    ap.add_argument("--kill-after", type=int, default=14,
                    help="SIGKILL the serving worker after this many steps")
    ap.add_argument("--snapshot-at", type=int, default=10,
                    help="force a snapshot after this many steps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    assert args.snapshot_at <= args.kill_after <= args.timesteps

    from repro.engine import AnomalyService
    from repro.gateway.client import GatewayClient
    from repro.gateway.workers import WorkerFront, default_gateway_factory

    store = tempfile.mkdtemp(prefix="durable-resume-")
    front = WorkerFront(
        functools.partial(default_gateway_factory, ARCH, "wavefront",
                          capacity=8, warm_seq_len=8),
        n_workers=2, heartbeat_ms=100.0, store_dir=store,
        snapshot_interval_ms=500.0,
    )
    host, port = front.start(ready_timeout=240.0)
    print(f"front up on {host}:{port}, store={store}", flush=True)

    # the oracle this process compares against: same arch/seed/config as
    # every worker, pooled exactly like the servers pool
    svc = AnomalyService(ARCH, schedule="wavefront")
    oracle_gw = svc.open_gateway(capacity=8)
    oracle_gw.admit("oracle")
    rng = np.random.default_rng(args.seed)
    data = (0.1 * np.cumsum(
        rng.standard_normal((args.timesteps, svc.features)), axis=0)
    ).astype(np.float32)
    oracle = [oracle_gw.step({"oracle": data[t]})["oracle"]
              for t in range(args.timesteps)]

    client = GatewayClient(host, port)
    scores = []
    for t in range(args.kill_after):
        scores.append(client.step(data[t])["running_error"])
        if t + 1 == args.snapshot_at:
            snap = client.request("snapshot")
            print(f"forced snapshot at seq {t + 1}: "
                  f"{snap['sessions']} session(s), {snap['bytes']} bytes",
                  flush=True)
    token = client.session_token
    replay = client.replay_buffer()
    assert token, "server did not return resumption tokens — durability off?"

    victim = next(w["pid"] for w in front.stats()["per_worker"]
                  if w["active_streams"] == 1)
    print(f"SIGKILL worker pid {victim} mid-stream "
          f"(seq {args.kill_after}/{args.timesteps})", flush=True)
    os.kill(victim, signal.SIGKILL)
    assert wait_until(lambda: front.restarts >= 1 and front.alive_workers == 2), \
        "victim was not respawned"
    try:
        client.close()
    except Exception:
        pass

    with GatewayClient(host, port) as c2:
        out = c2.resume(token, replay=replay)
        print(f"resumed at seq {out['seq']} after replaying "
              f"{out['replayed']} buffered step(s)", flush=True)
        assert out["seq"] == args.kill_after, out
        for t in range(args.kill_after, args.timesteps):
            scores.append(c2.step(data[t])["running_error"])
        mismatches = sum(1 for got, want in zip(scores, oracle)
                         if got != want)
        assert mismatches == 0, (
            f"{mismatches}/{len(scores)} scores diverged from the "
            f"uninterrupted oracle"
        )
        print(f"all {len(scores)} scores bit-equal to the uninterrupted "
              f"oracle (final={scores[-1]:.6f})", flush=True)
        # leave the session RESIDENT so the drain below must migrate it

        summary = front.shutdown()
    migrated, lost = summary["sessions_migrated"], summary["sessions_lost"]
    print(f"drained: {summary['clean_exits']}/{summary['workers']} clean, "
          f"sessions_migrated={migrated}, sessions_lost={lost}", flush=True)
    assert migrated >= 1, "drain handoff migrated nothing"
    assert lost == 0, f"drain lost {lost} session(s) despite durability"
    print("durable-resume OK", flush=True)


if __name__ == "__main__":
    main()

"""Client for the gateway's socket transport.

Drives a running ``python -m repro.launch.serve --arch <id> --http``
server end to end: one streaming session (step-per-sample, final score
on close), a batch of concurrent one-shot score requests (coalesced by
the server's micro-batcher and flushed by its background pump — no
client-side pumping), and a live threshold recalibration that takes
effect without the session being evicted.

``--protocol`` picks the wire format: the default ``json`` keeps this
example as the canonical legacy JSON-lines client (every exchange is
byte-identical to the PR 3 protocol — which is exactly what the interop
smoke asserts); ``binary`` requires the bp1 frame protocol and ``auto``
negotiates.  The driving code is identical either way — the client API
is protocol-agnostic.

Run (two terminals):

  PYTHONPATH=src python -m repro.launch.serve --arch lstm-ae-f32-d2 \\
      --http --port 8731 --train-steps 0
  PYTHONPATH=src python examples/gateway_client.py --port 8731
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import numpy as np

from repro.gateway.client import GatewayClient, GatewayClientError


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--timesteps", type=int, default=24,
                    help="streaming session length")
    ap.add_argument("--requests", type=int, default=24,
                    help="concurrent one-shot score requests")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--protocol", choices=("json", "binary", "auto"),
                    default="json",
                    help="wire protocol: json (legacy lines, default), "
                         "binary (require bp1 frames), auto (negotiate)")
    args = ap.parse_args()
    if args.timesteps < 1 or args.requests < 1:
        ap.error("--timesteps and --requests must be >= 1")

    rng = np.random.default_rng(args.seed)
    with GatewayClient(args.host, args.port, protocol=args.protocol) as client:
        assert client.ping()
        stats = client.stats()
        feats = int(stats["features"])
        print(f"connected: protocol={client.protocol} "
              f"schedule={stats['schedule']} "
              f"capacity={stats['capacity']} features={feats} "
              f"threshold={stats['threshold']}")

        # --- streaming session: this connection is the stream
        walk = np.cumsum(rng.standard_normal((args.timesteps, feats)), axis=0)
        walk = (0.1 * walk).astype(np.float32)
        t0 = time.perf_counter()
        for t in range(args.timesteps):
            resp = client.step(walk[t])
        final = client.end_session()["final"]
        dt = time.perf_counter() - t0
        print(f"streamed {args.timesteps} steps in {dt*1e3:.1f} ms "
              f"({args.timesteps/dt:,.0f} steps/s over the wire), "
              f"last running_error={resp['running_error']:.4f}, final={final:.4f}")

        # --- one-shot scores: submit all up front so the server batches them
        lengths = [max(4, args.timesteps - (i % 5)) for i in range(args.requests)]
        windows = [rng.standard_normal((L, feats)).astype(np.float32) * 0.1
                   for L in lengths]
        t0 = time.perf_counter()
        scores = client.score_many(windows)
        dt = time.perf_counter() - t0
        s = client.stats()
        print(f"scored {len(scores)} one-shot windows in {dt*1e3:.1f} ms "
              f"({len(scores)/dt:,.0f} req/s over the wire), "
              f"fill={s['batch_fill_ratio']:.2f}, "
              f"p50={s['latency_ms']['p50']:.2f} ms, "
              f"p95={s['latency_ms']['p95']:.2f} ms")

        # --- live recalibration: swap the threshold mid-connection and
        # watch alert flags flip on, sessions and queue untouched
        new_thr = float(np.median(scores))
        client.recalibrate(new_thr)
        alerts = sum(
            1 for w in windows
            if client.request("score", series=np.asarray(w).tolist()).get("alert")
        )
        print(f"recalibrated threshold={new_thr:.4f} live: "
              f"{alerts}/{len(windows)} windows now alert")

        # --- oversized windows are rejected, not compiled
        try:
            client.score(np.zeros((int(s["max_seq_len"]) + 1, feats), np.float32))
            print("ERROR: oversized window was not rejected", file=sys.stderr)
            sys.exit(1)
        except GatewayClientError as exc:
            print(f"oversized window rejected as expected: {exc.error}")
    print("client done")


if __name__ == "__main__":
    main()

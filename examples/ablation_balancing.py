"""Ablation: what the paper's dataflow balancing (Eq 7-8) actually buys.

Compares three accelerator configurations of the same LSTM-AE on the Eq-1
cycle model:
  A. UNBALANCED  — every module gets the same reuse factor RH_i = RH_m
     (naive provisioning: small layers over-provisioned, pipeline skewed);
  B. BALANCED    — the paper's Eq-8 assignment (equal per-timestep latency);
  C. SEQUENTIAL  — balanced modules but layer-by-layer execution (no
     temporal parallelism) — the prior-work baseline [SHARP et al.].

Reports cycles/timestep, steady-state multiplier utilization, and total
multiplier (DSP) demand, reproducing the motivation of paper §3.3/Table 1.

Run:  PYTHONPATH=src python examples/ablation_balancing.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.config import get_config
from repro.core.balancing import (
    LayerBalance,
    accelerator_latency_cycles,
    balance_model,
    mvm_h_latency,
    mvm_x_latency,
    sequential_latency_cycles,
    total_multipliers,
    utilization,
)
from repro.core.latency import PAPER_RH_M


def unbalanced_model(cfg, rh_m: int) -> list[LayerBalance]:
    """Naive: same reuse everywhere (no Eq-8)."""
    sizes = cfg.layer_sizes()
    in_sizes = cfg.layer_input_sizes()
    out = []
    for i, (lx, lh) in enumerate(zip(in_sizes, sizes)):
        rx = rh = rh_m
        x_t, h_t = mvm_x_latency(lx, lh, rx), mvm_h_latency(lh, rh)
        out.append(LayerBalance(
            index=i, lx=lx, lh=lh, rx=rx, rh=rh, x_t=x_t, h_t=h_t,
            lat_t=max(x_t, h_t), mx=4 * lh / rx, mh=4 * lh / rh,
        ))
    return out


def main():
    t = 64
    print(f"{'model':18s} {'config':12s} {'cyc@T=64':>9s} {'util':>6s} {'mults':>7s} "
          f"{'vs balanced':>11s}")
    for name, rh_m in PAPER_RH_M.items():
        cfg = get_config(name).lstm_ae
        bal = balance_model(cfg, rh_m)
        unb = unbalanced_model(cfg, rh_m)
        rows = [
            ("unbalanced", accelerator_latency_cycles(t, unb), unb, "dataflow"),
            ("balanced", accelerator_latency_cycles(t, bal), bal, "dataflow"),
            ("sequential", sequential_latency_cycles(t, bal), bal, "layer-by-layer"),
        ]
        base = rows[1][1]
        for tag, cyc, b, _ in rows:
            print(f"{name:18s} {tag:12s} {cyc:9d} {utilization(b):6.2f} "
                  f"{total_multipliers(b):7.0f} {cyc / base:10.2f}x")
        print()
    print("balanced beats unbalanced at EQUAL bottleneck latency by using")
    print("fewer multipliers on small layers (util -> 1.0); temporal")
    print("parallelism then beats sequential by ~depth at long T (Eq 1).")


if __name__ == "__main__":
    main()

"""Train a language model end-to-end with the full production stack:
sharded train step, AdamW + cosine schedule, async checkpointing, fault
recovery, and throughput reporting.

Default is CPU-sized (~7M params, 100 steps, a couple of minutes); pass
``--full`` for a ~100M-parameter run (hours on CPU — sized for a real
accelerator).

Run:  PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax

from repro.config import TrainConfig, reduced_config
from repro.data import LMDataConfig, LMIterator
from repro.distributed.fault import FailureInjector, HeartbeatMonitor, run_with_recovery
from repro.models import build_model
from repro.training import build_train_step, init_train_state
from repro.utils import tree_size


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", action="store_true",
                    help="kill the job mid-run to demo recovery")
    args = ap.parse_args()

    cfg = reduced_config("tinyllama-1.1b")
    if args.full:
        cfg = cfg.with_overrides(
            num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
            d_ff=2048, vocab_size=32000, name="tinyllama-100m",
        )
    else:
        cfg = cfg.with_overrides(
            num_layers=4, d_model=256, num_heads=8, num_kv_heads=2,
            d_ff=704, vocab_size=2048, name="tinyllama-7m",
        )
    api = build_model(cfg)
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=20,
                     total_steps=args.steps, loss_chunk=128)
    state = init_train_state(api, jax.random.PRNGKey(0), tc)
    print(f"model {cfg.name}: {tree_size(state.params)/1e6:.1f}M params")

    step = jax.jit(build_train_step(api, tc))
    it = LMIterator(LMDataConfig(vocab_size=cfg.vocab_size, seq_len=256,
                                 global_batch=8))
    injector = FailureInjector((args.steps // 2,)) if args.inject_failure else None
    monitor = HeartbeatMonitor()

    t0 = time.perf_counter()
    state, losses = run_with_recovery(
        state=state, train_step=step, iterator=it, total_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=25, injector=injector, monitor=monitor,
    )
    dt = time.perf_counter() - t0
    tokens = args.steps * 8 * 256
    print(f"trained {args.steps} steps in {dt:.1f}s "
          f"({tokens/dt:,.0f} tok/s); loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    if monitor.stragglers():
        print("stragglers:", monitor.stragglers())
    assert losses[-1] < losses[0], "no learning signal"
    print("train_lm OK")


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper's deployment scenario): a
streaming anomaly-detection service scoring batched windows through the
unified execution engine, with latency accounting against the paper's
Eq-1 model.

The whole fit -> calibrate -> score lifecycle runs through
``repro.engine.AnomalyService``; the execution schedule is a CLI knob
(``--schedule sequential|wavefront|pipelined``), which is exactly the
paper's sequential-vs-temporal-parallel comparison.

Serves ``--batches`` batches of ``--batch`` sequences x ``--timesteps``
steps, reports per-batch wall latency, throughput, detections, and the
calibrated-FPGA-model latency for the same workload (what the accelerator
of the paper would do).

Run:  PYTHONPATH=src python examples/serve_anomaly_stream.py
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax

from repro.config import TrainConfig, get_config
from repro.core.latency import PAPER_RH_M
from repro.data import TimeseriesConfig, make_batch
from repro.engine import AnomalyService, available_schedules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lstm-ae-f32-d6")
    ap.add_argument("--schedule", default="wavefront", choices=available_schedules())
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--timesteps", type=int, default=64)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--train-steps", type=int, default=80)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    svc = AnomalyService(cfg, schedule=args.schedule)
    feats = cfg.lstm_ae.input_features

    # --- fit the detector quickly on benign data (no-op at --train-steps 0:
    # the service then scores with its randomly-initialised params)
    train_cfg = TimeseriesConfig(features=feats, seq_len=args.timesteps, batch=64)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=10,
                     total_steps=max(1, args.train_steps))
    metrics = svc.fit(train_cfg, args.train_steps, train_cfg=tc)
    if metrics:
        print(f"trained {args.arch}: final mse={metrics['mse']:.4f}")
    else:
        print(f"serving {args.arch} untrained (--train-steps 0)")
    thr = svc.calibrate(train_cfg)
    print(f"calibrated threshold={thr:.4f} [schedule={args.schedule}]")

    # --- stream
    stream_cfg = TimeseriesConfig(features=feats, seq_len=args.timesteps,
                                  batch=args.batch, anomaly_rate=0.05, seed=42)
    # warmup compile
    series, _ = make_batch(stream_cfg, 0)
    jax.block_until_ready(svc.score(series))

    total_alerts = total_true = 0
    lat_ms = []
    for i in range(args.batches):
        series, labels = make_batch(stream_cfg, i)
        t0 = time.perf_counter()
        alerts = jax.block_until_ready(svc.alerts(series))
        lat_ms.append((time.perf_counter() - t0) * 1e3)
        total_alerts += int(alerts.sum())
        total_true += int(labels.sum())

    lat_ms.sort()
    p50 = lat_ms[len(lat_ms) // 2]
    p99 = lat_ms[int(len(lat_ms) * 0.99)]
    thpt = args.batch * args.timesteps / (p50 / 1e3)
    print(f"served {args.batches} batches of {args.batch}x{args.timesteps}: "
          f"p50={p50:.2f}ms p99={p99:.2f}ms throughput={thpt:,.0f} steps/s")
    print(f"alerts={total_alerts} (true anomalous sequences={total_true})")

    # the paper's accelerator pipelines one sequence at a time; the engine
    # knows its own Eq-1 accounting (dataflow vs sequential).  Calibrated
    # reuse factors exist only for the paper's Table-1 archs.
    if args.arch in PAPER_RH_M:
        est = svc.latency_model(args.timesteps)
        print(f"paper-model FPGA latency for one sequence (T={args.timesteps}, "
              f"{est.schedule}): {est.ms:.3f} ms ({est.cycles} cycles @300MHz, Eq-1)")


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper's deployment scenario): the
streaming anomaly gateway serving many concurrent streams and micro-batched
one-shot scoring requests over the unified execution engine, with latency
accounting against the paper's Eq-1 model.

The fit -> calibrate lifecycle runs through ``repro.engine.AnomalyService``;
serving then goes through ``repro.gateway.AnomalyGateway``:

* one-shot windows are submitted individually and coalesced by the
  shape-bucketed micro-batcher (``--max-batch`` / ``--max-wait-ms``) — the
  software analogue of the paper's inter-module FIFOs keeping the datapath
  fed;
* a ``--capacity``-slot session pool streams per-timestep samples for more
  logical streams than slots (admit/evict churn, one compiled masked step).

The execution schedule stays a CLI knob (``--schedule
sequential|wavefront|pipelined|fused``) — the paper's
sequential-vs-temporal-parallel comparison.

Run:  PYTHONPATH=src python examples/serve_anomaly_stream.py
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import numpy as np

from repro.config import TrainConfig, get_config
from repro.core.latency import PAPER_RH_M
from repro.data import TimeseriesConfig, make_batch
from repro.engine import AnomalyService, available_schedules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lstm-ae-f32-d6")
    ap.add_argument("--schedule", default="wavefront", choices=available_schedules())
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--timesteps", type=int, default=64)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--train-steps", type=int, default=80)
    ap.add_argument("--capacity", type=int, default=32,
                    help="gateway session-pool slots")
    ap.add_argument("--max-batch", type=int, default=64,
                    help="gateway micro-batch flush size")
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    svc = AnomalyService(cfg, schedule=args.schedule)
    feats = cfg.lstm_ae.input_features

    # --- fit the detector quickly on benign data (no-op at --train-steps 0:
    # the service then scores with its randomly-initialised params)
    train_cfg = TimeseriesConfig(features=feats, seq_len=args.timesteps, batch=64)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=10,
                     total_steps=max(1, args.train_steps))
    metrics = svc.fit(train_cfg, args.train_steps, train_cfg=tc)
    if metrics:
        print(f"trained {args.arch}: final mse={metrics['mse']:.4f}")
    else:
        print(f"serving {args.arch} untrained (--train-steps 0)")
    thr = svc.calibrate(train_cfg)
    print(f"calibrated threshold={thr:.4f} [schedule={args.schedule}]")

    # --- open the gateway: all serving below goes through it
    gw = svc.open_gateway(capacity=args.capacity, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms,
                          max_queue=max(1024, 2 * args.batch))

    # --- one-shot scoring: each window submitted individually, the
    # micro-batcher coalesces them into padded bucket-shaped batches
    stream_cfg = TimeseriesConfig(features=feats, seq_len=args.timesteps,
                                  batch=args.batch, anomaly_rate=0.05, seed=42)
    series, _ = make_batch(stream_cfg, 0)
    gw.score(list(np.asarray(series)[:4]))  # warmup compile of the bucket

    total_alerts = total_true = 0
    t0 = time.perf_counter()
    for i in range(args.batches):
        series, labels = make_batch(stream_cfg, i)
        scores = gw.score(list(np.asarray(series)))
        total_alerts += int((scores > thr).sum())
        total_true += int(labels.sum())
    dt = time.perf_counter() - t0
    s = gw.stats()
    n_req = args.batches * args.batch
    print(f"served {n_req} one-shot requests in {dt:.2f}s "
          f"({n_req/dt:,.0f} req/s, {n_req*args.timesteps/dt:,.0f} steps/s): "
          f"p50={s['latency_ms']['p50']:.2f}ms p95={s['latency_ms']['p95']:.2f}ms "
          f"fill={s['batch_fill_ratio']:.2f}")
    print(f"alerts={total_alerts} (true anomalous sequences={total_true})")

    # --- pooled streaming: 2x capacity logical streams share the slots
    from repro.gateway import drive_stream_churn

    n_streams = 2 * args.capacity
    pool_cfg = TimeseriesConfig(features=feats, seq_len=args.timesteps,
                                batch=n_streams, anomaly_rate=0.05, seed=43)
    xs = np.asarray(make_batch(pool_cfg, 0)[0])
    steps_before = gw.stats()["counters"].get("pool.stream_steps", 0)
    t0 = time.perf_counter()
    finals, unserved = drive_stream_churn(gw, xs)
    dt = time.perf_counter() - t0
    stream_alerts = sum(1 for e in finals.values() if e > thr)
    stepped = int(gw.stats()["counters"]["pool.stream_steps"] - steps_before)
    print(f"streamed {len(finals)}/{n_streams} logical streams over "
          f"{args.capacity} slots in {dt*1e3:.0f}ms "
          f"({stepped/dt:,.0f} stream-steps/s), stream alerts={stream_alerts}"
          + (f", {len(unserved)} still waiting at end" if unserved else ""))

    # the paper's accelerator pipelines one sequence at a time; the engine
    # knows its own Eq-1 accounting (dataflow vs sequential).  Calibrated
    # reuse factors exist only for the paper's Table-1 archs.
    if args.arch in PAPER_RH_M:
        est = svc.latency_model(args.timesteps)
        print(f"paper-model FPGA latency for one sequence (T={args.timesteps}, "
              f"{est.schedule}): {est.ms:.3f} ms ({est.cycles} cycles @300MHz, Eq-1)")


if __name__ == "__main__":
    main()

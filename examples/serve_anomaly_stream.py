"""End-to-end serving driver (the paper's deployment scenario): a
streaming anomaly-detection service scoring batched windows with the
temporal-parallel engine, with latency accounting against the paper's
Eq-1 model.

Serves ``--batches`` batches of ``--batch`` sequences x ``--timesteps``
steps, reports per-batch wall latency, throughput, detections, and the
calibrated-FPGA-model latency for the same workload (what the accelerator
of the paper would do).

Run:  PYTHONPATH=src python examples/serve_anomaly_stream.py
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.config import TrainConfig, get_config
from repro.core.anomaly import calibrate_threshold
from repro.core.latency import PAPER_RH_M, fpga_latency_ms
from repro.data import TimeseriesConfig, make_batch
from repro.models import build_model
from repro.training import build_train_step, init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lstm-ae-f32-d6")
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--timesteps", type=int, default=64)
    ap.add_argument("--batches", type=int, default=20)
    ap.add_argument("--train-steps", type=int, default=80)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    api = build_model(cfg)
    feats = cfg.lstm_ae.input_features

    # --- fit the detector quickly on benign data
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=10, total_steps=args.train_steps)
    state = init_train_state(api, jax.random.PRNGKey(0), tc)
    step = jax.jit(build_train_step(api, tc))
    train_cfg = TimeseriesConfig(features=feats, seq_len=args.timesteps, batch=64)
    for i in range(args.train_steps):
        series, _ = make_batch(train_cfg, i)
        state, m = step(state, {"series": series})
    print(f"trained {args.arch}: final mse={float(m['loss']):.4f}")

    score = jax.jit(lambda p, b: api.prefill(p, b)[0])
    val, _ = make_batch(train_cfg, 99_999)
    thr = calibrate_threshold(score(state.params, {"series": val}))

    # --- stream
    stream_cfg = TimeseriesConfig(features=feats, seq_len=args.timesteps,
                                  batch=args.batch, anomaly_rate=0.05, seed=42)
    # warmup compile
    series, _ = make_batch(stream_cfg, 0)
    jax.block_until_ready(score(state.params, {"series": series}))

    total_alerts = total_true = 0
    lat_ms = []
    for i in range(args.batches):
        series, labels = make_batch(stream_cfg, i)
        t0 = time.perf_counter()
        errors = jax.block_until_ready(score(state.params, {"series": series}))
        lat_ms.append((time.perf_counter() - t0) * 1e3)
        alerts = int((errors > thr).sum())
        total_alerts += alerts
        total_true += int(labels.sum())

    lat_ms.sort()
    p50 = lat_ms[len(lat_ms) // 2]
    p99 = lat_ms[int(len(lat_ms) * 0.99)]
    thpt = args.batch * args.timesteps / (p50 / 1e3)
    print(f"served {args.batches} batches of {args.batch}x{args.timesteps}: "
          f"p50={p50:.2f}ms p99={p99:.2f}ms throughput={thpt:,.0f} steps/s")
    print(f"alerts={total_alerts} (true anomalous sequences={total_true})")

    rh_m = PAPER_RH_M.get(args.arch)
    if rh_m:
        # the paper's accelerator pipelines one sequence at a time
        acc = fpga_latency_ms(cfg.lstm_ae, args.timesteps, rh_m)
        print(f"paper-model FPGA latency for one sequence (T={args.timesteps}): "
              f"{acc.ms:.3f} ms ({acc.cycles} cycles @300MHz, Eq-1)")


if __name__ == "__main__":
    main()

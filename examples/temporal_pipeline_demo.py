"""Multi-device temporal-parallel pipeline demo (the paper's Figure 2 on a
mesh): runs the LSTM-AE across 4 pipeline stages x 2 data shards on 8
emulated devices, verifies bit-consistency against layer-by-layer
execution, and prints the stage assignment + Eq-1 latency accounting.

Run:  PYTHONPATH=src python examples/temporal_pipeline_demo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.core import (
    balance_model,
    init_lstm_ae,
    lstm_ae_sequential,
    accelerator_latency_cycles,
    sequential_latency_cycles,
)
from repro.core.balancing import stage_assignment_for
from repro.core.temporal import build_stage_params, pipelined_forward, schedule_table
from repro.core.latency import PAPER_RH_M
from repro.launch.mesh import make_host_mesh


def main():
    arch = "lstm-ae-f32-d6"
    cfg = get_config(arch)
    params = init_lstm_ae(jax.random.PRNGKey(0), cfg)
    t_len, batch = 32, 8
    xs = jax.random.normal(jax.random.PRNGKey(1), (t_len, batch, 32))

    print(f"== {arch}: {cfg.lstm_ae.layer_sizes()} features ==")
    assignment, bottleneck = stage_assignment_for(cfg.lstm_ae, 4)
    print(f"layer->stage assignment (balanced DP): {assignment}, "
          f"bottleneck {bottleneck:.0f} MACs/timestep")

    print("wavefront schedule (first 8 steps):")
    for k, active in enumerate(schedule_table(cfg.num_layers, t_len)[:8]):
        print(f"  k={k}: " + "  ".join(f"L{l}@t{t}" for l, t in active))

    mesh = make_host_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} devices)")
    stage_params, counts, _ = build_stage_params(params, cfg, 4)
    ys = pipelined_forward(stage_params, counts, xs, mesh=mesh, cfg=cfg)
    ref = lstm_ae_sequential(params, xs)
    err = float(jnp.abs(ys - ref).max())
    print(f"pipeline vs layer-by-layer max |diff| = {err:.2e}")
    assert err < 1e-4

    rh_m = PAPER_RH_M[arch]
    bal = balance_model(cfg.lstm_ae, rh_m)
    acc = accelerator_latency_cycles(t_len, bal)
    seq = sequential_latency_cycles(t_len, bal)
    print(f"Eq-1 accounting @T={t_len}: dataflow={acc} cycles, "
          f"layer-by-layer={seq} cycles -> {seq/acc:.2f}x from temporal parallelism")
    print("demo OK")


if __name__ == "__main__":
    main()

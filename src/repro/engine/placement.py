"""First-class device placement for the execution engine and gateway.

The paper's headline is that a dataflow architecture scales LSTM-AE
throughput with hardware resources; the serving-layer analogue is *data
placement* — how pool-slot state, micro-batch rows, and pipeline stages
are laid out over a device mesh.  Before this module, placement was an
ad-hoc ``data_parallel`` int buried in :class:`EngineConfig` that only
the pipelined schedule read; neither the gateway session pool nor the
micro-batcher could use more than one device.

A :class:`Placement` is the single declarative surface:

>>> pl = Placement.data(4)            # 4-way data-parallel mesh
>>> pl.mesh()                         # jax Mesh over the first 4 devices
>>> pl.row_sharding()                 # NamedSharding: leading dim over "data"
>>> pl.pad_rows(30)                   # -> 32 (per-device multiple)

It is threaded through ``EngineConfig(placement=...)`` → :class:`Engine`
(batch/masked programs jitted with ``in_shardings``/``out_shardings``) →
``AnomalyService.open_gateway(placement=...)`` → ``SessionPool`` (the
stacked ``(h, c)`` + error-sum slot block shards over the data axis, so
capacity scales to ``slots_per_device x mesh_size``) and ``MicroBatcher``
(bucket flushes score data-parallel, padded to a per-device multiple).

Design rules:

* **Declarative and hashable** — a frozen dataclass of plain fields, so
  it participates in ``EngineConfig`` equality and the schedule
  resolve-cache key (sharded and unsharded compiled programs never
  collide).  Meshes are built lazily, per-process, via a cached factory;
  importing this module touches no jax device state.
* **Single-device no-op** — ``Placement.single()`` (the default) changes
  nothing: no mesh is built, no sharding constraints are added, programs
  and values are identical to the pre-placement code paths.
* **Contiguous row blocks** — ``row_sharding`` lays the leading dim out
  in contiguous per-device blocks (device *d* of *n* holds rows
  ``[d*rows/n, (d+1)*rows/n)``), which is what makes per-device slot
  occupancy and flush fill observable host-side.

The deprecated ``EngineConfig(data_parallel=N)`` maps to
``Placement.data(N)`` with a :class:`DeprecationWarning` (see
``engine/base.py``), so every PR 1–3 call site keeps working.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@functools.lru_cache(maxsize=None)
def _mesh_for(data_shards: int, data_axis: str) -> Mesh:
    """One cached 1-D mesh per (ways, axis name) — meshes hold device
    handles, so they are process-global state and must not be rebuilt per
    Engine (the resolve-cache leak class of bug)."""
    devices = jax.devices()
    if len(devices) < data_shards:
        raise ValueError(
            f"placement needs {data_shards} devices on the {data_axis!r} "
            f"axis, have {len(devices)}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={data_shards} "
            f"to emulate, or shrink the placement"
        )
    return jax.make_mesh((data_shards,), (data_axis,),
                         devices=devices[:data_shards])


@dataclass(frozen=True)
class Placement:
    """Declarative device placement: mesh axes + named shardings.

    ``data_shards``  ways on the data axis — pool slots, micro-batch rows
                     and batched scoring rows shard over it
    ``data_axis``    mesh axis name for the data dimension
    ``stage_axis``   mesh axis name pipeline stages use (the pipelined
                     schedule builds its own (data, stage) mesh from the
                     same axis names)
    """

    data_shards: int = 1
    data_axis: str = "data"
    stage_axis: str = "model"

    def __post_init__(self):
        if self.data_shards < 1:
            raise ValueError(f"data_shards must be >= 1, got {self.data_shards}")
        if self.data_axis == self.stage_axis:
            raise ValueError(
                f"data_axis and stage_axis must differ, both {self.data_axis!r}"
            )

    # -- constructors ------------------------------------------------------

    @classmethod
    def single(cls) -> "Placement":
        """The no-op placement: one device, no mesh, unchanged programs."""
        return cls()

    @classmethod
    def data(cls, n: int, *, data_axis: str = "data") -> "Placement":
        """N-way data-parallel placement (``data_parallel=N``'s successor)."""
        return cls(data_shards=n, data_axis=data_axis)

    @classmethod
    def from_spec(cls, spec: str) -> "Placement":
        """Parse a CLI mesh spec like ``"data=4"`` (the ``--mesh`` flag).

        Only the ``data`` axis is placeable from the CLI today; unknown
        axes fail loudly rather than being dropped.
        """
        out: dict[str, int] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            axis, sep, n = part.partition("=")
            axis = axis.strip()
            if not sep or axis not in ("data",):
                raise ValueError(
                    f"bad mesh spec {part!r}: expected data=N (axes "
                    f"supported: data)"
                )
            try:
                out[axis] = int(n)
            except ValueError:
                raise ValueError(f"bad mesh spec {part!r}: {n!r} is not an int")
        return cls.data(out.get("data", 1))

    # -- queries -----------------------------------------------------------

    @property
    def is_sharded(self) -> bool:
        return self.data_shards > 1

    @property
    def devices_needed(self) -> int:
        return self.data_shards

    def pad_rows(self, n: int) -> int:
        """Round ``n`` up to a per-device multiple (sharded leading dims
        must split evenly across the data axis)."""
        s = self.data_shards
        return ((max(n, 1) + s - 1) // s) * s

    def shard_of_row(self, row: int, n_rows: int) -> int:
        """Which data shard holds ``row`` of a ``row_sharding``-laid-out
        leading dim of ``n_rows`` (contiguous blocks)."""
        return row // (n_rows // self.data_shards)

    # -- mesh + shardings (lazy; never built for the single placement) ----

    def mesh(self) -> Mesh:
        """The 1-D data mesh (cached per process); raises with a clear
        message when fewer than ``data_shards`` devices exist."""
        return _mesh_for(self.data_shards, self.data_axis)

    def row_sharding(self) -> NamedSharding:
        """Leading dim over the data axis — pool-slot state, micro-batch
        rows, per-row scores."""
        return NamedSharding(self.mesh(), P(self.data_axis))

    def replicated_sharding(self) -> NamedSharding:
        """Fully replicated — model params, scalar controls."""
        return NamedSharding(self.mesh(), P())

    def describe(self) -> dict:
        """Telemetry-friendly summary (surfaced by ``gateway.stats()``)."""
        return {
            "data": self.data_shards,
            "data_axis": self.data_axis,
            "stage_axis": self.stage_axis,
        }

    def __repr__(self) -> str:
        if not self.is_sharded:
            return "Placement.single()"
        return (f"Placement.data({self.data_shards}, "
                f"data_axis={self.data_axis!r})")


__all__ = ["Placement"]

"""Named execution schedules for the LSTM-AE (paper Section 3).

The paper's contribution is a *schedule* — how the (layer x time) iteration
grid of a recurrent stack is walked — not a new model.  This module turns
each schedule into a first-class, registry-resolved object so every
consumer (serving, benchmarks, examples) selects it by name:

* ``"sequential"`` — layer-by-layer (the CPU/GPU baseline the paper
  compares against): layer i runs over all timesteps before layer i+1.
* ``"wavefront"``  — single-device temporal-parallel dataflow (§3.2): at
  wavefront step k every layer fires concurrently on its own timestep.
* ``"pipelined"``  — multi-device pipeline over a stage mesh axis with
  ppermute FIFOs (§3.1's inter-module queues).  Stage grouping + mesh
  construction are encapsulated here; on a single device it degenerates
  to the wavefront schedule (same dataflow semantics, no stage axis).
* ``"fused"``      — the Pallas fused-cell kernel (kernels/lstm_cell.py:
  MVM_X + MVM_H + gates + element-wise as one MXU kernel) scanned over the
  (layer, time) grid; interpret-mode fallback off-TPU.

Third-party backends register with :func:`register_schedule`; see README
§Execution engine for the contract.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, NamedTuple, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.core.lstm import lstm_ae_sequential
from repro.core.temporal import build_stage_params, pipelined_forward, wavefront_forward
from repro.utils import Params

if TYPE_CHECKING:
    from repro.engine.base import EngineConfig

# (params, xs (T, B, F)) -> reconstruction (T, B, F)
ForwardFn = Callable[[Params, jnp.ndarray], jnp.ndarray]


class Schedule(NamedTuple):
    """A resolved schedule: the executor plus its Eq-1 accounting kind."""
    name: str            # requested registry name
    resolved: str        # actual executor after fallbacks (may differ)
    latency_kind: str    # "dataflow" | "sequential" (core.latency Eq-1 mode)
    forward: ForwardFn
    # True when the factory already manages compilation internally (the
    # Engine must NOT wrap forward in an outer jax.jit; see _pipelined)
    prejitted: bool = False

    @property
    def tag(self) -> str:
        """Display form: the requested name, plus the resolved executor
        when a fallback rerouted it (e.g. ``pipelined->wavefront``)."""
        return self.name if self.resolved == self.name else f"{self.name}->{self.resolved}"


# name -> factory(cfg, engine_cfg) -> Schedule
_SCHEDULES: dict[str, Callable[[ModelConfig, "EngineConfig"], Schedule]] = {}
# name -> EngineConfig field names the factory actually reads (None = all).
# Used to canonicalise the cache key so configs differing only in fields a
# schedule ignores share one Schedule (and one set of compiled programs).
_SCHEDULE_FIELDS: dict[str, Optional[tuple[str, ...]]] = {}

# Resolve cache: explicit LRU so compiled executors (and, for "pipelined",
# their meshes) cannot accumulate without bound when callers resolve many
# distinct EngineConfigs.  Keys are canonicalised (see _canonical_cfg).
SCHEDULE_CACHE_CAPACITY = 32
_RESOLVE_CACHE: "OrderedDict[tuple, Schedule]" = OrderedDict()
# Monotonic resolve counters (process lifetime, not reset with the cache):
# a miss is a full factory build — possibly a fresh mesh + retrace — so a
# climbing miss count under steady serving is a recompile storm in progress.
_CACHE_STATS = {"hits": 0, "misses": 0}


def register_schedule(name: str, *, config_fields: Optional[tuple[str, ...]] = None):
    """Register a schedule factory under ``name`` (decorator).

    The factory receives ``(model_cfg, engine_cfg)`` and returns a
    :class:`Schedule` whose ``forward`` maps ``(params, xs (T,B,F))`` to the
    reconstruction ``(T,B,F)``.  Registration is how new backends plug in.

    ``config_fields`` optionally names the :class:`EngineConfig` fields the
    factory reads (e.g. ``("pwl",)``); resolutions then cache on those
    fields only, so EngineConfigs differing in irrelevant knobs share one
    compiled executor.  Omit it (the safe default) to key on every field.
    ``placement`` is always part of the key, declared or not — sharded and
    unsharded device layouts never share a cached Schedule.
    """
    def deco(factory):
        _SCHEDULES[name] = factory
        _SCHEDULE_FIELDS[name] = config_fields
        _RESOLVE_CACHE.clear()  # re-registration must not serve stale
        return factory
    return deco


def unregister_schedule(name: str) -> None:
    """Remove a registered schedule and drop its cached resolutions."""
    _SCHEDULES.pop(name, None)
    _SCHEDULE_FIELDS.pop(name, None)
    _RESOLVE_CACHE.clear()


def available_schedules() -> list[str]:
    return sorted(_SCHEDULES)


def schedule_cache_info() -> dict:
    """Resolve-cache occupancy — regression surface for the LRU cap.

    ``always_keyed`` are the EngineConfig fields every cache key includes
    regardless of a schedule's declared ``config_fields``; ``placements``
    lists the distinct device layouts currently cached (sharded and
    unsharded resolutions never alias one entry)."""
    return {
        "size": len(_RESOLVE_CACHE),
        "capacity": SCHEDULE_CACHE_CAPACITY,
        "always_keyed": ("schedule", "placement"),
        "placements": sorted({repr(k[2].placement) for k in _RESOLVE_CACHE}),
        "hits": _CACHE_STATS["hits"],
        "misses": _CACHE_STATS["misses"],
    }


def _canonical_cfg(name: str, engine_cfg: "EngineConfig") -> "EngineConfig":
    """Project ``engine_cfg`` onto the fields schedule ``name`` declares it
    reads; everything else is reset to the EngineConfig default so it cannot
    split the cache key.  ``placement`` is ALWAYS part of the key — a
    prejitted schedule bakes its compiled programs (and mesh) into the
    Schedule object, so two engines differing only in device layout must
    never alias one cached program (the ISSUE-4 aliasing bug)."""
    fields = _SCHEDULE_FIELDS.get(name)
    if fields is None:
        return dataclasses.replace(engine_cfg, schedule=name)
    from repro.engine.base import EngineConfig

    return dataclasses.replace(
        EngineConfig(schedule=name, placement=engine_cfg.placement),
        **{f: getattr(engine_cfg, f) for f in fields},
    )


def resolve_schedule(name: str, cfg: ModelConfig, engine_cfg: "EngineConfig") -> Schedule:
    """Look up ``name`` in the registry and build its executor.

    Resolutions are cached per (name, cfg, canonicalised engine_cfg):
    repeated calls — e.g. ``ModelAPI.prefill`` resolving per request, or
    several Engines on the same config — share one Schedule and hence one
    set of compiled programs instead of rebuilding meshes and retracing
    every time.  The cache is a capped LRU (``SCHEDULE_CACHE_CAPACITY``)
    so many distinct configs cannot leak compiled meshes."""
    if name not in _SCHEDULES:
        raise ValueError(
            f"unknown schedule {name!r}; available schedules: "
            f"{', '.join(available_schedules())}"
        )
    canon = _canonical_cfg(name, engine_cfg)
    key = (name, cfg, canon)
    sched = _RESOLVE_CACHE.get(key)
    if sched is None:
        _CACHE_STATS["misses"] += 1
        sched = _SCHEDULES[name](cfg, canon)
        _RESOLVE_CACHE[key] = sched
        while len(_RESOLVE_CACHE) > SCHEDULE_CACHE_CAPACITY:
            _RESOLVE_CACHE.popitem(last=False)
    else:
        _CACHE_STATS["hits"] += 1
        _RESOLVE_CACHE.move_to_end(key)
    return sched


def resolve_forward(
    name: str, cfg: ModelConfig, *, pwl: bool = False, n_stages: Optional[int] = None
) -> ForwardFn:
    """Convenience: schedule name -> ForwardFn with a default EngineConfig
    (used by ``models.lstm_ae.prefill`` so the ModelAPI delegates here)."""
    from repro.engine.base import EngineConfig

    ecfg = EngineConfig(schedule=name, pwl=pwl, n_stages=n_stages)
    return resolve_schedule(name, cfg, ecfg).forward


@register_schedule("sequential", config_fields=("pwl",))
def _sequential(cfg: ModelConfig, ecfg: "EngineConfig") -> Schedule:
    def forward(params, xs):
        return lstm_ae_sequential(params, xs, pwl=ecfg.pwl)

    return Schedule("sequential", "sequential", "sequential", forward)


@register_schedule("wavefront", config_fields=("pwl",))
def _wavefront(cfg: ModelConfig, ecfg: "EngineConfig") -> Schedule:
    def forward(params, xs):
        return wavefront_forward(params, xs, pwl=ecfg.pwl)

    return Schedule("wavefront", "wavefront", "dataflow", forward)


def _divisor_block(n: int, cap: int = 128) -> int:
    """Largest block size <= cap that divides n (Pallas grid constraint)."""
    d = min(n, cap)
    while n % d:
        d -= 1
    return d


@register_schedule("fused", config_fields=("pwl",))
def _fused(cfg: ModelConfig, ecfg: "EngineConfig") -> Schedule:
    """Pallas fused-cell schedule (ROADMAP follow-up): scans the fused
    MVM_X+MVM_H+gates kernel of ``kernels/lstm_cell.py`` over the
    (layer, time) grid layer-by-layer — the paper's single-module datapath
    as one MXU kernel per (layer, timestep).  Falls back to interpret mode
    off-TPU so CPU CI exercises the same kernel code."""
    from repro.kernels.lstm_cell import lstm_cell_pallas, pack_weights

    interpret = jax.default_backend() != "tpu"

    def forward(params, xs):
        ys = xs
        for layer in params["layers"]:
            wx, wh, b = pack_weights(layer)
            bsz = ys.shape[1]
            hidden = wh.shape[1]
            block_b = _divisor_block(bsz)
            block_h = _divisor_block(hidden)
            h0 = jnp.zeros((bsz, hidden), ys.dtype)
            c0 = jnp.zeros((bsz, hidden), jnp.float32)

            def step(carry, x_t, wx=wx, wh=wh, b=b, bb=block_b, bh=block_h):
                h, c = carry
                h, c = lstm_cell_pallas(
                    x_t, h, c, wx, wh, b, block_b=bb, block_h=bh,
                    pwl=ecfg.pwl, interpret=interpret,
                )
                return (h, c), h

            _, ys = jax.lax.scan(step, (h0, c0), ys)
        return ys

    return Schedule("fused", "fused", "sequential", forward)


@register_schedule("pipelined")  # reads every EngineConfig field: key on all
def _pipelined(cfg: ModelConfig, ecfg: "EngineConfig") -> Schedule:
    if cfg.lstm_ae is None:
        raise ValueError("pipelined schedule requires an lstm_ae config")
    depth = len(cfg.lstm_ae.layer_sizes())
    devices = jax.devices()
    data_par = ecfg.placement.data_shards  # data_parallel=N arrives here too (shim)
    n_stages = ecfg.n_stages or min(len(devices) // data_par, depth)

    if n_stages < 2:
        if data_par > 1:
            # the caller explicitly asked for batch sharding — degrading to
            # an unsharded single-device run must not happen silently
            raise ValueError(
                f"pipelined schedule with Placement.data({data_par}) needs "
                f"at least {2 * data_par} devices (2 stages x {data_par}), "
                f"have {len(devices)}"
            )
        # Single device (or a 1-stage request): the pipeline degenerates to
        # the wavefront schedule — identical dataflow semantics, no stage
        # axis.  Eq-1 accounting stays "dataflow".
        wf = _wavefront(cfg, ecfg)
        return Schedule("pipelined", "wavefront", "dataflow", wf.forward)

    need = data_par * n_stages
    if len(devices) < need:
        raise ValueError(
            f"pipelined schedule needs {need} devices "
            f"({data_par} data x {n_stages} stages), have {len(devices)}"
        )
    mesh = jax.make_mesh(
        (data_par, n_stages), (ecfg.data_axis, ecfg.stage_axis),
        devices=devices[:need],
    )

    # Stage grouping (balanced DP over per-timestep FLOPs) is encapsulated
    # here — callers never hand-build stage params or meshes.
    #
    # The two halves are compiled as SEPARATE programs on purpose: tracing
    # build_stage_params and the shard_map into ONE jit miscompiles on
    # jax 0.4.37 when the data mesh axis is >1 (the SPMD partitioner
    # produces wrong wx/wh stage weights; verified by value comparison).
    # Splitting the programs sidesteps the bug, so this Schedule is
    # ``prejitted`` and the Engine must not re-wrap it.
    def _build(params):
        stage_params, counts, _ = build_stage_params(params, cfg, n_stages)
        return stage_params, counts

    def _run(stage_params, counts, xs):
        return pipelined_forward(
            stage_params, counts, xs, mesh=mesh, cfg=cfg,
            stage_axis=ecfg.stage_axis, batch_axes=(ecfg.data_axis,),
            pwl=ecfg.pwl,
        )

    build = jax.jit(_build) if ecfg.jit else _build
    run = jax.jit(_run) if ecfg.jit else _run

    def forward(params, xs):
        if data_par > 1 and isinstance(xs, jax.core.Tracer):
            raise RuntimeError(
                "pipelined schedule with data_parallel>1 must not be traced "
                "into an enclosing jax.jit: inlining re-merges the two "
                "programs and hits the jax-0.4.37 shard_map miscompile "
                "(see core/temporal.py). Call it un-jitted — Engine/"
                "AnomalyService do this automatically."
            )
        stage_params, counts = build(params)
        return run(stage_params, counts, xs)

    return Schedule("pipelined", "pipelined", "dataflow", forward, prejitted=True)

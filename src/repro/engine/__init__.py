"""Unified execution-engine API (paper Section 3 as a pluggable subsystem).

- schedules.py  registry of named temporal schedules (sequential | wavefront
                | pipelined | fused) + ``register_schedule`` for new backends
- base.py       ``Engine``: score / reconstruct / stream / latency_model
                over any registered schedule (plus masked stream/score
                primitives for the gateway)
- service.py    ``AnomalyService``: fit -> calibrate -> score/detect/stream
                -> ``open_gateway`` (repro.gateway serving layer)
"""
from repro.engine.base import Engine, EngineConfig, build_engine
from repro.engine.schedules import (
    ForwardFn,
    Schedule,
    available_schedules,
    register_schedule,
    resolve_forward,
    resolve_schedule,
    schedule_cache_info,
    unregister_schedule,
)
from repro.engine.service import AnomalyService, StreamSession

__all__ = [
    "AnomalyService",
    "Engine",
    "EngineConfig",
    "ForwardFn",
    "Schedule",
    "StreamSession",
    "available_schedules",
    "build_engine",
    "register_schedule",
    "resolve_forward",
    "resolve_schedule",
    "schedule_cache_info",
    "unregister_schedule",
]

"""Unified execution-engine API (paper Section 3 as a pluggable subsystem).

- schedules.py  registry of named temporal schedules (sequential | wavefront
                | pipelined | fused) + ``register_schedule`` for new backends
- placement.py  ``Placement``: first-class device placement (data-mesh ways
                + named shardings for pool slots, micro-batch rows and
                pipeline stages) — threaded through EngineConfig, Engine,
                the gateway, and ``launch.serve --mesh``
- base.py       ``Engine``: score / reconstruct / stream / latency_model
                over any registered schedule (plus masked stream/score
                primitives for the gateway, placement-aware)
- service.py    ``AnomalyService``: fit -> calibrate -> score/detect/stream
                -> ``open_gateway`` (repro.gateway serving layer)
"""
from repro.engine.base import Engine, EngineConfig, build_engine
from repro.engine.placement import Placement
from repro.engine.schedules import (
    ForwardFn,
    Schedule,
    available_schedules,
    register_schedule,
    resolve_forward,
    resolve_schedule,
    schedule_cache_info,
    unregister_schedule,
)
from repro.engine.service import AnomalyService, StreamSession

__all__ = [
    "AnomalyService",
    "Engine",
    "EngineConfig",
    "ForwardFn",
    "Placement",
    "Schedule",
    "StreamSession",
    "available_schedules",
    "build_engine",
    "register_schedule",
    "resolve_forward",
    "resolve_schedule",
    "schedule_cache_info",
    "unregister_schedule",
]

"""The unified execution engine: one surface over every temporal schedule.

An :class:`Engine` binds a model config (or :class:`~repro.models.api.ModelAPI`)
plus parameters to a *named* execution schedule resolved from the registry in
``engine/schedules.py``.  All consumers — serving, benchmarks, examples —
talk to the same four methods regardless of which schedule executes:

    engine = build_engine(cfg, "wavefront", params=params)
    recon  = engine.reconstruct(batch)    # (B, T, F)
    errors = engine.score(batch)          # (B,) per-sequence MSE
    y, st  = engine.stream(x_t, st)       # one timestep, carried state
    est    = engine.latency_model(T)      # Eq-1 accounting for this schedule

Schedule choice is therefore a config knob (``EngineConfig.schedule`` or a
plain string), which is what the paper's sequential-vs-temporal-parallel
comparison needs and what future backends plug into.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.core.latency import PAPER_RH_M, LatencyEstimate, fpga_latency_ms
from repro.engine.schedules import Schedule, resolve_schedule
from repro.utils import Params


@dataclass(frozen=True)
class EngineConfig:
    """Declarative engine selection — everything needed to resolve a schedule.

    ``schedule``       registry name ("sequential" | "wavefront" | "pipelined" | ...)
    ``pwl``            piecewise-linear activations (the paper's HLS numerics)
    ``n_stages``       pipeline stages (pipelined; default: min(devices, depth))
    ``data_parallel``  batch-shard ways on the data mesh axis (pipelined)
    ``jit``            wrap the executor in jax.jit (disable for debugging)
    """
    schedule: str = "wavefront"
    pwl: bool = False
    n_stages: Optional[int] = None
    data_parallel: int = 1
    stage_axis: str = "model"
    data_axis: str = "data"
    jit: bool = True


def _as_engine_cfg(schedule: Union[str, EngineConfig]) -> EngineConfig:
    if isinstance(schedule, EngineConfig):
        return schedule
    return EngineConfig(schedule=schedule)


class Engine:
    """A model bound to one named temporal schedule.

    Construct via :func:`build_engine`.  ``params`` may be bound at
    construction, later via :meth:`bind`, or supplied per call through the
    ``*_with`` variants (the form ModelAPI/serving steps use).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        engine_cfg: Union[str, EngineConfig] = "wavefront",
        params: Optional[Params] = None,
    ):
        if cfg.family != "lstm_ae" or cfg.lstm_ae is None:
            raise ValueError(
                f"Engine executes the paper's lstm_ae family; got {cfg.family!r}"
            )
        self.cfg = cfg
        self.engine_cfg = _as_engine_cfg(engine_cfg)
        self.schedule: Schedule = resolve_schedule(
            self.engine_cfg.schedule, cfg, self.engine_cfg
        )
        self.params = params
        fwd = self.schedule.forward

        # Whole-request programs (transpose + forward + reduction fused),
        # jitted as one unit unless the schedule manages its own compilation
        # (prejitted, e.g. pipelined — its shard_map programs must not be
        # inlined into an enclosing jit; see schedules.py).
        def _reconstruct(params, series):
            xs = jnp.swapaxes(series, 0, 1)
            return jnp.swapaxes(fwd(params, xs), 0, 1)

        def _score(params, series):
            xs = jnp.swapaxes(series, 0, 1)
            recon = fwd(params, xs)
            return jnp.mean(
                jnp.square(recon.astype(jnp.float32) - xs.astype(jnp.float32)),
                axis=(0, 2),
            )

        def _score_masked(params, series, lengths):
            # Per-sequence MSE over each row's valid prefix only.  The LSTM
            # stack is causal, so zero-padding rows out to a common T does
            # not perturb the valid timesteps — the contract the gateway's
            # shape-bucketed micro-batching relies on.
            xs = jnp.swapaxes(series, 0, 1)                       # (T, B, F)
            recon = fwd(params, xs)
            sq = jnp.mean(
                jnp.square(recon.astype(jnp.float32) - xs.astype(jnp.float32)),
                axis=2,
            )                                                     # (T, B)
            valid = jnp.arange(sq.shape[0])[:, None] < lengths[None, :]
            denom = jnp.maximum(lengths, 1).astype(jnp.float32)
            return jnp.sum(jnp.where(valid, sq, 0.0), axis=0) / denom

        jit_here = self.engine_cfg.jit and not self.schedule.prejitted
        self._reconstruct = jax.jit(_reconstruct) if jit_here else _reconstruct
        self._score = jax.jit(_score) if jit_here else _score
        self._score_masked = jax.jit(_score_masked) if jit_here else _score_masked
        step = self._stream_step
        self._step = jax.jit(step) if self.engine_cfg.jit else step
        mstep = self._masked_stream_step
        self._mstep = jax.jit(mstep) if self.engine_cfg.jit else mstep

    # -- binding ----------------------------------------------------------

    def bind(self, params: Params) -> "Engine":
        """Bind parameters; returns self (compiled executors are reused)."""
        self.params = params
        return self

    def _require_params(self) -> Params:
        if self.params is None:
            raise ValueError("engine has no bound params; call bind(params)")
        return self.params

    # -- batch surface ----------------------------------------------------

    def reconstruct_with(self, params: Params, batch: dict) -> jnp.ndarray:
        """batch {"series": (B, T, F)} -> reconstruction (B, T, F)."""
        return self._reconstruct(params, batch["series"])

    def score_with(self, params: Params, batch: dict) -> jnp.ndarray:
        """batch {"series": (B, T, F)} -> per-sequence reconstruction MSE (B,)
        — the anomaly score of the paper's application."""
        return self._score(params, batch["series"])

    def score_masked_with(self, params: Params, batch: dict) -> jnp.ndarray:
        """batch {"series": (B, T, F), "lengths": (B,) int} -> per-sequence
        MSE over each row's first ``lengths[i]`` timesteps.  Rows padded
        beyond their length (and all-padding rows) do not contaminate
        scores — the micro-batching gateway's bucketed-scoring primitive."""
        lengths = jnp.asarray(batch["lengths"], jnp.int32)
        return self._score_masked(params, batch["series"], lengths)

    def reconstruct(self, batch: dict) -> jnp.ndarray:
        return self.reconstruct_with(self._require_params(), batch)

    def score(self, batch: dict) -> jnp.ndarray:
        return self.score_with(self._require_params(), batch)

    def score_masked(self, batch: dict) -> jnp.ndarray:
        return self.score_masked_with(self._require_params(), batch)

    # -- streaming surface ------------------------------------------------

    def init_stream_state(self, batch: int, dtype=jnp.float32) -> Params:
        """Zero (h, c) per layer for a streaming session of ``batch`` series."""
        from repro.models.lstm_ae import init_stream_state

        return init_stream_state(self.cfg, batch, dtype)

    def _stream_step(self, params, x_t, state):
        # One timestep through all layers.  A single timestep admits no
        # temporal parallelism (Eq 1 with T=1), so streaming is schedule-
        # independent: every schedule shares the ModelAPI decode cell loop.
        from repro.models.lstm_ae import decode_step

        return decode_step(params, x_t, state, None, self.cfg,
                           pwl=self.engine_cfg.pwl)

    def _masked_stream_step(self, params, x_t, state, mask):
        # Pooled-session streaming: advance only the rows ``mask`` selects.
        # Rows are independent through the cell (batched matmuls), so masked
        # stepping is value-identical to stepping each selected row alone.
        y_t, new_state = self._stream_step(params, x_t, state)
        keep = mask[:, None]
        merged = jax.tree.map(
            lambda new, old: jnp.where(keep, new, old), new_state, state
        )
        return y_t, merged

    def stream_with(
        self, params: Params, x_t: jnp.ndarray, state: Params
    ) -> tuple[jnp.ndarray, Params]:
        """One streaming timestep x_t (B, F) -> (reconstruction (B, F), state)."""
        return self._step(params, x_t, state)

    def stream(self, x_t: jnp.ndarray, state: Params) -> tuple[jnp.ndarray, Params]:
        return self.stream_with(self._require_params(), x_t, state)

    def stream_masked_with(
        self, params: Params, x_t: jnp.ndarray, state: Params, mask: jnp.ndarray
    ) -> tuple[jnp.ndarray, Params]:
        """Pooled step: x_t (B, F), mask (B,) bool -> (y_t (B, F), state)
        where only masked rows' (h, c) advance (others carry unchanged).
        The gateway session pool runs thousands of logical streams through
        this one compiled program — slot churn never retraces."""
        return self._mstep(params, x_t, state, mask)

    def stream_masked(
        self, x_t: jnp.ndarray, state: Params, mask: jnp.ndarray
    ) -> tuple[jnp.ndarray, Params]:
        return self.stream_masked_with(self._require_params(), x_t, state, mask)

    # -- analytics --------------------------------------------------------

    def latency_model(
        self, timesteps: int, rh_m: Optional[int] = None, **kw
    ) -> LatencyEstimate:
        """Eq-1 accounting of THIS schedule on the paper's accelerator model.

        ``rh_m`` defaults to the paper's Table-1 bottleneck reuse factor for
        this architecture (1 when the arch is not a paper config).
        """
        if rh_m is None:
            rh_m = PAPER_RH_M.get(self.cfg.name, 1)
        return fpga_latency_ms(
            self.cfg.lstm_ae, timesteps, rh_m,
            schedule=self.schedule.latency_kind, **kw,
        )

    def __repr__(self) -> str:
        return (f"Engine({self.cfg.name}, schedule={self.schedule.tag}, "
                f"bound={self.params is not None})")


def build_engine(
    model: Union[ModelConfig, "object"],
    schedule: Union[str, EngineConfig] = "wavefront",
    params: Optional[Params] = None,
) -> Engine:
    """Build an :class:`Engine` from a ModelConfig or a ModelAPI.

    ``schedule`` is a registry name or a full :class:`EngineConfig`.
    """
    cfg = getattr(model, "cfg", model)  # ModelAPI carries .cfg
    if not isinstance(cfg, ModelConfig):
        raise TypeError(f"expected ModelConfig or ModelAPI, got {type(model)!r}")
    return Engine(cfg, schedule, params=params)

"""The unified execution engine: one surface over every temporal schedule.

An :class:`Engine` binds a model config (or :class:`~repro.models.api.ModelAPI`)
plus parameters to a *named* execution schedule resolved from the registry in
``engine/schedules.py``.  All consumers — serving, benchmarks, examples —
talk to the same four methods regardless of which schedule executes:

    engine = build_engine(cfg, "wavefront", params=params)
    recon  = engine.reconstruct(batch)    # (B, T, F)
    errors = engine.score(batch)          # (B,) per-sequence MSE
    y, st  = engine.stream(x_t, st)       # one timestep, carried state
    est    = engine.latency_model(T)      # Eq-1 accounting for this schedule

Schedule choice is therefore a config knob (``EngineConfig.schedule`` or a
plain string), which is what the paper's sequential-vs-temporal-parallel
comparison needs and what future backends plug into.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from dataclasses import dataclass
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.core.latency import PAPER_RH_M, LatencyEstimate, fpga_latency_ms
from repro.engine.placement import Placement
from repro.engine.schedules import Schedule, resolve_schedule
from repro.utils import Params


@dataclass(frozen=True)
class EngineConfig:
    """Declarative engine selection — everything needed to resolve a schedule.

    ``schedule``       registry name ("sequential" | "wavefront" | "pipelined" | ...)
    ``pwl``            piecewise-linear activations (the paper's HLS numerics)
    ``n_stages``       pipeline stages (pipelined; default: min(devices, depth))
    ``placement``      device placement (:class:`~repro.engine.placement.Placement`):
                       data-mesh ways + axis names for pool slots, micro-batch
                       rows and pipeline stages; defaults to the single-device
                       no-op placement
    ``jit``            wrap the executor in jax.jit (disable for debugging)

    ``data_parallel`` / ``data_axis`` / ``stage_axis`` are the PR 1–3
    placement surface, kept as a deprecation shim: ``data_parallel=N`` maps
    to ``Placement.data(N)`` with a warning — including through
    ``dataclasses.replace(cfg, data_parallel=N)`` on an unsharded config.
    After normalisation ``data_parallel`` is *folded into* the placement
    and reset to None (so the two spellings hash/compare equal, and a
    later ``replace(cfg, placement=...)`` cannot be overridden by a stale
    legacy int), while ``data_axis``/``stage_axis`` mirror the placement's
    axis names.  When an explicitly *sharded* ``placement`` and a legacy
    int disagree, the placement wins with a ``UserWarning`` (never
    silently); read the layout from ``cfg.placement``, not the legacy
    fields.
    """
    schedule: str = "wavefront"
    pwl: bool = False
    n_stages: Optional[int] = None
    # DEPRECATED: use placement=Placement.data(N); None once normalised
    data_parallel: Optional[int] = None
    stage_axis: str = "model"   # DEPRECATED: use placement=Placement(stage_axis=...)
    data_axis: str = "data"     # DEPRECATED: use placement=Placement(data_axis=...)
    jit: bool = True
    placement: Optional[Placement] = None

    def __post_init__(self):
        pl = self.placement
        dp = self.data_parallel
        if pl is None:
            pl = Placement(data_shards=1, data_axis=self.data_axis,
                           stage_axis=self.stage_axis)
        if dp is not None and dp != pl.data_shards:
            if not pl.is_sharded:
                # the deprecated spelling (constructor or
                # dataclasses.replace on an unsharded config): fold it in
                warnings.warn(
                    f"EngineConfig(data_parallel={dp}) is deprecated; use "
                    f"placement=Placement.data({dp})",
                    DeprecationWarning, stacklevel=3,
                )
                pl = dataclasses.replace(pl, data_shards=dp)
            else:
                # the legacy int disagrees with a sharded placement
                # (including data_parallel=1, the legacy 'unshard'): the
                # placement wins, but never silently — unshard with
                # placement=Placement.single()
                warnings.warn(
                    f"EngineConfig: ignoring data_parallel={dp} in favour "
                    f"of the explicit placement {pl!r}",
                    UserWarning, stacklevel=3,
                )
        # the placement is now the single source of truth: the legacy int
        # folds in and resets (so shim and explicit spellings compare
        # equal, and replacing the placement later is never overridden by
        # a stale mirror); the axis names mirror the placement
        object.__setattr__(self, "placement", pl)
        object.__setattr__(self, "data_parallel", None)
        object.__setattr__(self, "data_axis", pl.data_axis)
        object.__setattr__(self, "stage_axis", pl.stage_axis)


def _as_engine_cfg(schedule: Union[str, EngineConfig]) -> EngineConfig:
    if isinstance(schedule, EngineConfig):
        return schedule
    return EngineConfig(schedule=schedule)


class Engine:
    """A model bound to one named temporal schedule.

    Construct via :func:`build_engine`.  ``params`` may be bound at
    construction, later via :meth:`bind`, or supplied per call through the
    ``*_with`` variants (the form ModelAPI/serving steps use).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        engine_cfg: Union[str, EngineConfig] = "wavefront",
        params: Optional[Params] = None,
    ):
        if cfg.family != "lstm_ae" or cfg.lstm_ae is None:
            raise ValueError(
                f"Engine executes the paper's lstm_ae family; got {cfg.family!r}"
            )
        self.cfg = cfg
        self.engine_cfg = _as_engine_cfg(engine_cfg)
        self.schedule: Schedule = resolve_schedule(
            self.engine_cfg.schedule, cfg, self.engine_cfg
        )
        self.params = params
        fwd = self.schedule.forward

        # Whole-request programs (transpose + forward + reduction fused),
        # jitted as one unit unless the schedule manages its own compilation
        # (prejitted, e.g. pipelined — its shard_map programs must not be
        # inlined into an enclosing jit; see schedules.py).
        def _reconstruct(params, series):
            xs = jnp.swapaxes(series, 0, 1)
            return jnp.swapaxes(fwd(params, xs), 0, 1)

        def _score(params, series):
            xs = jnp.swapaxes(series, 0, 1)
            recon = fwd(params, xs)
            return jnp.mean(
                jnp.square(recon.astype(jnp.float32) - xs.astype(jnp.float32)),
                axis=(0, 2),
            )

        def _score_masked(params, series, lengths):
            # Per-sequence MSE over each row's valid prefix only.  The LSTM
            # stack is causal, so zero-padding rows out to a common T does
            # not perturb the valid timesteps — the contract the gateway's
            # shape-bucketed micro-batching relies on.
            xs = jnp.swapaxes(series, 0, 1)                       # (T, B, F)
            recon = fwd(params, xs)
            sq = jnp.mean(
                jnp.square(recon.astype(jnp.float32) - xs.astype(jnp.float32)),
                axis=2,
            )                                                     # (T, B)
            valid = jnp.arange(sq.shape[0])[:, None] < lengths[None, :]
            denom = jnp.maximum(lengths, 1).astype(jnp.float32)
            return jnp.sum(jnp.where(valid, sq, 0.0), axis=0) / denom

        jit_here = self.engine_cfg.jit and not self.schedule.prejitted
        self._reconstruct = jax.jit(_reconstruct) if jit_here else _reconstruct
        self._score = jax.jit(_score) if jit_here else _score
        self._score_masked = jax.jit(_score_masked) if jit_here else _score_masked
        step = self._stream_step
        self._step = jax.jit(step) if self.engine_cfg.jit else step
        mstep = self._masked_stream_step
        self._mstep = jax.jit(mstep) if self.engine_cfg.jit else mstep

        # Placement-aware variants: the same programs jitted with explicit
        # in/out shardings — batch rows (and streaming state rows) laid out
        # over the placement's data axis, params replicated.  Built only for
        # a sharded placement (the single placement is a strict no-op) and
        # dispatched per call when the leading dim divides the mesh; callers
        # that need guaranteed sharding (the gateway) pad to a per-device
        # multiple.  Prejitted schedules (pipelined) manage their own batch
        # sharding, so only the schedule-independent streaming programs get
        # sharded variants there.
        self._sharded: dict[str, "object"] = {}
        pl = self.placement
        if pl.is_sharded and self.engine_cfg.jit:
            rows = pl.row_sharding()   # builds (or fails fast on) the mesh
            repl = pl.replicated_sharding()
            if not self.schedule.prejitted:
                self._sharded["reconstruct"] = jax.jit(
                    _reconstruct, in_shardings=(repl, rows), out_shardings=rows)
                self._sharded["score"] = jax.jit(
                    _score, in_shardings=(repl, rows), out_shardings=rows)
                self._sharded["score_masked"] = jax.jit(
                    _score_masked, in_shardings=(repl, rows, rows),
                    out_shardings=rows)
            self._sharded["step"] = jax.jit(
                step, in_shardings=(repl, rows, rows), out_shardings=(rows, rows))
            self._sharded["mstep"] = jax.jit(
                mstep, in_shardings=(repl, rows, rows, rows),
                out_shardings=(rows, rows))

        # Compile profiling: a jitted program (re)traces+compiles on the
        # first call per input shape, so the first-call wall time per
        # (program, shape) is the compile-cost proxy — that is what makes
        # a recompile storm on the bucket ladder visible in stats().
        self._seen_shapes: set = set()
        self.profile: dict = {"compiles": 0, "compile_ms": 0.0,
                              "per_program": {}}

    # -- placement ---------------------------------------------------------

    @property
    def placement(self) -> Placement:
        """The device placement this engine's programs are laid out on."""
        return self.engine_cfg.placement

    def with_placement(self, placement: Placement) -> "Engine":
        """A new engine on the same model/schedule/params with ``placement``
        (returns self when the placement already matches).  Compiled
        programs are NOT shared — sharded and unsharded programs must
        never collide (the resolve cache keys on placement too)."""
        if placement == self.placement:
            return self
        # data_parallel is always None post-normalisation, so replacing the
        # placement cannot be vetoed by a stale legacy mirror
        ecfg = dataclasses.replace(self.engine_cfg, placement=placement)
        return Engine(self.cfg, ecfg, params=self.params)

    def _row_program(self, key: str, rows: int):
        """The sharded variant of program ``key`` when one exists and the
        leading dim splits evenly over the data mesh; None otherwise (the
        caller falls back to the unsharded program — value-identical, the
        rows are independent)."""
        prog = self._sharded.get(key)
        if prog is not None and rows % self.placement.data_shards == 0:
            return prog
        return None

    # -- profiling ---------------------------------------------------------

    def _run_profiled(self, name: str, prog, shape: tuple, *args):
        """Dispatch ``prog`` and, on the first call per (program, shape),
        record its wall time as that shape's compile cost (tracing and
        compilation happen synchronously inside the first dispatch).
        Steady-state cost is one set lookup."""
        key = (name, shape)
        if key in self._seen_shapes:
            return prog(*args)
        t0 = time.perf_counter()
        out = prog(*args)
        ms = (time.perf_counter() - t0) * 1e3
        self._seen_shapes.add(key)
        self.profile["compiles"] += 1
        self.profile["compile_ms"] += ms
        per = self.profile["per_program"].setdefault(
            name, {"compiles": 0, "compile_ms": 0.0, "shapes": []}
        )
        per["compiles"] += 1
        per["compile_ms"] += ms
        per["shapes"].append(list(shape))
        return out

    def profile_info(self) -> dict:
        """JSON-safe compile profile: total + per-program compile counts,
        first-call wall time, and the shapes (bucket ladder rungs) seen."""
        return {
            "schedule": self.schedule.tag,
            "compiles": self.profile["compiles"],
            "compile_ms": round(self.profile["compile_ms"], 3),
            "per_program": {
                name: {
                    "compiles": d["compiles"],
                    "compile_ms": round(d["compile_ms"], 3),
                    "shapes": list(d["shapes"]),
                }
                for name, d in self.profile["per_program"].items()
            },
        }

    # -- binding ----------------------------------------------------------

    def bind(self, params: Params) -> "Engine":
        """Bind parameters; returns self (compiled executors are reused)."""
        self.params = params
        return self

    def _require_params(self) -> Params:
        if self.params is None:
            raise ValueError("engine has no bound params; call bind(params)")
        return self.params

    # -- batch surface ----------------------------------------------------

    def reconstruct_with(self, params: Params, batch: dict) -> jnp.ndarray:
        """batch {"series": (B, T, F)} -> reconstruction (B, T, F)."""
        series = batch["series"]
        sharded = self._row_program("reconstruct", series.shape[0])
        return self._run_profiled(
            "reconstruct@sharded" if sharded is not None else "reconstruct",
            sharded or self._reconstruct, tuple(series.shape), params, series,
        )

    def score_with(self, params: Params, batch: dict) -> jnp.ndarray:
        """batch {"series": (B, T, F)} -> per-sequence reconstruction MSE (B,)
        — the anomaly score of the paper's application.  Under a sharded
        placement the batch rows are scored data-parallel over the mesh."""
        series = batch["series"]
        sharded = self._row_program("score", series.shape[0])
        return self._run_profiled(
            "score@sharded" if sharded is not None else "score",
            sharded or self._score, tuple(series.shape), params, series,
        )

    def score_masked_with(self, params: Params, batch: dict) -> jnp.ndarray:
        """batch {"series": (B, T, F), "lengths": (B,) int} -> per-sequence
        MSE over each row's first ``lengths[i]`` timesteps.  Rows padded
        beyond their length (and all-padding rows) do not contaminate
        scores — the micro-batching gateway's bucketed-scoring primitive
        (which pads B to a per-device multiple under a sharded placement)."""
        series = batch["series"]
        lengths = jnp.asarray(batch["lengths"], jnp.int32)
        sharded = self._row_program("score_masked", series.shape[0])
        return self._run_profiled(
            "score_masked@sharded" if sharded is not None else "score_masked",
            sharded or self._score_masked, tuple(series.shape),
            params, series, lengths,
        )

    def reconstruct(self, batch: dict) -> jnp.ndarray:
        return self.reconstruct_with(self._require_params(), batch)

    def score(self, batch: dict) -> jnp.ndarray:
        return self.score_with(self._require_params(), batch)

    def score_masked(self, batch: dict) -> jnp.ndarray:
        return self.score_masked_with(self._require_params(), batch)

    # -- streaming surface ------------------------------------------------

    def init_stream_state(self, batch: int, dtype=jnp.float32) -> Params:
        """Zero (h, c) per layer for a streaming session of ``batch`` series."""
        from repro.models.lstm_ae import init_stream_state

        return init_stream_state(self.cfg, batch, dtype)

    def _stream_step(self, params, x_t, state):
        # One timestep through all layers.  A single timestep admits no
        # temporal parallelism (Eq 1 with T=1), so streaming is schedule-
        # independent: every schedule shares the ModelAPI decode cell loop.
        from repro.models.lstm_ae import decode_step

        return decode_step(params, x_t, state, None, self.cfg,
                           pwl=self.engine_cfg.pwl)

    def _masked_stream_step(self, params, x_t, state, mask):
        # Pooled-session streaming: advance only the rows ``mask`` selects.
        # Rows are independent through the cell (batched matmuls), so masked
        # stepping is value-identical to stepping each selected row alone.
        y_t, new_state = self._stream_step(params, x_t, state)
        keep = mask[:, None]
        merged = jax.tree.map(
            lambda new, old: jnp.where(keep, new, old), new_state, state
        )
        return y_t, merged

    def stream_with(
        self, params: Params, x_t: jnp.ndarray, state: Params
    ) -> tuple[jnp.ndarray, Params]:
        """One streaming timestep x_t (B, F) -> (reconstruction (B, F), state)."""
        sharded = self._row_program("step", x_t.shape[0])
        return self._run_profiled(
            "step@sharded" if sharded is not None else "step",
            sharded or self._step, tuple(x_t.shape), params, x_t, state,
        )

    def stream(self, x_t: jnp.ndarray, state: Params) -> tuple[jnp.ndarray, Params]:
        return self.stream_with(self._require_params(), x_t, state)

    def stream_masked_with(
        self, params: Params, x_t: jnp.ndarray, state: Params, mask: jnp.ndarray
    ) -> tuple[jnp.ndarray, Params]:
        """Pooled step: x_t (B, F), mask (B,) bool -> (y_t (B, F), state)
        where only masked rows' (h, c) advance (others carry unchanged).
        The gateway session pool runs thousands of logical streams through
        this one compiled program — slot churn never retraces.  Under a
        sharded placement the slot rows live distributed over the data
        mesh (state in, state out keep the row sharding)."""
        sharded = self._row_program("mstep", x_t.shape[0])
        return self._run_profiled(
            "mstep@sharded" if sharded is not None else "mstep",
            sharded or self._mstep, tuple(x_t.shape), params, x_t, state, mask,
        )

    def stream_masked(
        self, x_t: jnp.ndarray, state: Params, mask: jnp.ndarray
    ) -> tuple[jnp.ndarray, Params]:
        return self.stream_masked_with(self._require_params(), x_t, state, mask)

    # -- analytics --------------------------------------------------------

    def latency_model(
        self, timesteps: int, rh_m: Optional[int] = None, **kw
    ) -> LatencyEstimate:
        """Eq-1 accounting of THIS schedule on the paper's accelerator model.

        ``rh_m`` defaults to the paper's Table-1 bottleneck reuse factor for
        this architecture (1 when the arch is not a paper config).
        """
        if rh_m is None:
            rh_m = PAPER_RH_M.get(self.cfg.name, 1)
        return fpga_latency_ms(
            self.cfg.lstm_ae, timesteps, rh_m,
            schedule=self.schedule.latency_kind, **kw,
        )

    def __repr__(self) -> str:
        pl = f", placement={self.placement!r}" if self.placement.is_sharded else ""
        return (f"Engine({self.cfg.name}, schedule={self.schedule.tag}"
                f"{pl}, bound={self.params is not None})")


def build_engine(
    model: Union[ModelConfig, "object"],
    schedule: Union[str, EngineConfig] = "wavefront",
    params: Optional[Params] = None,
) -> Engine:
    """Build an :class:`Engine` from a ModelConfig or a ModelAPI.

    ``schedule`` is a registry name or a full :class:`EngineConfig`.
    """
    cfg = getattr(model, "cfg", model)  # ModelAPI carries .cfg
    if not isinstance(cfg, ModelConfig):
        raise TypeError(f"expected ModelConfig or ModelAPI, got {type(model)!r}")
    return Engine(cfg, schedule, params=params)

"""AnomalyService: the paper's deployment scenario as one object.

fit (train on benign series) -> calibrate (threshold on a benign split) ->
score / detect (batched windows) -> stream (per-timestep state + running
errors).  This replaces the train/calibrate/score loops that used to be
copy-pasted across ``examples/serve_anomaly_stream.py``, ``launch/serve.py``
and ``examples/quickstart.py``; the execution schedule underneath is a
config knob (any name in the engine registry).
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.config import TrainConfig, get_config
from repro.config.core import ModelConfig
from repro.core.anomaly import DetectionReport, calibrate_threshold, evaluate_detection
from repro.core.latency import LatencyEstimate
from repro.data import TimeseriesConfig, make_batch
from repro.engine.base import Engine, EngineConfig, build_engine
from repro.models.api import build_model
from repro.utils import Params

_UNSET = object()  # distinguishes "not given" from an explicit None


@dataclass
class StreamSession:
    """Carried state of one streaming connection: per-layer (h, c) plus the
    running sum of squared reconstruction error per series."""
    state: Params
    sq_err_sum: jnp.ndarray   # (B,)
    steps: int

    @property
    def errors(self) -> jnp.ndarray:
        """Mean squared reconstruction error so far, per series (B,)."""
        return self.sq_err_sum / max(1, self.steps)


class AnomalyService:
    """End-to-end anomaly detection on a pluggable execution engine.

    >>> svc = AnomalyService("lstm-ae-f32-d2", schedule="wavefront")
    >>> svc.fit(TimeseriesConfig(features=32, seq_len=32, batch=64), steps=100)
    >>> svc.calibrate(TimeseriesConfig(features=32, seq_len=32, batch=64))
    >>> report = svc.detect(series, labels)
    """

    def __init__(
        self,
        model: Union[str, ModelConfig],
        schedule: Union[str, EngineConfig] = "wavefront",
        *,
        seed: int = 0,
    ):
        cfg = get_config(model) if isinstance(model, str) else model
        self.cfg = cfg
        self.api = build_model(cfg)
        self.engine: Engine = build_engine(cfg, schedule)
        self.seed = seed
        self.params: Params = self.api.init(jax.random.PRNGKey(seed))
        self.engine.bind(self.params)
        self.threshold: Optional[float] = None
        # open gateways whose engine is a placement re-layout of ours (see
        # open_gateway): weakly held so a dropped gateway is collectable,
        # rebound on every param swap so they never serve stale params
        self._gateways: "weakref.WeakSet" = weakref.WeakSet()

    def _bind(self, params: Params) -> None:
        """Swap ``params`` onto this service AND every open gateway engine.

        A gateway opened with a different placement carries its own Engine
        (same model, re-laid-out programs); binding only ``self.engine``
        would leave it scoring with stale params — the contract is that
        open gateways always read the params now in effect."""
        self.params = params
        self.engine.bind(params)
        for gw in list(self._gateways):
            if gw.engine is not self.engine:
                gw.engine.bind(params)

    @property
    def features(self) -> int:
        return self.cfg.lstm_ae.input_features

    # -- fit --------------------------------------------------------------

    def fit(
        self,
        data_cfg: TimeseriesConfig,
        steps: int,
        train_cfg: Optional[TrainConfig] = None,
        log_every: int = 0,
    ) -> dict:
        """Train on benign windows drawn from ``data_cfg``; binds the fitted
        params onto the engine.  Returns the final metrics (empty when
        ``steps == 0`` — the service then scores with its init params)."""
        if steps <= 0:
            return {}
        from repro.training import build_train_step, init_train_state

        tc = train_cfg or TrainConfig(
            learning_rate=5e-3, warmup_steps=min(10, steps), total_steps=steps
        )
        # the ctor seed governs training init too, so two services with
        # different seeds fit genuinely different models
        state = init_train_state(self.api, jax.random.PRNGKey(self.seed), tc)
        step = jax.jit(build_train_step(self.api, tc))
        metrics: dict = {}
        for i in range(steps):
            series, _ = make_batch(data_cfg, i)
            state, metrics = step(state, {"series": series})
            if log_every and (i % log_every == 0 or i == steps - 1):
                print(f"step {i:4d}  mse={float(metrics['loss']):.4f}")
        self._bind(state.params)
        return {k: float(v) for k, v in metrics.items()}

    # -- calibrate --------------------------------------------------------

    def calibrate(
        self,
        benign: Union[TimeseriesConfig, jnp.ndarray],
        k_sigma: float = 3.0,
        seed: int = 99_999,
    ) -> float:
        """Threshold = mean + k*std of scores on a benign split.  ``benign``
        is either a series batch (B, T, F) or a TimeseriesConfig to draw one."""
        if isinstance(benign, TimeseriesConfig):
            benign, _ = make_batch(benign, seed)
        self.threshold = calibrate_threshold(self.score(benign), k_sigma=k_sigma)
        return self.threshold

    def recalibrate(
        self,
        benign: Union[TimeseriesConfig, jnp.ndarray, None] = None,
        *,
        threshold=_UNSET,
        params: Optional[Params] = None,
        k_sigma: float = 3.0,
        seed: int = 99_999,
    ) -> Optional[float]:
        """Refresh the live detector in place — no drain, no restart.

        Optionally rebinds ``params`` (e.g. a freshly fitted model) onto
        the engine, then swaps the threshold: either ``threshold``
        directly (an explicit None disables alerting — same semantics as
        :meth:`AnomalyGateway.recalibrate`; omit it to leave the threshold
        alone), or re-derived from a ``benign`` split (after the param
        swap, so the new threshold reflects the new model).  Streaming
        sessions and open gateways keep serving throughout — both read the
        engine's current params and this threshold per operation.  Returns
        the threshold now in effect.
        """
        if params is not None:
            self._bind(params)
        if threshold is not _UNSET:
            self.threshold = None if threshold is None else float(threshold)
        elif benign is not None:
            self.calibrate(benign, k_sigma=k_sigma, seed=seed)
        return self.threshold

    # -- batch scoring ----------------------------------------------------

    def score(self, series: jnp.ndarray) -> jnp.ndarray:
        """(B, T, F) -> per-sequence reconstruction errors (B,)."""
        return self.engine.score({"series": series})

    def alerts(self, series: jnp.ndarray) -> jnp.ndarray:
        """(B, T, F) -> boolean alert mask (B,); requires calibration."""
        return self.score(series) > self._require_threshold()

    def detect(self, series: jnp.ndarray, labels: jnp.ndarray) -> DetectionReport:
        """Score + evaluate against ground-truth labels (B,)."""
        return evaluate_detection(self.score(series), labels, self._require_threshold())

    def _require_threshold(self) -> float:
        if self.threshold is None:
            raise ValueError("service is not calibrated; call calibrate(...) first")
        return self.threshold

    # -- streaming --------------------------------------------------------

    def stream_start(self, batch: int) -> StreamSession:
        return StreamSession(
            state=self.engine.init_stream_state(batch),
            sq_err_sum=jnp.zeros((batch,), jnp.float32),
            steps=0,
        )

    def stream_step(
        self, x_t: jnp.ndarray, session: StreamSession
    ) -> tuple[jnp.ndarray, StreamSession]:
        """One timestep x_t (B, F); returns (running errors (B,), session)."""
        y_t, state = self.engine.stream(x_t, session.state)
        sq = jnp.mean(
            jnp.square(y_t.astype(jnp.float32) - x_t.astype(jnp.float32)), axis=-1
        )
        session = StreamSession(
            state=state, sq_err_sum=session.sq_err_sum + sq, steps=session.steps + 1
        )
        return session.errors, session

    # -- gateway ----------------------------------------------------------

    def open_gateway(
        self,
        *,
        capacity: int = 32,
        max_batch: int = 32,
        max_wait_ms: float = 5.0,
        max_queue: int = 1024,
        max_seq_len: Optional[int] = None,
        placement: Optional["object"] = None,
        **kw,
    ) -> "object":
        """Open a streaming/micro-batching gateway over this service.

        Returns a :class:`repro.gateway.AnomalyGateway`: a ``capacity``-slot
        session pool (admit/step/evict over one compiled masked step) plus a
        shape-bucketed one-shot scoring queue (flush on ``max_batch`` or
        ``max_wait_ms``, reject past ``max_queue`` pending or ``max_seq_len``
        timesteps).  ``placement`` (a
        :class:`~repro.engine.placement.Placement`, or an int as shorthand
        for ``Placement.data(n)``) shards the gateway's serving programs
        over a data mesh — pool-slot state distributes over the mesh so
        ``capacity`` can exceed what one device holds, and bucket flushes
        score data-parallel; it defaults to this engine's own placement.
        See README §Gateway / §Placement; front it with
        :class:`repro.gateway.server.GatewayServer` for socket serving.
        """
        from repro.gateway import AnomalyGateway  # lazy: gateway imports engine

        # the gateway registers itself in self._gateways, so future param
        # swaps (fit / recalibrate) rebind its engine too
        return AnomalyGateway(
            self, capacity=capacity, max_batch=max_batch,
            max_wait_ms=max_wait_ms, max_queue=max_queue,
            max_seq_len=max_seq_len, placement=placement, **kw,
        )

    # -- analytics --------------------------------------------------------

    def latency_model(self, timesteps: int, **kw) -> LatencyEstimate:
        """Eq-1 accounting of the bound schedule (paper accelerator model)."""
        return self.engine.latency_model(timesteps, **kw)

"""Dataflow balancing (paper Section 3.2-3.3): the reuse-factor latency
model, Eqs (1)-(8), plus the TPU-side projection (layer -> stage makespan
partition, since a TPU core cannot be fractionally provisioned the way FPGA
multipliers can — see DESIGN.md §2).

All equations reference the paper:

  (1) Acc_Lat = T*Lat_t_m + sum_{i != m} Lat_t_i
  (2) Lat_t_i = max(X_t_i, H_t_i)
  (3) X_t_i = LX_i*RX_i + LH_i        (4) H_t_i = LH_i*RH_i + LH_i
  (5) RX_i = 4*LH_i / MX_i            (6) RH_i = 4*LH_i / MH_i
  (7) RX_i = (LH_i/LX_i) * RH_i
  (8) RH_i = (LH_m - LH_i)/LH_i + (LH_m/LH_i)*RH_m
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.config.core import LSTMAEConfig


@dataclass(frozen=True)
class LayerBalance:
    """Balanced configuration of one LSTM_i module."""
    index: int
    lx: int           # input feature dim LX_i
    lh: int           # hidden dim LH_i
    rx: int           # reuse factor of MVM_X (>= 1, integer like hardware)
    rh: int           # reuse factor of MVM_H
    x_t: int          # Eq (3)
    h_t: int          # Eq (4)
    lat_t: int        # Eq (2)
    mx: float         # parallel multipliers in MVM_X, Eq (5)
    mh: float         # parallel multipliers in MVM_H, Eq (6)


def mvm_x_latency(lx: int, lh: int, rx: int) -> int:
    return lx * rx + lh  # Eq (3)


def mvm_h_latency(lh: int, rh: int) -> int:
    return lh * rh + lh  # Eq (4)


def balanced_rx(lx: int, lh: int, rh: float) -> float:
    return (lh / lx) * rh  # Eq (7)


def balanced_rh(lh_i: int, lh_m: int, rh_m: float) -> float:
    return (lh_m - lh_i) / lh_i + (lh_m / lh_i) * rh_m  # Eq (8)


def multipliers(lh: int, r: float) -> float:
    return 4.0 * lh / r  # Eq (5)/(6) inverted


def balance_model(cfg: LSTMAEConfig, rh_m: int) -> list[LayerBalance]:
    """Apply the paper's balancing methodology to an LSTM-AE model.

    The bottleneck module m is the one with the largest LH (its H_t
    dominates once internally balanced).  Reuse factors are integers >= 1 in
    hardware; we ceil, which can only make a module *slower* than the ideal
    — the paper accepts the same rounding.
    """
    sizes = cfg.layer_sizes()
    in_sizes = cfg.layer_input_sizes()
    lh_m = max(sizes)
    out: list[LayerBalance] = []
    for i, (lx, lh) in enumerate(zip(in_sizes, sizes)):
        rh = max(1, math.ceil(balanced_rh(lh, lh_m, rh_m)))
        # Eq (7) can be fractional; hardware reuse factors are integers.
        # Round DOWN (spend a few more multipliers) so X_t <= H_t and the
        # intra-module balance max(X_t, H_t) = H_t survives the rounding.
        rx = max(1, math.floor(balanced_rx(lx, lh, rh)))
        x_t = mvm_x_latency(lx, lh, rx)
        h_t = mvm_h_latency(lh, rh)
        out.append(
            LayerBalance(
                index=i, lx=lx, lh=lh, rx=rx, rh=rh,
                x_t=x_t, h_t=h_t, lat_t=max(x_t, h_t),
                mx=multipliers(lh, rx), mh=multipliers(lh, rh),
            )
        )
    return out


def accelerator_latency_cycles(timesteps: int, balances: list[LayerBalance]) -> int:
    """Eq (1): steady-state bottleneck + pipeline fill/drain of the others."""
    lat_m = max(b.lat_t for b in balances)
    fill_drain = sum(b.lat_t for b in balances) - lat_m
    return timesteps * lat_m + fill_drain


def sequential_latency_cycles(timesteps: int, balances: list[LayerBalance]) -> int:
    """Layer-by-layer execution latency (no temporal parallelism): every
    layer runs over all T timesteps before the next starts."""
    return timesteps * sum(b.lat_t for b in balances)


def total_multipliers(balances: list[LayerBalance]) -> float:
    return sum(b.mx + b.mh for b in balances)


def utilization(balances: list[LayerBalance]) -> float:
    """Fraction of multiplier-cycles doing useful work in steady state.

    A module with Lat_t_i < Lat_t_m idles for the difference; perfect
    balancing -> 1.0.  This is the quantity the paper's Eq-8 maximises.
    """
    lat_m = max(b.lat_t for b in balances)
    used = sum((b.mx + b.mh) * b.lat_t for b in balances)
    avail = total_multipliers(balances) * lat_m
    return used / avail


# ---------------------------------------------------------------------------
# TPU projection: layer -> stage partition (DESIGN.md §2).
# A TPU pipeline has S equal cores, not per-layer multiplier budgets; the
# balancing problem becomes: partition contiguous layers into <= S groups
# minimising the bottleneck group cost (classic linear-partition DP, exact).
# ---------------------------------------------------------------------------

def stage_partition(costs: list[float], n_stages: int) -> tuple[list[int], float]:
    """Exact DP.  Returns (stage id per layer, bottleneck cost)."""
    n = len(costs)
    n_stages = max(1, min(n_stages, n))
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)

    inf = float("inf")
    # dp[s][i] = minimal bottleneck for first i layers in s stages
    dp = [[inf] * (n + 1) for _ in range(n_stages + 1)]
    cut = [[0] * (n + 1) for _ in range(n_stages + 1)]
    dp[0][0] = 0.0
    for s in range(1, n_stages + 1):
        for i in range(1, n + 1):
            for j in range(s - 1, i):
                cand = max(dp[s - 1][j], prefix[i] - prefix[j])
                if cand < dp[s][i]:
                    dp[s][i] = cand
                    cut[s][i] = j
    best_s = min(range(1, n_stages + 1), key=lambda s: (dp[s][n], s))
    assignment = [0] * n
    i, s = n, best_s
    while s > 0:
        j = cut[s][i]
        for k in range(j, i):
            assignment[k] = s - 1
        i, s = j, s - 1
    return assignment, dp[best_s][n]


def lstm_layer_flops(lx: int, lh: int) -> float:
    """Per-timestep MACs of one LSTM layer (both MVMs, Fig. 1)."""
    return 4.0 * lh * (lx + lh)


def stage_assignment_for(cfg: LSTMAEConfig, n_stages: int) -> tuple[list[int], float]:
    """Balance the paper's model onto ``n_stages`` pipeline stages by
    per-timestep FLOPs (the TPU analogue of Eq 8)."""
    costs = [
        lstm_layer_flops(lx, lh)
        for lx, lh in zip(cfg.layer_input_sizes(), cfg.layer_sizes())
    ]
    return stage_partition(costs, n_stages)

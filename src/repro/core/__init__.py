"""The paper's contribution: temporal-parallel dataflow LSTM-AE execution.

- lstm.py       LSTM cell / layer / autoencoder (Fig. 1, Section 2)
- temporal.py   wavefront + pipelined executors (Section 3.1-3.2)
- balancing.py  reuse-factor equations (2)-(8) + TPU stage partition (3.3)
- latency.py    Eq (1) latency/energy model reproducing Tables 1-3
- anomaly.py    reconstruction-error detection (the application)
"""
from repro.core.balancing import (
    LayerBalance,
    accelerator_latency_cycles,
    balance_model,
    balanced_rh,
    balanced_rx,
    sequential_latency_cycles,
    stage_assignment_for,
    stage_partition,
    utilization,
)
from repro.core.lstm import (
    init_lstm_ae,
    init_lstm_cell,
    lstm_ae_reconstruction_error,
    lstm_ae_sequential,
    lstm_cell,
    lstm_layer,
    pwl_sigmoid,
    pwl_tanh,
    stacked_cell_params,
)
from repro.core.temporal import (
    build_stage_params,
    pipelined_forward,
    schedule_table,
    wavefront_forward,
)

__all__ = [
    "LayerBalance",
    "accelerator_latency_cycles",
    "balance_model",
    "balanced_rh",
    "balanced_rx",
    "build_stage_params",
    "init_lstm_ae",
    "init_lstm_cell",
    "lstm_ae_reconstruction_error",
    "lstm_ae_sequential",
    "lstm_cell",
    "lstm_layer",
    "pipelined_forward",
    "pwl_sigmoid",
    "pwl_tanh",
    "schedule_table",
    "sequential_latency_cycles",
    "stacked_cell_params",
    "stage_assignment_for",
    "stage_partition",
    "utilization",
    "wavefront_forward",
]

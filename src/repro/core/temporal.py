"""Temporal parallelism (paper Section 3): wavefront execution of a
multi-layer recurrent stack.

Two executors over the (layer x time) iteration grid:

* :func:`wavefront_forward` — single-device skewed scan.  At wavefront step
  k every layer fires concurrently (one vmapped fused cell over the layer
  stack), layer i processing timestep ``k - i``.  This is the paper's
  dataflow schedule expressed as data parallelism over layers; it is
  bit-exact against :func:`repro.core.lstm.lstm_ae_sequential`.

* :func:`pipelined_forward` — multi-device pipeline via ``shard_map`` over a
  stage mesh axis.  Each stage owns a contiguous group of layers (chosen by
  the Eq-8-analogue DP in core/balancing.py); inter-stage activations move
  through ``jax.lax.ppermute`` — the depth-1 FIFO of the paper's
  architecture.  Batch is sharded over the data axis at the same time.

Latency semantics match Eq (1): K = T + S - 1 wavefront steps, each costing
the bottleneck stage's per-timestep latency.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.config.core import ModelConfig
from repro.core.balancing import stage_assignment_for
from repro.core.lstm import lstm_cell, stacked_cell_params
from repro.utils import Params


def schedule_table(num_layers: int, timesteps: int) -> list[list[tuple[int, int]]]:
    """Which (layer, timestep) pairs execute at each wavefront step —
    documentation/test helper mirroring Fig. 2's staggered execution."""
    steps = []
    for k in range(timesteps + num_layers - 1):
        active = [(i, k - i) for i in range(num_layers) if 0 <= k - i < timesteps]
        steps.append(active)
    return steps


def wavefront_forward(params: Params, xs: jnp.ndarray, pwl: bool = False) -> jnp.ndarray:
    """Single-device wavefront execution.  xs: (T, B, F) -> (T, B, F).

    All N layers execute in ONE vmapped cell per wavefront step — the
    software rendering of "all modules operate concurrently" (paper §3.2).
    """
    layers = params["layers"]
    n = len(layers)
    t_len, b, f = xs.shape
    stacked, in_sizes, hid_sizes = stacked_cell_params(layers)
    in_max = stacked["wx"].shape[1]
    h_max = stacked["wh"].shape[1]

    k_total = t_len + n - 1
    xs_ext = jnp.pad(xs, ((0, n - 1), (0, 0), (0, in_max - f)))  # drain steps: zeros

    cell = functools.partial(lstm_cell, pwl=pwl)
    vcell = jax.vmap(cell)  # over the layer stack

    h0 = jnp.zeros((n, b, h_max), xs.dtype)
    c0 = jnp.zeros((n, b, h_max), jnp.float32)
    layer_ids = jnp.arange(n)

    def step(carry, inp):
        h, c = carry
        x_k, k = inp
        # layer 0 reads the fresh input; layer i reads layer i-1's carry h
        upstream = jnp.pad(h[:-1], ((0, 0), (0, 0), (0, in_max - h_max)))
        in_buf = jnp.concatenate([x_k[None], upstream], axis=0)   # (N, B, in_max)
        h_new, c_new = vcell(stacked, in_buf, h, c)
        t_for_layer = k - layer_ids
        valid = (t_for_layer >= 0) & (t_for_layer < t_len)        # (N,)
        vmask = valid[:, None, None]
        h = jnp.where(vmask, h_new, h)
        c = jnp.where(vmask, c_new, c)
        return (h, c), h[-1]

    (_, _), ys = jax.lax.scan(step, (h0, c0), (xs_ext, jnp.arange(k_total)))
    return ys[n - 1 :, :, :f]


# ---------------------------------------------------------------------------
# Multi-device pipeline (shard_map over the stage axis)
# ---------------------------------------------------------------------------

def build_stage_params(
    params: Params, cfg: ModelConfig, n_stages: int
) -> tuple[Params, jnp.ndarray, list[int]]:
    """Group layers into stages (balanced DP) and stack padded cells into
    (S, max_layers_per_stage, ...) arrays shardable over the stage axis.

    Returns (stage_params, per-stage layer counts (S,), assignment list).
    """
    layers = params["layers"]
    assignment, _ = stage_assignment_for(cfg.lstm_ae, n_stages)
    n_used = max(assignment) + 1
    groups: list[list] = [[] for _ in range(n_stages)]
    for layer, sid in zip(layers, assignment):
        groups[sid].append(layer)
    max_per = max(len(g) for g in groups)

    stacked_all, _, _ = stacked_cell_params(list(layers))
    in_max = stacked_all["wx"].shape[1]
    h_max = stacked_all["wh"].shape[1]

    def pad_group(group):
        # pad cells to the GLOBAL dims (gate-aligned) before stacking, then
        # pad the layer-count dim up to max_per with zero cells
        if group:
            g_stacked, _, _ = stacked_cell_params(group, in_max=in_max, h_max=h_max)
        else:
            g_stacked = {
                "wx": jnp.zeros((0, in_max, 4 * h_max), jnp.float32),
                "wh": jnp.zeros((0, h_max, 4 * h_max), jnp.float32),
                "b": jnp.zeros((0, 4 * h_max), jnp.float32),
            }
        def pad_leaf(leaf):
            pads = [(0, max_per - leaf.shape[0])] + [(0, 0)] * (leaf.ndim - 1)
            return jnp.pad(leaf, pads)
        return jax.tree.map(pad_leaf, g_stacked)

    stage_params = jax.tree.map(lambda *xs: jnp.stack(xs), *[pad_group(g) for g in groups])
    counts = jnp.array([len(g) for g in groups], jnp.int32)
    return stage_params, counts, assignment


def pipelined_forward(
    stage_params: Params,
    counts: jnp.ndarray,
    xs: jnp.ndarray,
    *,
    mesh: Mesh,
    cfg: ModelConfig,
    stage_axis: str = "model",
    batch_axes: tuple[str, ...] = ("data",),
    pwl: bool = False,
) -> jnp.ndarray:
    """Pipelined wavefront over ``stage_axis``.  xs: (T, B, F) -> (T, B, F).

    stage_params: (S, max_per, ...) stacked padded cells (stage-sharded);
    counts: (S,) layers per stage.  Stages beyond the model depth idle and
    pass activations through — utilisation is reported by the balancing
    module, mirroring the paper's Table-1 discussion.

    Compilation caveat: do NOT trace :func:`build_stage_params` and this
    function into one ``jax.jit`` program when the batch mesh axis is >1 —
    on jax 0.4.37 the SPMD partitioner produces wrong stage weights for
    that combined program.  Compile them separately (the engine's
    "pipelined" schedule in engine/schedules.py does this).
    """
    n_stages = counts.shape[0]
    t_len, b, f = xs.shape
    in_max = stage_params["wx"].shape[2]
    h_max = stage_params["wh"].shape[2]
    max_per = stage_params["wx"].shape[1]
    total_layers = len(cfg.lstm_ae.layer_sizes())
    k_total = t_len + n_stages - 1

    xs_ext = jnp.pad(xs, ((0, n_stages - 1), (0, 0), (0, in_max - f)))

    def stage_fn(sp, cnt, xs_loc):
        sid = jax.lax.axis_index(stage_axis)
        b_loc = xs_loc.shape[1]
        cnt = cnt[0]  # my layer count
        cell = functools.partial(lstm_cell, pwl=pwl)

        h0 = jnp.zeros((max_per, b_loc, h_max), xs_loc.dtype)
        c0 = jnp.zeros((max_per, b_loc, h_max), jnp.float32)
        fifo0 = jnp.zeros((b_loc, in_max), xs_loc.dtype)

        def step(carry, inp):
            h, c, fifo = carry
            x_k, k = inp
            t_mine = k - sid
            active_t = (t_mine >= 0) & (t_mine < t_len)
            cur = jnp.where(sid == 0, x_k, fifo)  # stage input (B, in_max)

            def run_layer(j, acc):
                cur_j, h, c = acc
                pj = jax.tree.map(lambda a: a[0, j], sp)
                h_j, c_j = cell(pj, cur_j, h[j], c[j])
                is_active = (j < cnt) & active_t
                h = h.at[j].set(jnp.where(is_active, h_j, h[j]))
                c = c.at[j].set(jnp.where(is_active, c_j, c[j]))
                nxt = jnp.pad(h_j, ((0, 0), (0, in_max - h_max)))
                cur_j = jnp.where(j < cnt, nxt, cur_j)  # inactive slot: pass through
                return (cur_j, h, c)

            cur_out, h, c = jax.lax.fori_loop(0, max_per, run_layer, (cur, h, c))
            # FIFO hop to the next stage (paper's inter-module queue)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            fifo = jax.lax.ppermute(cur_out, stage_axis, perm)
            return (h, c, fifo), cur_out

        (_, _, _), ys = jax.lax.scan(step, (h0, c0, fifo0), (xs_loc, jnp.arange(k_total)))
        return ys[None]  # (1, K, B_loc, in_max): stage-major for out_specs

    in_specs = (
        P(stage_axis),                 # stage_params stacked on dim 0
        P(stage_axis),                 # counts
        P(None, batch_axes, None),     # xs (K, B, F)
    )
    # out: (S, K, B, in_max) — stage-major stack of every stage's stream
    out_specs = P(stage_axis, None, batch_axes, None)

    fn = shard_map(
        stage_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
    all_streams = fn(stage_params, counts, xs_ext)
    # Stages with zero layers pass activations through, so the final stage's
    # stream is always the model output, delayed by (n_stages - 1) fill steps.
    ys = all_streams[-1, n_stages - 1 :, :, :f]
    return ys

"""LSTM cell / layer / autoencoder — the paper's model family (Section 2).

Gate order is (i, f, g, o) as in Figure 1 of the paper:

    i = sigmoid(Wxi x + Whi h + b)      f = sigmoid(...)
    g = tanh(...)                        o = sigmoid(...)
    c' = f*c + i*g                       h' = o * tanh(c')

The two MVMs (on x_t and on h_{t-1}) are kept separable — ``MVM_X`` and
``MVM_H`` in the paper's accelerator — so the reuse-factor latency model in
core/balancing.py maps one-to-one onto this code, and the fused Pallas
kernel (kernels/lstm_cell.py) can fuse them for the MXU.

The paper uses Q8.24 fixed point with piecewise-linear (PWL) sigmoid/tanh;
``pwl=True`` reproduces that approximation for fidelity experiments.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.utils import Params, split_keys, truncated_normal_init


def pwl_sigmoid(x: jnp.ndarray) -> jnp.ndarray:
    """Piecewise-linear sigmoid (hard sigmoid), the paper's HLS approximation."""
    return jnp.clip(0.25 * x + 0.5, 0.0, 1.0)


def pwl_tanh(x: jnp.ndarray) -> jnp.ndarray:
    """Piecewise-linear tanh (hard tanh)."""
    return jnp.clip(x, -1.0, 1.0)


def _acts(pwl: bool):
    if pwl:
        return pwl_sigmoid, pwl_tanh
    return jax.nn.sigmoid, jnp.tanh


def init_lstm_cell(key: jax.Array, input_size: int, hidden_size: int) -> Params:
    kx, kh = jax.random.split(key)
    return {
        "wx": truncated_normal_init(kx, (input_size, 4 * hidden_size), fan_in=input_size),
        "wh": truncated_normal_init(kh, (hidden_size, 4 * hidden_size), fan_in=hidden_size),
        "b": jnp.zeros((4 * hidden_size,), jnp.float32),
    }


def lstm_cell_specs() -> Params:
    return {"wx": (None, "tp"), "wh": (None, "tp"), "b": ("tp",)}


def lstm_cell(
    params: Params,
    x: jnp.ndarray,
    h: jnp.ndarray,
    c: jnp.ndarray,
    pwl: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One timestep.  x: (B, In); h, c: (B, H) -> (h', c')."""
    sig, tnh = _acts(pwl)
    hidden = h.shape[-1]
    gx = x @ params["wx"].astype(x.dtype)          # MVM_X
    gh = h @ params["wh"].astype(h.dtype)          # MVM_H
    gates = (gx + gh + params["b"].astype(x.dtype)).astype(jnp.float32)
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = sig(f) * c.astype(jnp.float32) + sig(i) * tnh(g)
    h_new = sig(o) * tnh(c_new)
    return h_new.astype(h.dtype), c_new.astype(c.dtype)


def lstm_layer(
    params: Params,
    xs: jnp.ndarray,
    h0: Optional[jnp.ndarray] = None,
    c0: Optional[jnp.ndarray] = None,
    pwl: bool = False,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Scan one LSTM layer over time.  xs: (T, B, In) -> ys (T, B, H)."""
    b = xs.shape[1]
    hidden = params["wh"].shape[0]
    if h0 is None:
        h0 = jnp.zeros((b, hidden), xs.dtype)
    if c0 is None:
        c0 = jnp.zeros((b, hidden), jnp.float32)

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell(params, x_t, h, c, pwl=pwl)
        return (h, c), h

    (h, c), ys = jax.lax.scan(step, (h0, c0), xs)
    return ys, (h, c)


class LSTMAEParams(NamedTuple):
    layers: tuple[Params, ...]


def init_lstm_ae(key: jax.Array, cfg: ModelConfig) -> Params:
    """The paper's LSTM-AE: stacked seq-to-seq LSTM layers (encoder halves
    features to the bottleneck, decoder doubles back; final layer width =
    input width, reconstructing x_t per timestep)."""
    ae = cfg.lstm_ae
    sizes = ae.layer_sizes()
    in_sizes = ae.layer_input_sizes()
    keys = jax.random.split(key, len(sizes))
    layers = tuple(
        init_lstm_cell(k, i, h) for k, i, h in zip(keys, in_sizes, sizes)
    )
    return {"layers": layers}


def lstm_ae_specs(cfg: ModelConfig) -> Params:
    return {"layers": tuple(lstm_cell_specs() for _ in cfg.lstm_ae.layer_sizes())}


def lstm_ae_sequential(
    params: Params, xs: jnp.ndarray, pwl: bool = False
) -> jnp.ndarray:
    """Layer-by-layer execution (the traditional schedule the paper compares
    against): layer i runs over ALL timesteps before layer i+1 starts.
    xs: (T, B, F) -> reconstruction (T, B, F)."""
    ys = xs
    for layer in params["layers"]:
        ys, _ = lstm_layer(layer, ys, pwl=pwl)
    return ys


def lstm_ae_reconstruction_error(
    params: Params, xs: jnp.ndarray, pwl: bool = False
) -> jnp.ndarray:
    """Per-sequence mean squared reconstruction error: (B,)."""
    recon = lstm_ae_sequential(params, xs, pwl=pwl)
    err = jnp.mean(jnp.square(recon.astype(jnp.float32) - xs.astype(jnp.float32)), axis=(0, 2))
    return err


def stacked_cell_params(
    layer_params: Sequence[Params],
    in_max: Optional[int] = None,
    h_max: Optional[int] = None,
) -> tuple[Params, tuple, tuple]:
    """Zero-pad per-layer cells to common (In_max, H_max) and stack.

    Returns (stacked params {wx (N,In,4H), wh (N,H,4H), b (N,4H)},
    in_sizes (N,), hidden_sizes (N,)).  Zero padding is exact AND
    gate-aligned: each of the four gate column blocks is padded to h_max
    separately, so gate boundaries stay at multiples of h_max.  Padded
    input rows/hidden columns contribute nothing to valid gates, and
    downstream layers' padded wx rows null out any padded h values.
    ``in_max``/``h_max`` may be given explicitly (stage grouping pads
    sub-groups to the model-global dims).
    """
    in_sizes = tuple(p["wx"].shape[0] for p in layer_params)
    hid_sizes = tuple(p["wh"].shape[0] for p in layer_params)
    in_max = in_max or max(in_sizes)
    h_max = h_max or max(hid_sizes)

    def pad_cell(p: Params) -> Params:
        i, h4 = p["wx"].shape
        h = p["wh"].shape[0]
        hh = h4 // 4
        # wx/wh columns are 4 gate blocks: pad each gate block to h_max
        def pad_gates(w, rows_to):
            blocks = jnp.split(w, 4, axis=1)
            blocks = [jnp.pad(b_, ((0, rows_to - w.shape[0]), (0, h_max - hh))) for b_ in blocks]
            return jnp.concatenate(blocks, axis=1)
        return {
            "wx": pad_gates(p["wx"], in_max),
            "wh": pad_gates(p["wh"], h_max),
            "b": jnp.concatenate(
                [jnp.pad(b_, (0, h_max - hh)) for b_ in jnp.split(p["b"], 4)]
            ),
        }

    padded = [pad_cell(p) for p in layer_params]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *padded)
    return stacked, in_sizes, hid_sizes

"""Reconstruction-error anomaly detection (the paper's application domain).

LSTM-AEs trained on benign data overfit normal behaviour; anomalous
sequences reconstruct poorly.  Threshold calibration on a benign validation
split + standard detection metrics.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DetectionReport:
    threshold: float
    precision: float
    recall: float
    f1: float
    auroc: float
    anomaly_rate: float


def calibrate_threshold(benign_errors: jnp.ndarray, k_sigma: float = 3.0) -> float:
    """mean + k*std over benign reconstruction errors."""
    e = np.asarray(benign_errors, np.float64)
    return float(e.mean() + k_sigma * e.std())


def auroc(scores: np.ndarray, labels: np.ndarray) -> float:
    """Rank-based AUROC (Mann-Whitney U)."""
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    pos = labels == 1
    n_pos, n_neg = pos.sum(), (~pos).sum()
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    u = ranks[pos].sum() - n_pos * (n_pos + 1) / 2
    return float(u / (n_pos * n_neg))


def evaluate_detection(
    errors: jnp.ndarray, labels: jnp.ndarray, threshold: float
) -> DetectionReport:
    """errors: (B,) reconstruction errors; labels: (B,) 1=anomalous."""
    e = np.asarray(errors, np.float64)
    y = np.asarray(labels).astype(int)
    pred = (e > threshold).astype(int)
    tp = int(((pred == 1) & (y == 1)).sum())
    fp = int(((pred == 1) & (y == 0)).sum())
    fn = int(((pred == 0) & (y == 1)).sum())
    precision = tp / max(1, tp + fp)
    recall = tp / max(1, tp + fn)
    f1 = 2 * precision * recall / max(1e-12, precision + recall)
    return DetectionReport(
        threshold=threshold,
        precision=precision,
        recall=recall,
        f1=f1,
        auroc=auroc(e, y),
        anomaly_rate=float(pred.mean()),
    )

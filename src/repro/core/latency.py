"""Analytical latency / energy model (paper Section 3.2 + Tables 1-3).

``fpga_latency_ms`` evaluates Eq (1) at the paper's 300 MHz clock.  The raw
Eq-1 cycle count is idealised: regressing the paper's own Table 2 against it
shows an empirical cycles-per-timestep ~4.2x Eq-2 (FIFO handshakes,
activation-unit initiation interval, AXI streaming) plus a ~33 us constant
invocation overhead (DMA + kernel start).  Both calibration constants are
exposed and recorded in EXPERIMENTS.md; setting them to (1.0, 0.0) gives the
pure-Eq-1 model.

Energy model: E_per_timestep = P * latency / T with the paper's measured
powers (FPGA 11.5 W, CPU 260 W, GPU 37.5 W midpoints).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.config.core import LSTMAEConfig
from repro.core.balancing import (
    LayerBalance,
    accelerator_latency_cycles,
    balance_model,
    sequential_latency_cycles,
)

CLOCK_HZ = 300e6

# Calibrated against paper Table 2 (see EXPERIMENTS.md §Paper-model fit).
DEFAULT_CYCLE_FACTOR = 4.2       # empirical cycles-per-timestep multiplier
DEFAULT_OVERHEAD_US = 33.0       # invocation overhead (DMA, kernel start)

POWER_W = {"fpga": 11.5, "cpu": 260.0, "gpu": 37.5}

# Table 1: the paper's chosen bottleneck reuse factors per model.
PAPER_RH_M = {
    "lstm-ae-f32-d2": 1,
    "lstm-ae-f64-d2": 4,
    "lstm-ae-f32-d6": 1,
    "lstm-ae-f64-d6": 8,
}


@dataclass(frozen=True)
class LatencyEstimate:
    timesteps: int
    cycles: int
    ms: float
    schedule: str            # "dataflow" (Eq 1) or "sequential"


def fpga_latency_ms(
    cfg: LSTMAEConfig,
    timesteps: int,
    rh_m: int,
    *,
    schedule: str = "dataflow",
    cycle_factor: float = DEFAULT_CYCLE_FACTOR,
    overhead_us: float = DEFAULT_OVERHEAD_US,
) -> LatencyEstimate:
    balances = balance_model(cfg, rh_m)
    if schedule == "dataflow":
        cycles = accelerator_latency_cycles(timesteps, balances)
    elif schedule == "sequential":
        cycles = sequential_latency_cycles(timesteps, balances)
    else:
        raise ValueError(schedule)
    ms = (cycles * cycle_factor / CLOCK_HZ) * 1e3 + overhead_us * 1e-3
    return LatencyEstimate(timesteps=timesteps, cycles=cycles, ms=ms, schedule=schedule)


def serving_floor_ms(
    cfg: LSTMAEConfig,
    timesteps: int,
    *,
    rh_m: int | None = None,
    arch: str | None = None,
    schedule: str = "dataflow",
) -> float:
    """Model-predicted compute floor (ms) for one served bucket shape.

    The feedforward prior for the adaptive batching controller
    (:mod:`repro.control`): the latency model bounds how fast a flush of
    this bucket can possibly finish, so the controller subtracts the
    floor from the declared p95 SLO and searches only the residual
    (queueing + batching slack) instead of rediscovering physics by trial.
    ``rh_m`` defaults to the paper's Table-1 reuse factor for ``arch``
    (1 when the arch is unknown).
    """
    if rh_m is None:
        rh_m = PAPER_RH_M.get(arch or "", 1)
    return fpga_latency_ms(cfg, int(timesteps), int(rh_m), schedule=schedule).ms


def energy_per_timestep_mj(latency_ms: float, timesteps: int, platform: str) -> float:
    return POWER_W[platform] * latency_ms / max(1, timesteps)


def speedup_table(
    cfg: LSTMAEConfig, rh_m: int, timesteps: tuple[int, ...] = (1, 2, 4, 6, 16, 64)
) -> list[dict]:
    """Dataflow-vs-sequential latency on the paper's own cycle model —
    isolates the temporal-parallelism win from platform effects."""
    rows = []
    for t in timesteps:
        df = fpga_latency_ms(cfg, t, rh_m, schedule="dataflow")
        sq = fpga_latency_ms(cfg, t, rh_m, schedule="sequential")
        rows.append(
            {
                "timesteps": t,
                "dataflow_ms": df.ms,
                "sequential_ms": sq.ms,
                # schedule win on raw cycles (platform overheads excluded)
                "speedup": sq.cycles / df.cycles,
                "dataflow_cycles": df.cycles,
                "sequential_cycles": sq.cycles,
            }
        )
    return rows

"""Adaptive micro-batching controller: p95-vs-SLO feedback over
``max_batch`` / ``max_wait_ms`` with the latency model as feedforward.

The controller closes the loop the ROADMAP asked for: each tick it reads
the sensors PR 7 built (request p95, batch-fill ratio, queue depth,
windowed arrival rate) and nudges the two batching knobs.  Three design
rules keep it from wrecking the thing it tunes:

* **Feedforward prior** — :func:`repro.core.latency.serving_floor_ms`
  predicts the compute floor for the served bucket shape, so the
  controller treats ``slo - floor`` as its whole search space (the
  *residual budget*) and never commands a wait that alone blows the SLO.
  An SLO at or under the floor is declared infeasible once instead of
  being chased forever.
* **Hysteresis** — it acts only after ``patience`` consecutive ticks out
  of band (over the SLO, or under ``low_band * slo`` with room to relax)
  and then goes quiet for ``cooldown_ticks``, so one noisy percentile
  sample never flaps the knobs.
* **Bounded actuation** — one knob, one bounded multiplicative step per
  action; ``max_batch`` moves only inside ``[1, lanes]`` where the
  compiled (lanes, bucket_T, F) shapes are already minted, so adaptation
  NEVER causes a recompile (the compile cache is the one thing a latency
  controller must not oscillate).

Pure decision logic — no I/O, no threads; the owning plane applies the
returned knobs and journals the decision.
"""
from __future__ import annotations

from typing import Optional


class BatchingController:
    """One `decide()` per control tick -> hold / shrink_wait / grow_wait /
    grow_batch / shrink_batch, with the reason attached."""

    def __init__(
        self,
        *,
        slo_p95_ms: float,
        floor_ms: float,
        lanes: int,
        min_wait_ms: float = 0.25,
        low_band: float = 0.6,
        wait_budget_frac: float = 0.8,
        step: float = 2.0,
        patience: int = 2,
        cooldown_ticks: int = 2,
        full_fill: float = 0.9,
    ):
        if slo_p95_ms <= 0:
            raise ValueError(f"slo_p95_ms must be > 0, got {slo_p95_ms}")
        self.slo_p95_ms = float(slo_p95_ms)
        self.floor_ms = float(floor_ms)
        self.lanes = int(lanes)
        self.min_wait_ms = float(min_wait_ms)
        self.low_band = float(low_band)
        self.step = float(step)
        self.patience = int(patience)
        self.cooldown_ticks = int(cooldown_ticks)
        self.full_fill = float(full_fill)
        # residual the controller is allowed to spend on queueing/batching
        self.budget_ms = self.slo_p95_ms - self.floor_ms
        self.wait_cap_ms = max(self.min_wait_ms, wait_budget_frac * self.budget_ms)
        self._hot = 0       # consecutive ticks over the SLO
        self._cold = 0      # consecutive ticks far under it
        self._cooldown = 0  # ticks to stay quiet after an action
        self._infeasible_reported = False
        self.actions = 0

    @property
    def feasible(self) -> bool:
        return self.budget_ms > 0.0

    def prior_knobs(self, max_batch: int, max_wait_ms: float) -> dict:
        """Feedforward starting point: spend a quarter of the residual
        budget on batching wait (capped), before any feedback has run."""
        if not self.feasible:
            return {"max_batch": max_batch, "max_wait_ms": 0.0}
        wait = min(self.wait_cap_ms, max(self.min_wait_ms, 0.25 * self.budget_ms))
        return {
            "max_batch": min(max(1, int(max_batch)), self.lanes),
            "max_wait_ms": min(float(max_wait_ms), wait)
            if max_wait_ms else wait,
        }

    def decide(
        self,
        *,
        p95_ms: float,
        fill: float,
        depth: int,
        arrival_rps: float,
        max_batch: int,
        max_wait_ms: float,
    ) -> dict:
        """One control tick.  Returns a decision record::

            {"action", "reason", "knobs" (None when holding),
             "p95_ms", "slo_ms", "fill", "depth", "arrival_rps"}
        """
        obs = {
            "p95_ms": float(p95_ms), "slo_ms": self.slo_p95_ms,
            "fill": float(fill), "depth": int(depth),
            "arrival_rps": float(arrival_rps),
        }

        def out(action: str, reason: str, knobs: Optional[dict] = None) -> dict:
            if knobs is not None:
                self.actions += 1
                self._cooldown = self.cooldown_ticks
                self._hot = self._cold = 0
            return {"action": action, "reason": reason, "knobs": knobs, **obs}

        if not self.feasible:
            # the model says the SLO is unreachable even with zero wait —
            # pin the wait to zero once and say so, don't thrash
            if not self._infeasible_reported:
                self._infeasible_reported = True
                return out(
                    "pin_wait", "slo_infeasible",
                    {"max_wait_ms": 0.0},
                )
            return out("hold", "slo_infeasible")

        if self._cooldown > 0:
            self._cooldown -= 1
            return out("hold", "cooldown")

        if p95_ms > self.slo_p95_ms:
            self._hot += 1
            self._cold = 0
        elif p95_ms < self.low_band * self.slo_p95_ms and p95_ms > 0.0:
            self._cold += 1
            self._hot = 0
        else:
            self._hot = self._cold = 0
            return out("hold", "in_band")

        if self._hot >= self.patience:
            if fill >= self.full_fill and max_batch < self.lanes:
                # batches already full: throughput-bound, widen the flush
                # (still inside the pre-compiled lane count)
                new_batch = min(self.lanes, max(max_batch + 1,
                                                int(max_batch * self.step)))
                return out("grow_batch", "over_slo_batches_full",
                           {"max_batch": new_batch})
            if max_wait_ms > self.min_wait_ms:
                # wait-bound: flush sooner
                new_wait = max(self.min_wait_ms, max_wait_ms / self.step)
                return out("shrink_wait", "over_slo_wait_bound",
                           {"max_wait_ms": new_wait})
            if max_batch > 1 and fill < self.full_fill:
                # nothing left on the wait axis and batches run empty:
                # smaller flush trigger trims residual queueing
                return out("shrink_batch", "over_slo_wait_floored",
                           {"max_batch": max(1, max_batch // 2)})
            return out("hold", "over_slo_saturated")

        if self._cold >= self.patience and max_wait_ms < self.wait_cap_ms:
            # comfortably under the SLO: trade latency headroom for fill
            new_wait = min(self.wait_cap_ms,
                           max(max_wait_ms * self.step, 2 * self.min_wait_ms))
            return out("grow_wait", "under_slo_headroom",
                       {"max_wait_ms": new_wait})

        return out("hold", "waiting_for_patience")

    def describe(self) -> dict:
        return {
            "slo_p95_ms": self.slo_p95_ms,
            "floor_ms": self.floor_ms,
            "budget_ms": self.budget_ms,
            "wait_cap_ms": self.wait_cap_ms,
            "feasible": self.feasible,
            "actions": self.actions,
        }

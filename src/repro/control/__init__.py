"""Adaptive serving control plane (ROADMAP: "SLO-driven adaptive
serving — close the loop from telemetry to knobs").

The paper fixes its accelerator's operating point at synthesis time;
SHARP (PAPERS.md) argues an RNN accelerator should instead *adapt* its
configuration to the workload.  This package is that argument applied
to the serving tier: a declared p95 latency SLO plus three cooperating
controllers that read the PR-7 sensors and actuate the knobs the stack
already exposes —

* :class:`~repro.control.batching.BatchingController` — per-tick
  ``max_batch`` / ``max_wait_ms`` tuning with the
  :mod:`repro.core.latency` model as feedforward prior, hysteresis, and
  bounded steps that never mint a new compiled shape;
* :class:`~repro.control.admission.AdmissionController` — priority
  classes over the flat overload error (shed lowest class first,
  per-class counters, per-tenant token buckets);
* :class:`~repro.control.autoscale.Autoscaler` — worker count between
  declared min/max from windowed arrival rate and saturation, executed
  as zero-drop snapshot-handoff drains.

Wiring lives in :mod:`repro.control.plane`: :func:`enable_control`
attaches a :class:`GatewayControl` to one in-process gateway (pump-
driven ticks), :class:`ControlLoop` runs supervisor-side over a
:class:`~repro.gateway.workers.WorkerFront`.  Every decision is
journaled to ``controller.jsonl``.
"""
from repro.control.admission import AdmissionController, TokenBucket
from repro.control.autoscale import Autoscaler
from repro.control.batching import BatchingController
from repro.control.plane import (
    CONTROLLER_LOG,
    ControlConfig,
    ControlLoop,
    GatewayControl,
    enable_control,
)

__all__ = [
    "AdmissionController",
    "Autoscaler",
    "BatchingController",
    "CONTROLLER_LOG",
    "ControlConfig",
    "ControlLoop",
    "GatewayControl",
    "TokenBucket",
    "enable_control",
]

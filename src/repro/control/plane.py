"""Control-plane wiring: config, per-gateway control, supervisor loop.

Two deployment shapes share the same controllers:

* **In-process** (:class:`GatewayControl`, via :func:`enable_control`) —
  attaches to one :class:`~repro.gateway.AnomalyGateway` exactly like
  durability does (``gateway.control``), gates ``submit()`` through the
  admission controller, and rides the transport's pump loop via
  :meth:`GatewayControl.maybe_tick` — no thread of its own, same
  single-owner discipline as the rest of the gateway.
* **Multi-worker** (:class:`ControlLoop`) — a supervisor-side daemon
  thread over a :class:`~repro.gateway.workers.WorkerFront`: each tick it
  reads the front-aggregated ``stats()`` (merged histograms, windowed
  rates), runs the batching controller and the autoscaler, fans batching
  knobs out over the existing control pipes (the same path
  ``recalibrate`` takes), and scales the worker fleet with zero-drop
  drain on the way down.  Admission runs worker-side (each worker's
  gateway gets its own :class:`~repro.control.admission.AdmissionController`
  from the factory), because shedding must happen where requests arrive.

Every decision — hold or act — is journaled to ``controller.jsonl``
(:class:`repro.obs.events.EventLog` schema: ``{"ts", "kind":
"control_tick", "tick", "scope", "p95_ms", "slo_ms", "action",
"reason", ...}``) so an operator can replay exactly why the plane did
what it did.

Percentile sensing is *windowed*: telemetry histograms are lifetime
accumulators, so each tick diffs the current bucket counts against the
previous tick's snapshot and computes p95 over the delta — the
controller reacts to the last tick's traffic, not the whole run's.
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Mapping, Optional

from repro.core.latency import serving_floor_ms
from repro.gateway.queue import bucket_for
from repro.obs.events import EventLog
from repro.obs.histogram import Histogram

from repro.control.admission import AdmissionController
from repro.control.autoscale import Autoscaler
from repro.control.batching import BatchingController

CONTROLLER_LOG = "controller.jsonl"


@dataclass
class ControlConfig:
    """Declared operating point for the control plane.

    ``slo_p95_ms`` None disables the batching controller (admission and
    autoscaling can still run); ``priority_classes`` 1 keeps flat
    admission; ``autoscale_min``/``autoscale_max`` None disables the
    autoscaler.  ``worker_rps`` overrides the latency-model-derived
    per-worker capacity estimate; ``floor_timesteps`` picks the bucket
    shape the feedforward floor is computed for (default: the
    ``max_seq_len`` bucket, the conservative choice).
    """

    slo_p95_ms: Optional[float] = None
    tick_interval_s: float = 1.0
    priority_classes: int = 1
    tenant_rate: Optional[float] = None
    tenant_burst: Optional[float] = None
    autoscale_min: Optional[int] = None
    autoscale_max: Optional[int] = None
    worker_rps: Optional[float] = None
    floor_timesteps: Optional[int] = None
    arch: Optional[str] = None
    min_wait_ms: float = 0.25
    patience: int = 2
    cooldown_ticks: int = 2
    extra: dict = field(default_factory=dict)

    @property
    def autoscaling(self) -> bool:
        return self.autoscale_min is not None and self.autoscale_max is not None


def _delta_hist(cur: Mapping[int, int], prev: Mapping[int, int]) -> Histogram:
    """Histogram of the samples recorded between two bucket snapshots."""
    out = Histogram()
    for idx, n in cur.items():
        d = int(n) - int(prev.get(idx, 0))
        if d > 0:
            out.counts[int(idx)] = d
            out.count += d
    return out


def _estimate_worker_rps(cfg: ControlConfig, floor_ms: float, lanes: int) -> float:
    """Per-worker sustainable score rate: one full flush per compute
    floor, derated 50% for assemble/wire overheads the model excludes."""
    if cfg.worker_rps is not None:
        return float(cfg.worker_rps)
    per_flush_s = max(floor_ms, 1e-3) / 1e3
    return 0.5 * max(1, lanes) / per_flush_s


class GatewayControl:
    """In-process control: admission gate + pump-driven batching ticks."""

    def __init__(
        self,
        gateway,
        cfg: ControlConfig,
        *,
        events: Optional[EventLog] = None,
    ):
        self.gateway = gateway
        self.cfg = cfg
        self.events = events if events is not None else gateway.events
        clock = gateway.telemetry.now
        self._clock = clock
        self.admission = AdmissionController(
            classes=cfg.priority_classes,
            tenant_rate=cfg.tenant_rate,
            tenant_burst=cfg.tenant_burst,
            telemetry=gateway.telemetry,
            clock=clock,
        )
        self.batching: Optional[BatchingController] = None
        self.floor_ms = 0.0
        if cfg.slo_p95_ms is not None:
            t_floor = bucket_for(cfg.floor_timesteps
                                 or gateway.batcher.max_seq_len)
            self.floor_ms = serving_floor_ms(
                gateway.engine.cfg.lstm_ae, t_floor, arch=cfg.arch,
            )
            self.batching = BatchingController(
                slo_p95_ms=cfg.slo_p95_ms,
                floor_ms=self.floor_ms,
                lanes=gateway.batcher.lanes,
                min_wait_ms=cfg.min_wait_ms,
                patience=cfg.patience,
                cooldown_ticks=cfg.cooldown_ticks,
            )
            gateway.batcher.set_knobs(**self.batching.prior_knobs(
                gateway.batcher.max_batch, gateway.batcher.max_wait_ms,
            ))
        self.ticks = 0
        self.last_decision: Optional[dict] = None
        self._next_tick = clock() + cfg.tick_interval_s
        self._prev_req_counts: dict[int, int] = {}
        self._prev_fill = (0.0, 0.0)  # (batch.filled, batch.slots)

    # -- admission gate (called from gateway.submit) -----------------------

    def admit(self, priority=None, tenant=None) -> int:
        batcher = self.gateway.batcher
        return self.admission.admit(
            depth=batcher.queue_depth,
            max_queue=batcher.max_queue,
            priority=priority,
            tenant=tenant,
        )

    # -- tick loop (ridden by the transport's pump) ------------------------

    def maybe_tick(self, now: Optional[float] = None) -> Optional[dict]:
        now = self._clock() if now is None else now
        if now < self._next_tick:
            return None
        self._next_tick = now + self.cfg.tick_interval_s
        return self.tick()

    def tick(self) -> dict:
        tel = self.gateway.telemetry
        self.ticks += 1
        req = tel.request_histogram
        window = _delta_hist(req.counts, self._prev_req_counts)
        self._prev_req_counts = dict(req.counts)
        filled = tel.counters.get("batch.filled", 0.0)
        slots = tel.counters.get("batch.slots", 0.0)
        d_filled = filled - self._prev_fill[0]
        d_slots = slots - self._prev_fill[1]
        self._prev_fill = (filled, slots)
        fill = (d_filled / d_slots) if d_slots else 0.0
        batcher = self.gateway.batcher
        decision: dict = {"action": "hold", "reason": "no_slo",
                          "knobs": None, "p95_ms": window.percentile(95),
                          "slo_ms": None}
        if self.batching is not None:
            decision = self.batching.decide(
                p95_ms=window.percentile(95),
                fill=fill,
                depth=batcher.queue_depth,
                arrival_rps=tel.windowed_rate("queue.submitted"),
                max_batch=batcher.max_batch,
                max_wait_ms=batcher.max_wait_ms,
            )
            if decision["knobs"]:
                decision["applied"] = batcher.set_knobs(**decision["knobs"])
        tel.count("control.ticks")
        self.last_decision = decision
        self.events.emit("control_tick", scope="gateway", tick=self.ticks,
                         **{k: v for k, v in decision.items() if k != "knobs"})
        return decision

    def describe(self) -> dict:
        out = {
            "ticks": self.ticks,
            "tick_interval_s": self.cfg.tick_interval_s,
            "slo_p95_ms": self.cfg.slo_p95_ms,
            "floor_ms": self.floor_ms,
            "admission": self.admission.describe(),
        }
        if self.batching is not None:
            out["batching"] = self.batching.describe()
        if self.last_decision is not None:
            out["last"] = {k: v for k, v in self.last_decision.items()
                           if k != "knobs"}
        return out


def enable_control(
    gateway,
    cfg: ControlConfig,
    *,
    event_dir: Optional[str] = None,
) -> GatewayControl:
    """Attach a control plane to one gateway (``gateway.control``), the
    same opt-in shape as ``enable_durability``.  ``event_dir`` points the
    decision journal at ``<event_dir>/controller.jsonl``; omitted, the
    gateway's own event log carries the ``control_tick`` records."""
    events = None
    if event_dir is not None:
        events = EventLog(os.path.join(os.fspath(event_dir), CONTROLLER_LOG))
    control = GatewayControl(gateway, cfg, events=events)
    gateway.control = control
    return control


class ControlLoop:
    """Supervisor-side control thread over a :class:`WorkerFront`.

    Owns nothing the workers own: it senses through ``front.stats()``
    (merged histograms, summed windowed rates), actuates batching through
    the ``control`` fan-out op, and actuates fleet size through
    ``front.scale_up()`` / ``front.scale_down()`` (drain-based, zero
    drop).  All cross-thread state is guarded by ``_lock`` — ``stats()``
    readers call :meth:`describe` from other threads.
    """

    def __init__(
        self,
        front,
        cfg: ControlConfig,
        *,
        lanes: int = 16,
        max_queue: int = 1024,
        model_cfg=None,
        event_dir: Optional[str] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.front = front
        self.cfg = cfg
        self._clock = clock
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.events = EventLog(
            os.path.join(os.fspath(event_dir), CONTROLLER_LOG)
            if event_dir is not None else None
        )
        self.floor_ms = 0.0
        if model_cfg is not None:
            t_floor = bucket_for(cfg.floor_timesteps or 64)
            self.floor_ms = serving_floor_ms(model_cfg, t_floor, arch=cfg.arch)
        self.batching: Optional[BatchingController] = None
        if cfg.slo_p95_ms is not None:
            self.batching = BatchingController(
                slo_p95_ms=cfg.slo_p95_ms,
                floor_ms=self.floor_ms,
                lanes=lanes,
                min_wait_ms=cfg.min_wait_ms,
                patience=cfg.patience,
                cooldown_ticks=cfg.cooldown_ticks,
            )
        self.autoscaler: Optional[Autoscaler] = None
        if cfg.autoscaling:
            self.autoscaler = Autoscaler(
                min_workers=cfg.autoscale_min,
                max_workers=cfg.autoscale_max,
                worker_rps=_estimate_worker_rps(cfg, self.floor_ms, lanes),
            )
        self.max_queue = int(max_queue)
        self.ticks = 0
        self.last_decision: Optional[dict] = None
        self._prev_req_counts: dict[int, int] = {}
        self._prev_fill = (0.0, 0.0)
        self._knobs: dict = {}
        # attach like enable_control does for a gateway: the front's
        # stats() picks up describe() and shutdown() stops the thread
        front.control = self

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "ControlLoop":
        if self._thread is not None:
            raise RuntimeError("control loop already started")
        self._thread = threading.Thread(
            target=self._run, name="control-loop", daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        self.events.close()

    def _run(self) -> None:
        while not self._stop.wait(self.cfg.tick_interval_s):
            try:
                self.tick()
            except Exception:
                # the control plane must never take the data plane down
                import logging
                logging.getLogger(__name__).exception("control tick failed")

    # -- one tick ----------------------------------------------------------

    def tick(self, stats: Optional[Mapping] = None) -> dict:
        """Sense -> decide -> actuate once.  ``stats`` is injectable so
        tests and the benchmark can drive ticks without the thread."""
        s = dict(stats) if stats is not None else self.front.stats()
        hist = Histogram.from_dict(
            (s.get("histograms") or {}).get("request_ms")
        )
        with self._lock:
            self.ticks += 1
            tick_no = self.ticks
            window = _delta_hist(hist.counts, self._prev_req_counts)
            self._prev_req_counts = dict(hist.counts)
            counters = s.get("counters") or {}
            filled = counters.get("batch.filled", 0.0)
            slots = counters.get("batch.slots", 0.0)
            d_filled = filled - self._prev_fill[0]
            d_slots = slots - self._prev_fill[1]
            self._prev_fill = (filled, slots)
        fill = (d_filled / d_slots) if d_slots else 0.0
        p95 = window.percentile(95)
        arrival = float(s.get("arrival_rps_window", 0.0))
        depth = int(s.get("queue_depth", 0))
        workers_sec = s.get("workers") or {}
        n_workers = int(workers_sec.get("count", 0) or 0)
        decision: dict = {"p95_ms": p95, "slo_ms": self.cfg.slo_p95_ms,
                          "action": "hold", "reason": "no_slo"}

        if self.batching is not None:
            with self._lock:
                knobs = dict(self._knobs)
            b = self.batching.decide(
                p95_ms=p95, fill=fill, depth=depth, arrival_rps=arrival,
                max_batch=int(knobs.get("max_batch", 0))
                or int(s.get("max_batch", self.batching.lanes)),
                max_wait_ms=float(knobs.get("max_wait_ms", 0.0))
                or float(self.cfg.extra.get("max_wait_ms", 1.0)),
            )
            decision.update(b)
            if b["knobs"]:
                applied = self.front.set_batching(**b["knobs"])
                decision["applied"] = applied
                with self._lock:
                    self._knobs.update(b["knobs"])

        if self.autoscaler is not None:
            a = self.autoscaler.decide(
                arrival_rps=arrival, workers=max(n_workers, 1),
                queue_depth=depth, max_queue=self.max_queue,
            )
            decision["scale"] = {"delta": a["delta"], "reason": a["reason"],
                                 "utilization": a["utilization"]}
            if a["delta"] > 0:
                decision["scale"]["worker"] = self.front.scale_up()
            elif a["delta"] < 0:
                decision["scale"]["drain"] = self.front.scale_down()

        with self._lock:
            self.last_decision = decision
        self.events.emit(
            "control_tick", scope="front", tick=tick_no,
            **{k: v for k, v in decision.items() if k != "knobs"},
        )
        return decision

    def describe(self) -> dict:
        with self._lock:
            out = {
                "ticks": self.ticks,
                "tick_interval_s": self.cfg.tick_interval_s,
                "slo_p95_ms": self.cfg.slo_p95_ms,
                "floor_ms": self.floor_ms,
                "knobs": dict(self._knobs),
                "last": dict(self.last_decision or {}),
            }
        out["last"].pop("knobs", None)
        if self.batching is not None:
            out["batching"] = self.batching.describe()
        if self.autoscaler is not None:
            out["autoscale"] = self.autoscaler.describe()
        return out

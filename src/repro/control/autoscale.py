"""Worker autoscaler: arrival-rate-driven worker count between bounds.

SHARP's argument in hardware — an RNN accelerator should adapt its
configuration to the workload instead of shipping one operating point —
applied to the worker fleet: the supervisor already knows how to spawn
workers and drain them with zero-loss snapshot handoff (PR 6), so worker
count is just one more actuated knob.  :class:`Autoscaler` is the pure
decision half: each tick it compares the windowed arrival rate against
the fleet's estimated service capacity (``workers * worker_rps``, where
``worker_rps`` comes from the latency model or measurement) plus queue
saturation, and votes +1 / 0 / -1 inside ``[min_workers, max_workers]``.

Same discipline as the batching controller: ``patience`` consecutive
out-of-band ticks before any action, a cooldown after each one (worker
spawn has real cost — compile warm-up — so flapping is worse here), and
a bounded step of one worker per action.  Scale-down is decided here but
*executed* by the supervisor as a drain, never a kill.
"""
from __future__ import annotations


class Autoscaler:
    """Utilization-band voter over the worker count."""

    def __init__(
        self,
        *,
        min_workers: int,
        max_workers: int,
        worker_rps: float,
        high_util: float = 0.85,
        low_util: float = 0.35,
        depth_high: float = 0.5,
        patience: int = 2,
        cooldown_ticks: int = 3,
    ):
        if min_workers < 1 or max_workers < min_workers:
            raise ValueError(
                f"need 1 <= min <= max, got {min_workers}:{max_workers}"
            )
        if worker_rps <= 0:
            raise ValueError(f"worker_rps must be > 0, got {worker_rps}")
        self.min_workers = int(min_workers)
        self.max_workers = int(max_workers)
        self.worker_rps = float(worker_rps)
        self.high_util = float(high_util)
        self.low_util = float(low_util)
        self.depth_high = float(depth_high)
        self.patience = int(patience)
        self.cooldown_ticks = int(cooldown_ticks)
        self._hot = 0
        self._cold = 0
        self._cooldown = 0
        self.actions = 0

    def decide(
        self,
        *,
        arrival_rps: float,
        workers: int,
        queue_depth: int = 0,
        max_queue: int = 1024,
    ) -> dict:
        """One tick -> ``{"delta", "reason", "utilization", ...}`` with
        ``delta`` in {-1, 0, +1} already clamped to the bounds."""
        capacity = max(workers, 1) * self.worker_rps
        util = float(arrival_rps) / capacity
        depth_frac = float(queue_depth) / max(1, workers * max_queue)
        obs = {
            "utilization": util, "depth_frac": depth_frac,
            "arrival_rps": float(arrival_rps), "workers": int(workers),
            "worker_rps": self.worker_rps,
        }

        def out(delta: int, reason: str) -> dict:
            if delta:
                self.actions += 1
                self._cooldown = self.cooldown_ticks
                self._hot = self._cold = 0
            return {"delta": delta, "reason": reason, **obs}

        if workers < self.min_workers:
            return out(+1, "below_min")
        if workers > self.max_workers:
            return out(-1, "above_max")
        if self._cooldown > 0:
            self._cooldown -= 1
            return out(0, "cooldown")

        if util > self.high_util or depth_frac > self.depth_high:
            self._hot += 1
            self._cold = 0
        elif util < self.low_util and depth_frac < 0.1:
            self._cold += 1
            self._hot = 0
        else:
            self._hot = self._cold = 0
            return out(0, "in_band")

        if self._hot >= self.patience:
            if workers >= self.max_workers:
                return out(0, "saturated_at_max")
            return out(+1, "over_capacity")
        if self._cold >= self.patience:
            if workers <= self.min_workers:
                return out(0, "idle_at_min")
            return out(-1, "under_utilized")
        return out(0, "waiting_for_patience")

    def describe(self) -> dict:
        return {
            "min_workers": self.min_workers,
            "max_workers": self.max_workers,
            "worker_rps": self.worker_rps,
            "high_util": self.high_util,
            "low_util": self.low_util,
            "actions": self.actions,
        }

"""Priority-aware admission control with per-tenant token buckets.

Flat backpressure (``GatewayOverloadedError`` at ``max_queue`` pending)
sheds whoever arrives last, which under overload is exactly backwards:
the paper's deployment story is a detector guarding real equipment, so
an alert-path request must survive a flood of best-effort backfill.
:class:`AdmissionController` layers declared priority classes on top of
the same queue-depth signal — class 0 (highest) keeps the flat limit
verbatim, class ``k`` of ``n`` is admitted only while the queue is under
``(1 - k/n)`` of ``max_queue`` — so shedding starts at the bottom class
and climbs, and a deployment with one class (or clients that never send
``priority``) behaves bit-for-bit like the flat gateway.

Each shed increments a per-class counter (``admission.shed_p<k>``,
rendered on ``/metrics`` like any counter) so shed *fairness* is
observable, and an optional per-tenant token bucket rate-limits chatty
tenants before they reach the queue at all (``admission.rate_limited``).

Single-threaded like the gateway that owns it; ``clock`` is injectable.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro.gateway.queue import GatewayOverloadedError
from repro.gateway.telemetry import Telemetry


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill up to ``burst``."""

    __slots__ = ("rate", "burst", "_tokens", "_t_last")

    def __init__(self, rate: float, burst: float, now: float):
        if rate <= 0 or burst <= 0:
            raise ValueError("rate and burst must be > 0")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._t_last = now

    def try_take(self, now: float, n: float = 1.0) -> bool:
        elapsed = max(0.0, now - self._t_last)
        self._tokens = min(self.burst, self._tokens + elapsed * self.rate)
        self._t_last = now
        if self._tokens >= n:
            self._tokens -= n
            return True
        return False

    @property
    def tokens(self) -> float:
        return self._tokens


class AdmissionController:
    """Depth-thresholded priority classes + optional tenant rate limit."""

    def __init__(
        self,
        *,
        classes: int = 1,
        tenant_rate: Optional[float] = None,
        tenant_burst: Optional[float] = None,
        telemetry: Optional[Telemetry] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if classes < 1:
            raise ValueError(f"need at least one priority class, got {classes}")
        self.classes = int(classes)
        self.tenant_rate = float(tenant_rate) if tenant_rate else None
        self.tenant_burst = (
            float(tenant_burst) if tenant_burst
            else (2.0 * self.tenant_rate if self.tenant_rate else None)
        )
        self.telemetry = telemetry or Telemetry(clock=clock)
        self._clock = clock
        self._buckets: dict[str, TokenBucket] = {}

    # -- policy ------------------------------------------------------------

    def normalize(self, priority) -> int:
        """Clamp a wire ``priority`` into [0, classes); None (legacy
        clients) maps to class 0 — exactly the old flat behaviour."""
        if priority is None:
            return 0
        return min(max(0, int(priority)), self.classes - 1)

    def depth_limit(self, klass: int, max_queue: int) -> int:
        """Queue depth below which class ``klass`` is still admitted.

        Class 0's limit is ``max_queue`` itself (flat semantics kept
        verbatim); each lower class gives up an equal share of headroom,
        so under rising depth class ``n-1`` sheds first and class 0 last.
        """
        if klass == 0:
            return int(max_queue)
        return max(1, int(max_queue * (1.0 - klass / self.classes)))

    def admit(
        self,
        *,
        depth: int,
        max_queue: int,
        priority=None,
        tenant: Optional[str] = None,
    ) -> int:
        """Gate one request before it reaches the queue.

        Returns the normalized priority class on admission; raises
        :class:`GatewayOverloadedError` on shed (per-class counter) or
        tenant rate limit.  The queue's own ``max_queue`` check still
        runs afterwards — this controller only ever sheds *earlier*.
        """
        klass = self.normalize(priority)
        if self.tenant_rate is not None:
            key = str(tenant) if tenant is not None else "_default"
            bucket = self._buckets.get(key)
            if bucket is None:
                bucket = self._buckets[key] = TokenBucket(
                    self.tenant_rate, self.tenant_burst, self._clock()
                )
            if not bucket.try_take(self._clock()):
                self.telemetry.count("admission.rate_limited")
                raise GatewayOverloadedError(
                    f"tenant {key!r} over rate limit "
                    f"({self.tenant_rate:g} req/s, burst {self.tenant_burst:g})"
                )
        if depth >= self.depth_limit(klass, max_queue):
            self.telemetry.count(f"admission.shed_p{klass}")
            raise GatewayOverloadedError(
                f"queue depth {depth} at or past class-{klass} admission "
                f"limit {self.depth_limit(klass, max_queue)} "
                f"(max_queue={max_queue}); shed"
            )
        self.telemetry.count(f"admission.admitted_p{klass}")
        return klass

    # -- introspection -----------------------------------------------------

    def describe(self) -> dict:
        c = self.telemetry.counters
        return {
            "classes": self.classes,
            "tenant_rate": self.tenant_rate,
            "tenant_burst": self.tenant_burst,
            "tenants_tracked": len(self._buckets),
            "shed_by_class": {
                str(k): c.get(f"admission.shed_p{k}", 0.0)
                for k in range(self.classes)
            },
            "rate_limited": c.get("admission.rate_limited", 0.0),
        }

"""Architecture registry: ``--arch <id>`` resolves here.

Each ``repro/configs/<id>.py`` module defines ``CONFIG`` (the exact published
configuration) and ``reduced()`` (a smoke-test-sized config of the same
family).  Importing this module populates the registry lazily so that config
files stay single-purpose and greppable.
"""
from __future__ import annotations

import importlib

from repro.config.core import ModelConfig

# id -> module path (one file per assigned architecture + the paper's own four)
_ARCH_MODULES: dict[str, str] = {
    # assigned pool (10)
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "olmo-1b": "repro.configs.olmo_1b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    # the paper's own models (Section 4.1)
    "lstm-ae-f32-d2": "repro.configs.lstm_ae_f32_d2",
    "lstm-ae-f32-d6": "repro.configs.lstm_ae_f32_d6",
    "lstm-ae-f64-d2": "repro.configs.lstm_ae_f64_d2",
    "lstm-ae-f64-d6": "repro.configs.lstm_ae_f64_d6",
}

REGISTRY = dict(_ARCH_MODULES)  # public view of known ids


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch])


def get_config(arch: str) -> ModelConfig:
    """The exact published configuration for ``arch``."""
    return _module(arch).CONFIG


def reduced_config(arch: str) -> ModelConfig:
    """A smoke-test-sized config of the same family (CPU-runnable)."""
    return _module(arch).reduced()


def list_archs() -> list[str]:
    return sorted(_ARCH_MODULES)

"""Config system: every architecture is a frozen dataclass, selectable by id.

``--arch <id>`` resolves through :data:`repro.config.registry.REGISTRY`.
A config fully describes the model; shapes (seq_len x batch x step-kind) are
orthogonal :class:`ShapeConfig` values attached per architecture.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    every: int = 1           # apply MoE on layers where (layer_idx % every == every-1)
    capacity_factor: float = 1.25
    impl: str = "scatter"    # "scatter" (ragged, prod) | "dense" (GShard oracle)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM hyper-params (used by jamba)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64     # rank of the data-dependent decay LoRA
    token_shift: bool = True
    # "steps": exact nested per-step scan (baseline);
    # "chunked": GLA-style matmul tiles — ~20x less HBM traffic (§Perf)
    scan_impl: str = "steps"


@dataclass(frozen=True)
class LSTMAEConfig:
    """The paper's LSTM-Autoencoder family: F{X}-D{Y}.

    ``feature_sizes`` holds the per-layer hidden sizes, e.g. F32-D6 =>
    (16, 8, 4, 8, 16, 32) for input feature size 32 (the output of the final
    decoder layer reconstructs the input width).
    """
    input_features: int
    depth: int               # total LSTM layers (half encoder / half decoder)

    def layer_sizes(self) -> tuple[int, ...]:
        """Per-layer hidden sizes, halving to the bottleneck then doubling back."""
        half = self.depth // 2
        enc = [self.input_features // (2 ** (i + 1)) for i in range(half)]
        dec = list(reversed(enc[:-1])) + [self.input_features]
        sizes = tuple(enc + dec)
        assert len(sizes) == self.depth
        assert all(s >= 1 for s in sizes), f"depth {self.depth} too deep for F{self.input_features}"
        return sizes

    def layer_input_sizes(self) -> tuple[int, ...]:
        """Input feature dimension LX_i of each LSTM layer."""
        return (self.input_features,) + self.layer_sizes()[:-1]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # transformer | rwkv6 | jamba | whisper | lstm_ae
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"    # rmsnorm | layernorm | nonparametric_ln
    activation: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 10000.0
    max_seq_len: int = 524_288
    tie_embeddings: bool = False
    qkv_bias: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    lstm_ae: Optional[LSTMAEConfig] = None
    # hybrid interleave: attention on layers where (idx % attn_every == attn_offset)
    attn_every: int = 1
    attn_offset: int = 0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq_len: int = 0     # post-conv frame count (stub frontend)
    # modality frontend stub: none | audio_stub | vision_stub
    frontend: str = "none"
    vision_patches: int = 576    # phi-3-vision: 24x24 CLIP patch tokens (stub)
    # sub-quadratic? (controls long_500k applicability)
    subquadratic: bool = False
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # decode layer loop: "scan" (compact HLO; baseline) or "unroll"
    # (per-layer cache slices update in place — kills the full-cache
    # rewrite XLA emits for scanned ys caches; see EXPERIMENTS.md §Perf)
    decode_loop: str = "scan"
    # §Perf lever: constrain the layer-body ENTRY so backward cotangents
    # keep the (batch, sp) sharding (suppresses replicated full-seq grads)
    bwd_constrain: bool = False

    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)

    def is_attn_layer(self, idx: int) -> bool:
        return idx % self.attn_every == self.attn_offset

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None:
            return False
        return idx % self.moe.every == self.moe.every - 1

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# The assignment's four LM shapes, reused by every LM-family architecture.
TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")
LM_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

# LSTM-AE (paper) shapes: streaming anomaly detection over T timesteps.
LSTMAE_SHAPES = tuple(
    ShapeConfig(f"stream_{t}", seq_len=t, global_batch=4096, kind="train")
    for t in (16, 64)
) + (ShapeConfig("serve_64", seq_len=64, global_batch=8192, kind="prefill"),)


def shapes_for(cfg: ModelConfig) -> tuple[ShapeConfig, ...]:
    """The dry-run cells for an architecture (skips noted in DESIGN.md)."""
    if cfg.family == "lstm_ae":
        return LSTMAE_SHAPES
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.subquadratic:
            continue  # full-attention arch: O(S^2) at 524k — assignment-mandated skip
        out.append(s)
    return tuple(out)


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    remat: str = "layer"        # none | layer (checkpoint each block)
    loss_chunk: int = 2048      # chunked xent: tokens per logits chunk
    grad_compression: str = "none"  # none | int8_ef
    microbatch: int = 1         # gradient accumulation steps


@dataclass(frozen=True)
class MeshShape:
    """Logical mesh description; concretised by launch/mesh.py."""
    shape: tuple[int, ...]
    axes: tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshShape(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshShape(shape=(2, 16, 16), axes=("pod", "data", "model"))

"""Gradient compression for cross-pod all-reduce: int8 quantisation with
error feedback (EF-SGD style [arXiv:1901.09847]).

At 1000+ node scale the pod-axis (DCN) gradient all-reduce is the scarcest
bandwidth; int8 + EF cuts those bytes 4x vs f32 (2x vs bf16) while the
error-feedback buffer keeps the update unbiased in the long run.  The
quantiser is per-leaf symmetric (scale = max|g|/127).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import Params


def quantize_int8(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(
    grads: Params, error: Params
) -> tuple[Params, Params]:
    """Quantise (grads + carried error) to int8; return (dequantised grads,
    new error buffers).  Wrap the all-reduce around the int8 payload on real
    hardware; here the dequantised value is what enters the optimiser, so
    tests verify the EF contraction property end-to-end."""

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        return deq, target - deq

    flat = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda v: isinstance(v, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda v: isinstance(v, tuple))
    return deq, new_err

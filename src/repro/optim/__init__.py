from repro.optim.adamw import (
    AdamWState,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    lr_schedule,
    opt_state_specs,
)
from repro.optim.compression import (
    compress_grads,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)

__all__ = [
    "AdamWState",
    "adamw_update",
    "clip_by_global_norm",
    "compress_grads",
    "dequantize_int8",
    "global_norm",
    "init_error_feedback",
    "init_opt_state",
    "lr_schedule",
    "opt_state_specs",
    "quantize_int8",
]

"""AdamW with decoupled weight decay, global-norm clipping, and a
warmup+cosine schedule.  Flax/optax-free; states mirror the param tree so
every sharding spec applies unchanged to the optimizer state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.config.core import TrainConfig
from repro.utils import Params


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class AdamWState:
    step: jnp.ndarray     # () int32
    mu: Params            # first moment (f32, param tree)
    nu: Params            # second moment (f32, param tree)


def init_opt_state(params: Params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def opt_state_specs(param_specs: Params) -> Any:
    """Optimizer-state spec tree mirroring the param specs."""
    return AdamWState(step=(), mu=param_specs, nu=param_specs)


def lr_schedule(step: jnp.ndarray, tc: TrainConfig) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, tc.warmup_steps))
    progress = jnp.clip(
        (step - tc.warmup_steps) / max(1, tc.total_steps - tc.warmup_steps), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(
    params: Params, grads: Params, state: AdamWState, tc: TrainConfig
) -> tuple[Params, AdamWState, dict]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if tc.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(state.step, tc)
    b1, b2 = tc.beta1, tc.beta2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
    mu_hat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
    nu_hat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))

    def upd(p, m, v):
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + tc.eps)
        u = u + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu), {
        "grad_norm": gnorm, "lr": lr,
    }

"""Checkpointing: atomic, async-capable, elastic-remesh-aware.

Format: one directory per step holding a flat ``.npz`` of leaves (keyed by
tree path) + ``meta.json`` (step, tree structure, logical axis specs).
Writes go to ``<dir>.tmp`` then ``os.replace`` — a crash mid-save can never
corrupt the latest checkpoint (fault-tolerance requirement).

Elastic scaling: leaves are saved UNSHARDED-logical (gathered); ``restore``
takes the *target* mesh + spec tree and ``jax.device_put``s each leaf to
its NamedSharding — the same checkpoint restores onto 1 CPU, a 16x16 pod,
or a 2x16x16 multi-pod mesh (different device count than at save time).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.utils import Params


def _flatten(tree: Params) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Flatten to {path-key: host array}; returns the ORIGINAL dtype per key
    alongside, because npz cannot round-trip ml_dtypes — bfloat16 leaves are
    upcast to float32 on disk and must be cast back on restore (the upcast
    is lossless, so the round trip is exact)."""
    flat = {}
    dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
        )
        arr = np.asarray(leaf)
        dtypes[key] = arr.dtype.name
        if arr.dtype.name == "bfloat16":
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat, dtypes


def _saved_dtype(meta: dict, key: str, fallback) -> Any:
    """Dtype a leaf was saved with.  Checkpoints written before the dtype
    map existed have no ``dtypes`` entry; those fall back to the restore
    target's dtype (the historical behavior)."""
    name = meta.get("dtypes", {}).get(key)
    if name is None:
        return fallback
    try:
        return np.dtype(name)  # ml_dtypes registers "bfloat16" with numpy
    except TypeError:
        return fallback


def save_checkpoint(directory: str | Path, step: int, state: Params,
                    extra_meta: Optional[dict] = None) -> Path:
    """Atomic synchronous save.  Returns the final checkpoint path."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, dtypes = _flatten(state)
    np.savez(tmp / "leaves.npz", **flat)
    treedef = jax.tree_util.tree_structure(state)
    meta = {
        "step": step,
        "num_leaves": len(flat),
        "keys": sorted(flat.keys()),
        "dtypes": dtypes,
        "treedef": str(treedef),
        **(extra_meta or {}),
    }
    (tmp / "meta.json").write_text(json.dumps(meta, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncCheckpointer:
    """Background-thread checkpointing: snapshot to host, save off-thread.

    ``save`` blocks only for the device->host copy; serialization and fsync
    happen on the worker thread.  ``wait()`` joins outstanding saves (call
    before exit / before deleting old checkpoints)."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    @property
    def busy(self) -> bool:
        """True while a background save is still in flight.  Callers on a
        latency-sensitive thread (the gateway pump) poll this to *skip* a
        snapshot tick instead of blocking in ``save`` -> ``wait``."""
        return self._thread is not None and self._thread.is_alive()

    def save(self, step: int, state: Params, extra_meta: Optional[dict] = None):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _work():
            try:
                save_checkpoint(self.directory, step, host_state, extra_meta)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(list_checkpoints(self.directory))
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)


def list_checkpoints(directory: str | Path) -> list[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    steps = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
            try:
                steps.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def latest_checkpoint(directory: str | Path) -> Optional[Path]:
    steps = list_checkpoints(directory)
    if not steps:
        return None
    return Path(directory) / f"step_{steps[-1]:08d}"


def restore_checkpoint(
    path: str | Path,
    target: Params,
    *,
    mesh=None,
    spec_tree: Any = None,
) -> tuple[Params, dict]:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``mesh`` + ``spec_tree``, each leaf is placed
    with its NamedSharding — this is the elastic-remesh path."""
    path = Path(path)
    with np.load(path / "leaves.npz") as data:
        flat = {k: data[k] for k in data.files}
    meta = json.loads((path / "meta.json").read_text())

    leaves_with_path = jax.tree_util.tree_flatten_with_path(target)[0]
    treedef = jax.tree_util.tree_structure(target)
    restored = []
    for p, leaf in leaves_with_path:
        key = "/".join(
            str(q.key) if hasattr(q, "key") else str(getattr(q, "idx", q)) for q in p
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs target {leaf.shape}")
        restored.append(jax.numpy.asarray(arr).astype(_saved_dtype(meta, key, leaf.dtype)))
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    if mesh is not None and spec_tree is not None:
        # local import: repro.distributed.fault imports this module, so a
        # module-scope import here would close a cycle and break whichever
        # package happens to be imported first
        from repro.distributed.sharding import rules_for_mesh, spec_tree_to_shardings
        shardings = spec_tree_to_shardings(mesh, rules_for_mesh(mesh), spec_tree)
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, meta

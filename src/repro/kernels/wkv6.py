"""RWKV-6 WKV recurrence Pallas TPU kernel.

The paper's core insight — keep recurrent state resident next to the
compute unit and stream timesteps through it — applied at kernel level:
the per-head state S (hd x hd) lives in a VMEM scratch across the whole
sequence chunk, so HBM traffic is only the r/k/v/w streams and the output
(vs. the XLA scan, which spills per-step intermediates; see EXPERIMENTS.md
§Perf for the measured delta on rwkv6-7b train_4k).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (diag(u) k_t^T v_t + S_{t-1})

Grid: (B, H).  Block: full (T, hd) streams for one (batch, head) pair; the
time loop runs inside the kernel (jax.lax.fori_loop) with S in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_out_ref, *, t_len: int):
    u = u_ref[...].astype(jnp.float32)            # (hd,)
    s0 = s0_ref[...].astype(jnp.float32)          # (hd, hd)

    def step(t, s):
        r_t = r_ref[t, :].astype(jnp.float32)     # (hd,)
        k_t = k_ref[t, :].astype(jnp.float32)
        v_t = v_ref[t, :].astype(jnp.float32)
        w_t = w_ref[t, :].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]          # (hd, hd)
        y_t = r_t @ (s + u[:, None] * kv)         # (hd,)
        y_ref[t, :] = y_t.astype(y_ref.dtype)
        return w_t[:, None] * s + kv

    s = jax.lax.fori_loop(0, t_len, step, s0)
    s_out_ref[...] = s.astype(s_out_ref.dtype)


def wkv6_pallas(
    r: jnp.ndarray,      # (B, T, H, hd)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,      # decay in (0,1), f32
    u: jnp.ndarray,      # (H, hd)
    s0: jnp.ndarray,     # (B, H, hd, hd) f32
    *,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bsz, t_len, h, hd = r.shape
    grid = (bsz, h)
    kernel = functools.partial(_wkv6_kernel, t_len=t_len)

    # layout: streams blocked per (batch, head): squeeze to (T, hd) in-kernel
    stream_spec = pl.BlockSpec((None, t_len, None, hd), lambda b, hh: (b, 0, hh, 0))
    state_spec = pl.BlockSpec((None, None, hd, hd), lambda b, hh: (b, hh, 0, 0))
    y, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            stream_spec, stream_spec, stream_spec, stream_spec,
            pl.BlockSpec((None, hd), lambda b, hh: (hh, 0)),
            state_spec,
        ],
        out_specs=[
            stream_spec,
            state_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t_len, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, hd, hd), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_out

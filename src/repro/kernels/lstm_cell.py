"""Fused LSTM-cell Pallas TPU kernel — the paper's MVM_X/MVM_H + gates +
element-wise unit as ONE kernel.

TPU adaptation of the paper's per-module datapath (DESIGN.md §2):

* MVM_X and MVM_H are fused into one MXU pass over the concatenated
  ``[x_t, h_{t-1}]`` — the Eq-7 "equal latency of the two MVMs" becomes
  a single matmul whose contraction covers both operands.
* The hidden-block size ``block_h`` is the reuse-factor analogue: it sets
  how many of the 4*LH gate MACs execute in parallel per VMEM tile
  (paper Eq 5/6: M = 4*LH/R), trading VMEM footprint for parallelism.
* The activation + element-wise unit runs on the VPU in the same kernel
  (the paper's pipelined Activations/Element-Wise stage).

Weights layout: wx (4, In, H), wh (4, H, H), b (4, H) — gate-major so each
grid step loads only its gate-block columns (BRAM-partitioning analogue).

Grid: (B / block_b, H / block_h).  Per step the kernel computes all four
gate slices for its (batch, hidden) tile and updates (h, c) in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
                      h_out_ref, c_out_ref, *, pwl: bool):
    x = x_ref[...]          # (Bb, In)
    h = h_ref[...]          # (Bb, H)  (full hidden needed for MVM_H)
    c = c_ref[...]          # (Bb, Hb)
    wx = wx_ref[...]        # (4, In, Hb)
    wh = wh_ref[...]        # (4, H, Hb)
    b = b_ref[...]          # (4, Hb)

    def mvm(g):
        # fused MVM_X + MVM_H for gate g on this hidden block
        gx = jnp.dot(x, wx[g], preferred_element_type=jnp.float32)
        gh = jnp.dot(h, wh[g], preferred_element_type=jnp.float32)
        return gx + gh + b[g].astype(jnp.float32)

    i_g, f_g, g_g, o_g = mvm(0), mvm(1), mvm(2), mvm(3)
    if pwl:
        sig = lambda t: jnp.clip(0.25 * t + 0.5, 0.0, 1.0)
        tnh = lambda t: jnp.clip(t, -1.0, 1.0)
    else:
        sig = jax.nn.sigmoid
        tnh = jnp.tanh
    c_new = sig(f_g) * c.astype(jnp.float32) + sig(i_g) * tnh(g_g)
    h_new = sig(o_g) * tnh(c_new)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)


def lstm_cell_pallas(
    x: jnp.ndarray,         # (B, In)
    h: jnp.ndarray,         # (B, H)
    c: jnp.ndarray,         # (B, H)
    wx: jnp.ndarray,        # (4, In, H)
    wh: jnp.ndarray,        # (4, H, H)
    b: jnp.ndarray,         # (4, H)
    *,
    block_b: int = 128,
    block_h: int = 128,
    pwl: bool = False,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    bsz, in_dim = x.shape
    hidden = h.shape[1]
    block_b = min(block_b, bsz)
    block_h = min(block_h, hidden)
    assert bsz % block_b == 0 and hidden % block_h == 0
    grid = (bsz // block_b, hidden // block_h)

    kernel = functools.partial(_lstm_cell_kernel, pwl=pwl)
    h_new, c_new = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, in_dim), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, hidden), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, block_h), lambda i, j: (i, j)),
            pl.BlockSpec((4, in_dim, block_h), lambda i, j: (0, 0, j)),
            pl.BlockSpec((4, hidden, block_h), lambda i, j: (0, 0, j)),
            pl.BlockSpec((4, block_h), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_h), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_h), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hidden), h.dtype),
            jax.ShapeDtypeStruct((bsz, hidden), jnp.float32),
        ],
        interpret=interpret,
    )(x, h, c, wx, wh, b)
    return h_new, c_new


def pack_weights(params: dict) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Convert core/lstm.py layout {wx (In,4H), wh (H,4H), b (4H,)} to the
    kernel's gate-major (4, In, H) / (4, H, H) / (4, H)."""
    in_dim, h4 = params["wx"].shape
    hidden = h4 // 4
    wx = jnp.stack(jnp.split(params["wx"], 4, axis=1))   # (4, In, H)
    wh = jnp.stack(jnp.split(params["wh"], 4, axis=1))   # (4, H, H)
    b = jnp.stack(jnp.split(params["b"], 4))             # (4, H)
    return wx, wh, b

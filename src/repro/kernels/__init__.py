"""Pallas TPU kernels for the paper's compute hot-spots.

- lstm_cell.py        fused LSTM cell (MVM_X + MVM_H + gates + elementwise
                      — the paper's per-module datapath as one MXU pass)
- wkv6.py             RWKV6 recurrence chunk (VMEM-resident state)
- flash_attention.py  causal flash attention (prefill shapes)
- ops.py              jitted public wrappers (interpret=True on CPU)
- ref.py              pure-jnp oracles (the allclose targets)
"""
from repro.kernels.ops import flash_attention_op, lstm_cell_op, wkv6_op
from repro.kernels.ref import ref_attention, ref_lstm_cell, ref_wkv6

__all__ = [
    "flash_attention_op",
    "lstm_cell_op",
    "ref_attention",
    "ref_lstm_cell",
    "ref_wkv6",
    "wkv6_op",
]

"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each ref_* mirrors its kernel's signature exactly; tests sweep shapes and
dtypes and assert kernel(interpret=True) == ref to tight tolerances.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_lstm_cell(x, h, c, wx, wh, b, *, pwl: bool = False):
    """x (B,In); h,c (B,H); wx (4,In,H); wh (4,H,H); b (4,H)."""
    if pwl:
        sig = lambda t: jnp.clip(0.25 * t + 0.5, 0.0, 1.0)
        tnh = lambda t: jnp.clip(t, -1.0, 1.0)
    else:
        sig, tnh = jax.nn.sigmoid, jnp.tanh
    gates = (
        jnp.einsum("bi,gio->gbo", x, wx)
        + jnp.einsum("bh,gho->gbo", h, wh)
        + b[:, None, :]
    ).astype(jnp.float32)
    i_g, f_g, g_g, o_g = gates[0], gates[1], gates[2], gates[3]
    c_new = sig(f_g) * c.astype(jnp.float32) + sig(i_g) * tnh(g_g)
    h_new = sig(o_g) * tnh(c_new)
    return h_new.astype(h.dtype), c_new.astype(jnp.float32)


def ref_wkv6(r, k, v, w, u, s0):
    """r/k/v/w (B,T,H,hd); u (H,hd); s0 (B,H,hd,hd) f32."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None].astype(jnp.float32) * v_t[..., None, :].astype(jnp.float32)
        y_t = jnp.einsum(
            "bhk,bhkv->bhv", r_t.astype(jnp.float32),
            s + u[None, :, :, None].astype(jnp.float32) * kv,
        )
        s = w_t[..., :, None].astype(jnp.float32) * s + kv
        return s, y_t

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s  # (B,T,H,hd) f32, final state


def ref_attention(q, k, v, *, causal: bool = True):
    """q/k/v (B,H,S,d) -> (B,H,S,d); exact softmax in fp32."""
    b, h, s, d = q.shape
    sk = k.shape[2]
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32
    ) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, sk), bool), k=sk - s)
        scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum(
        "bhqk,bhkd->bhqd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    ).astype(q.dtype)

"""Sequence-streaming LSTM Pallas TPU kernel — the paper's temporal
dataflow INSIDE one kernel.

Where ``lstm_cell.py`` fuses one timestep, this kernel keeps (h, c)
resident in VMEM scratch and streams ALL timesteps of a layer through the
MXU — the per-module half of the paper's architecture (weights stationary
in VMEM = BRAM-resident weights; the FIFO to the next layer is the written
output stream).  HBM traffic per layer drops from
O(T·(x + h + gates + state)) for the XLA scan to O(T·(x + h_out)) + one
weight read.

Grid: (B / block_b,).  VMEM per step: weights 4·H·(In+H) + streams
(block_b, In/H) + state — e.g. In=H=256, block_b=256: ~2.3 MB, MXU-aligned.

Layout matches core/lstm.py via kernels/lstm_cell.pack_weights: wx
(4, In, H), wh (4, H, H), b (4, H).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lstm_seq_kernel(xs_ref, h0_ref, c0_ref, wx_ref, wh_ref, b_ref,
                     ys_ref, h_out_ref, c_out_ref, h_scr, c_scr,
                     *, t_len: int, pwl: bool):
    wx = wx_ref[...]          # (4, In, H)
    wh = wh_ref[...]          # (4, H, H)
    b = b_ref[...]            # (4, H)
    h_scr[...] = h0_ref[...].astype(jnp.float32)
    c_scr[...] = c0_ref[...].astype(jnp.float32)

    if pwl:
        sig = lambda t: jnp.clip(0.25 * t + 0.5, 0.0, 1.0)
        tnh = lambda t: jnp.clip(t, -1.0, 1.0)
    else:
        sig = jax.nn.sigmoid
        tnh = jnp.tanh

    def step(t, _):
        x_t = xs_ref[t, :, :]                  # (Bb, In)
        h = h_scr[...]
        c = c_scr[...]

        def gate(g):
            gx = jnp.dot(x_t, wx[g], preferred_element_type=jnp.float32)
            gh = jnp.dot(h.astype(x_t.dtype), wh[g], preferred_element_type=jnp.float32)
            return gx + gh + b[g].astype(jnp.float32)

        i_g, f_g, g_g, o_g = gate(0), gate(1), gate(2), gate(3)
        c_new = sig(f_g) * c + sig(i_g) * tnh(g_g)
        h_new = sig(o_g) * tnh(c_new)
        h_scr[...] = h_new
        c_scr[...] = c_new
        ys_ref[t, :, :] = h_new.astype(ys_ref.dtype)
        return 0

    jax.lax.fori_loop(0, t_len, step, 0)
    h_out_ref[...] = h_scr[...].astype(h_out_ref.dtype)
    c_out_ref[...] = c_scr[...].astype(c_out_ref.dtype)


def lstm_seq_pallas(
    xs: jnp.ndarray,      # (T, B, In)
    h0: jnp.ndarray,      # (B, H)
    c0: jnp.ndarray,      # (B, H) f32
    wx: jnp.ndarray,      # (4, In, H)
    wh: jnp.ndarray,      # (4, H, H)
    b: jnp.ndarray,       # (4, H)
    *,
    block_b: int = 256,
    pwl: bool = False,
    interpret: bool = False,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    t_len, bsz, in_dim = xs.shape
    hidden = h0.shape[1]
    block_b = min(block_b, bsz)
    assert bsz % block_b == 0
    grid = (bsz // block_b,)
    kernel = functools.partial(_lstm_seq_kernel, t_len=t_len, pwl=pwl)

    ys, h_out, c_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_len, block_b, in_dim), lambda i: (0, i, 0)),
            pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),
            pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),
            pl.BlockSpec((4, in_dim, hidden), lambda i: (0, 0, 0)),
            pl.BlockSpec((4, hidden, hidden), lambda i: (0, 0, 0)),
            pl.BlockSpec((4, hidden), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t_len, block_b, hidden), lambda i: (0, i, 0)),
            pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),
            pl.BlockSpec((block_b, hidden), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, bsz, hidden), xs.dtype),
            jax.ShapeDtypeStruct((bsz, hidden), h0.dtype),
            jax.ShapeDtypeStruct((bsz, hidden), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_b, hidden), jnp.float32),  # h
            pltpu.VMEM((block_b, hidden), jnp.float32),  # c
        ],
        interpret=interpret,
    )(xs, h0, c0, wx, wh, b)
    return ys, (h_out, c_out)

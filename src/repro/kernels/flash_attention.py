"""Causal flash-attention forward Pallas TPU kernel [arXiv:2205.14135,
adapted to the TPU grid model].

Grid: (B*H, nQ, nK) with the KV axis innermost; the online-softmax
accumulators (acc, m, l) live in VMEM scratch and persist across the nK
steps of each (batch-head, q-block) pair — TPU grids execute sequentially,
which substitutes for FA's explicit inner loop.  Causal wedge: KV blocks
strictly above the diagonal are skipped via ``pl.when`` predication (on
TPU this skips the MXU work; the triangular FLOP saving the XLA fallback
path only gets via the q-chunk wedge in layers/attention.py).

VMEM per step: q (Qb x d) + k,v (Kb x d) + scores (Qb x Kb) + acc (Qb x d)
— with Qb=Kb=512, d=128 in bf16/f32 about 3.3 MB, comfortably inside the
~16 MB VMEM budget, and MXU-aligned (multiples of 128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, block_q: int, block_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip blocks entirely above the diagonal
    run = (qi * block_q + block_q - 1) >= (ki * block_k) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[...]                        # (Qb, d)
        k = k_ref[...]                        # (Kb, d)
        v = v_ref[...]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                             # (Qb, Kb)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,       # (B, H, S, d)
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, s, d = q.shape
    sk = k.shape[2]
    block_q = min(block_q, s)
    block_k = min(block_k, sk)
    assert s % block_q == 0 and sk % block_k == 0
    grid = (b * h, s // block_q, sk // block_k)
    scale = 1.0 / math.sqrt(d)

    qr = q.reshape(b * h, s, d)
    kr = k.reshape(b * h, sk, d)
    vr = v.reshape(b * h, sk, d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k, causal=causal
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((None, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),     # l (running sum)
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)

"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on real
TPU — resolved once at import from the local backend, overridable per call.
The wrappers adapt framework-native layouts (e.g. core/lstm.py param dicts,
(B,S,H,d) attention tensors) to kernel layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lstm_cell import lstm_cell_pallas, pack_weights
from repro.kernels.wkv6 import wkv6_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_b", "block_h", "pwl", "interpret"))
def lstm_cell_op(params, x, h, c, *, block_b: int = 128, block_h: int = 128,
                 pwl: bool = False, interpret: bool | None = None):
    """Fused LSTM cell using core/lstm.py param layout {wx, wh, b}."""
    if interpret is None:
        interpret = _default_interpret()
    wx, wh, b = pack_weights(params)
    return lstm_cell_pallas(
        x, h, c, wx, wh, b, block_b=block_b, block_h=block_h, pwl=pwl,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6_op(r, k, v, w, u, s0, *, interpret: bool | None = None):
    """WKV6 recurrence: r/k/v/w (B,T,H,hd), u (H,hd), s0 (B,H,hd,hd)."""
    if interpret is None:
        interpret = _default_interpret()
    return wkv6_pallas(r, k, v, w, u, s0, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_b", "pwl", "interpret"))
def lstm_seq_op(params, xs, h0=None, c0=None, *, block_b: int = 256,
                pwl: bool = False, interpret: bool | None = None):
    """Sequence-streaming LSTM layer (state VMEM-resident across T).

    params: core/lstm.py layout; xs (T, B, In) -> (ys (T,B,H), (h, c))."""
    from repro.kernels.lstm_seq import lstm_seq_pallas

    if interpret is None:
        interpret = _default_interpret()
    wx, wh, b = pack_weights(params)
    bsz = xs.shape[1]
    hidden = wh.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, hidden), xs.dtype)
    if c0 is None:
        c0 = jnp.zeros((bsz, hidden), jnp.float32)
    return lstm_seq_pallas(
        xs, h0, c0, wx, wh, b, block_b=block_b, pwl=pwl, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_op(q, k, v, *, causal: bool = True, block_q: int = 512,
                       block_k: int = 512, interpret: bool | None = None):
    """Flash attention over (B, S, H, d) layout (framework-native)."""
    if interpret is None:
        interpret = _default_interpret()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_pallas(
        qt, kt, vt, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)

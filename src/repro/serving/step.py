"""Serve-step builders: prefill (prompt -> KV cache/state + first logits)
and decode (one token against the cache), under the same mesh-context
machinery as training.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardingRules, mesh_context, rules_for_mesh
from repro.models.api import ModelAPI


def build_prefill_step(api: ModelAPI, mesh=None, rules: Optional[ShardingRules] = None,
                       q_chunks: int = 1, kv_chunk: int = 1024):
    def prefill_step(params, batch):
        with mesh_context(mesh, rules or (rules_for_mesh(mesh) if mesh else None)):
            return api.prefill(params, batch, q_chunks=q_chunks, kv_chunk=kv_chunk)
    return prefill_step


def build_score_step(engine, mesh=None, rules: Optional[ShardingRules] = None):
    """Anomaly-scoring step over a :class:`repro.engine.Engine` — the
    LSTM-AE serving path.  The engine owns the execution schedule (and, for
    "pipelined", its own mesh); ``mesh`` here only supplies sharding rules
    for any enclosing context."""
    def score_step(params, batch):
        with mesh_context(mesh, rules or (rules_for_mesh(mesh) if mesh else None)):
            return engine.score_with(params, batch)
    return score_step


def build_decode_step(api: ModelAPI, mesh=None, rules: Optional[ShardingRules] = None):
    def decode_step(params, token, cache, cache_len):
        with mesh_context(mesh, rules or (rules_for_mesh(mesh) if mesh else None)):
            return api.decode(params, token, cache, cache_len)
    return decode_step


def greedy_decode_loop(api: ModelAPI, params, cache, first_token, cache_len0,
                       num_steps: int):
    """Greedy autoregressive loop (CPU-scale examples/tests)."""
    def body(carry, _):
        token, cache, n = carry
        logits, cache = api.decode(params, token, cache, n)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return (nxt, cache, n + 1), nxt[:, 0]

    (_, cache, _), tokens = jax.lax.scan(
        body, (first_token, cache, cache_len0), None, length=num_steps
    )
    return jnp.moveaxis(tokens, 0, 1), cache  # (B, num_steps)

from repro.serving.step import (
    build_decode_step,
    build_prefill_step,
    build_score_step,
    greedy_decode_loop,
)

__all__ = [
    "build_decode_step",
    "build_prefill_step",
    "build_score_step",
    "greedy_decode_loop",
]

"""rwkv6-7b — Finch: attention-free, data-dependent decay.

[arXiv:2404.05892; hf]
32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.  Sub-quadratic: the
long_500k decode shape runs for this architecture (O(1) state per token).
"""
from repro.config.core import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="rwkv6",
    num_layers=32,
    d_model=4096,
    num_heads=64,          # d_model / rwkv.head_dim
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65_536,
    norm="layernorm",
    activation="relu_sq",  # RWKV channel-mix uses squared ReLU
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-reduced",
        family="rwkv6",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=224,
        vocab_size=512,
        norm="layernorm",
        activation="relu_sq",
        rwkv=RWKVConfig(head_dim=16, decay_lora=8),
        subquadratic=True,
    )

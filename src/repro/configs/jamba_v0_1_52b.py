"""jamba-v0.1-52b — hybrid Mamba + attention (1:7), MoE 16e top-2.

[arXiv:2403.19887; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.  Attention on one
layer in eight (offset 4, the middle of each Jamba block); MoE on every
second layer.  Sub-quadratic overall: long_500k runs (only 4 attention
layers carry a KV cache).
"""
from repro.config.core import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="jamba",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65_536,
    norm="rmsnorm",
    activation="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    attn_offset=4,
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-reduced",
        family="jamba",
        num_layers=8,          # one full Jamba period (7 mamba + 1 attn)
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        norm="rmsnorm",
        activation="swiglu",
        moe=MoEConfig(num_experts=4, top_k=2, every=2),
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
        attn_every=8,
        attn_offset=4,
        subquadratic=True,
    )

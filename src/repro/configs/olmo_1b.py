"""olmo-1b — dense, non-parametric LayerNorm.

[arXiv:2402.00838; hf]
16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
"""
from repro.config.core import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="transformer",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50_304,
    norm="nonparametric_ln",
    activation="swiglu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-reduced",
        family="transformer",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        norm="nonparametric_ln",
        activation="swiglu",
        tie_embeddings=True,
    )

"""LSTM-AE-F64-D6 — 6 layers, 64->32->16->8->16->32->64 features.

Paper Section 4.1, Table 1: RH_m = 8 on the ZCU104.
"""
from repro.config.core import LSTMAEConfig, ModelConfig

CONFIG = ModelConfig(
    name="lstm-ae-f64-d6",
    family="lstm_ae",
    num_layers=6,
    lstm_ae=LSTMAEConfig(input_features=64, depth=6),
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(name="lstm-ae-f64-d6-reduced")

"""One module per selectable architecture (``--arch <id>``).

Each module defines ``CONFIG`` (exact published dims, per the assignment) and
``reduced()`` (same family, smoke-test sized, CPU-runnable).
"""

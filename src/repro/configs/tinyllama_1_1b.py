"""tinyllama-1.1b — llama2-architecture small model.

[arXiv:2401.02385; hf]
22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""
from repro.config.core import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="transformer",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32_000,
    norm="rmsnorm",
    activation="swiglu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b-reduced",
        family="transformer",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=160,
        vocab_size=512,
        norm="rmsnorm",
        activation="swiglu",
    )

"""whisper-large-v3 — encoder-decoder audio model, conv frontend stubbed.

[arXiv:2212.04356; unverified]
32L d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866.  The mel/conv
frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (1500 frames at d_model).  The 32 layers are
the decoder; the encoder mirrors with 32 layers (whisper-large-v3 layout).
"""
from repro.config.core import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="whisper",
    num_layers=32,          # decoder layers
    encoder_layers=32,
    encoder_seq_len=1500,   # 30 s of audio after the (stubbed) conv stem
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    norm="layernorm",
    activation="gelu",
    qkv_bias=True,
    frontend="audio_stub",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-reduced",
        family="whisper",
        num_layers=2,
        encoder_layers=2,
        encoder_seq_len=12,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        norm="layernorm",
        activation="gelu",
        qkv_bias=True,
        frontend="audio_stub",
    )

"""internlm2-20b — dense GQA.

[arXiv:2403.17297; hf]
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from repro.config.core import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="transformer",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92_544,
    norm="rmsnorm",
    activation="swiglu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-reduced",
        family="transformer",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        d_ff=192,
        vocab_size=512,
        norm="rmsnorm",
        activation="swiglu",
    )

"""LSTM-AE-F64-D2 — 2 layers, 64->32->64 features.

Paper Section 4.1, Table 1: RH_m = 4 on the ZCU104.
"""
from repro.config.core import LSTMAEConfig, ModelConfig

CONFIG = ModelConfig(
    name="lstm-ae-f64-d2",
    family="lstm_ae",
    num_layers=2,
    lstm_ae=LSTMAEConfig(input_features=64, depth=2),
    subquadratic=True,
)


def reduced() -> ModelConfig:
    return CONFIG.with_overrides(name="lstm-ae-f64-d2-reduced")

"""moonshot-v1-16b-a3b — kimi/moonlight MoE, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6.
"""
from repro.config.core import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="transformer",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    norm="rmsnorm",
    activation="swiglu",
    moe=MoEConfig(num_experts=64, top_k=6, every=1),
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-reduced",
        family="transformer",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=48,
        vocab_size=512,
        norm="rmsnorm",
        activation="swiglu",
        moe=MoEConfig(num_experts=8, top_k=2, every=1),
    )

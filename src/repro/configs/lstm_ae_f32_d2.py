"""LSTM-AE-F32-D2 — the paper's smallest model: 2 layers, 32->16->32 features.

Paper Section 4.1, Table 1: RH_m = 1 on the ZCU104.
"""
from repro.config.core import LSTMAEConfig, ModelConfig

CONFIG = ModelConfig(
    name="lstm-ae-f32-d2",
    family="lstm_ae",
    num_layers=2,
    lstm_ae=LSTMAEConfig(input_features=32, depth=2),
    subquadratic=True,
)


def reduced() -> ModelConfig:
    # Already CPU-sized; the reduced config is the config itself.
    return CONFIG.with_overrides(name="lstm-ae-f32-d2-reduced")

"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stubbed).

[hf:microsoft/Phi-3-vision-128k-instruct; hf]
32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.  The CLIP image
tower is a STUB per the assignment: ``input_specs()`` provides precomputed
patch embeddings (576 tokens at d_model) prepended to the text tokens.
"""
from repro.config.core import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="transformer",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    norm="rmsnorm",
    activation="swiglu",
    frontend="vision_stub",
    vision_patches=576,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b-reduced",
        family="transformer",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        norm="rmsnorm",
        activation="swiglu",
        frontend="vision_stub",
        vision_patches=8,
    )

"""phi4-mini-3.8b — dense, RoPE SwiGLU GQA.

[arXiv:2412.08905; hf]
32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.config.core import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="transformer",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200_064,
    norm="rmsnorm",
    activation="swiglu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b-reduced",
        family="transformer",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        norm="rmsnorm",
        activation="swiglu",
        tie_embeddings=True,
    )

"""dbrx-132b — 16 experts top-4, fine-grained MoE.

[hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
"""
from repro.config.core import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="transformer",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100_352,
    norm="layernorm",
    activation="swiglu",
    moe=MoEConfig(num_experts=16, top_k=4, every=1),
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-reduced",
        family="transformer",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        d_ff=96,
        vocab_size=512,
        norm="layernorm",
        activation="swiglu",
        moe=MoEConfig(num_experts=4, top_k=2, every=1),
    )

"""Observability plane for the serving stack: traces, histograms, /metrics.

The paper's evaluation is built on per-stage visibility (latency per
timestep, datapath utilization, energy per step); this package is the
software analogue for the serving layers:

* :mod:`repro.obs.histogram` — mergeable log-linear latency histograms
  with FIXED bucket boundaries, so per-worker histograms sum exactly and
  a multi-worker front reports true front-wide percentiles instead of a
  worst-worker approximation.
* :mod:`repro.obs.trace` — a :class:`Tracer` (injectable clock) producing
  per-request spans whose named stages decompose end-to-end wire latency
  (client serialize -> wire -> queue wait -> flush assembly -> compiled
  step -> response).
* :mod:`repro.obs.events` — an append-only JSONL event log carrying
  sampled spans plus lifecycle events (boot, respawn, snapshot, resume,
  migration, recalibrate, drain).
* :mod:`repro.obs.prometheus` — Prometheus text exposition of
  ``gateway.stats()``-shaped dicts and a tiny threaded ``/metrics`` HTTP
  endpoint (``launch/serve.py --metrics-port``).

Everything here is dependency-free host-side bookkeeping: histograms and
spans serialize as plain JSON-safe dicts so they cross both the workers'
control pipes (pickle) and the wire protocol (JSON) unchanged.
"""
from repro.obs.events import EventLog
from repro.obs.histogram import Histogram, bucket_bound, bucket_index
from repro.obs.prometheus import MetricsServer, render_stats
from repro.obs.trace import Span, Tracer

__all__ = [
    "EventLog",
    "Histogram",
    "MetricsServer",
    "Span",
    "Tracer",
    "bucket_bound",
    "bucket_index",
    "render_stats",
]

"""Per-request spans: named stages decomposing end-to-end latency.

A :class:`Tracer` (injectable clock, like ``Telemetry``) produces
:class:`Span` objects.  A span accumulates named stage durations two
ways:

* :meth:`Span.mark` — close the time since the previous mark as a named
  stage (the server's dispatch path uses this for inline stages);
* :meth:`Span.stage` — add an externally measured duration (the
  micro-batcher stamps ``queue_wait``/``assemble``/``compute`` per
  ticket at flush time, which the server folds into the request's span).

Trace ids travel as an optional ``"trace"`` field on wire requests;
both sides' dict-based dispatch ignores unknown fields, so PR 3 clients
and servers interoperate unchanged.  Traced responses carry
``{"trace": {"id", "stages", "total_ms"}}`` back, and the client adds
its own ``serialize`` stage plus the ``wire`` remainder (end-to-end
minus everything attributed), giving a span whose stages sum to the
observed wire latency.

Finished spans are sampled into the JSONL event log (``kind: "span"``)
at a deterministic 1-in-``sample_every`` cadence — no RNG, so tests and
replays see identical sampling decisions.
"""
from __future__ import annotations

import itertools
import os
import time
from typing import Callable, Optional

from repro.obs.events import EventLog


class Span:
    """One request's named-stage timing breakdown (durations in ms)."""

    __slots__ = ("name", "trace_id", "t0", "_last", "_clock", "stages",
                 "total_ms")

    def __init__(self, name: str, trace_id: str,
                 clock: Callable[[], float]):
        self.name = name
        self.trace_id = trace_id
        self._clock = clock
        self.t0 = clock()
        self._last = self.t0
        self.stages: dict[str, float] = {}
        self.total_ms: Optional[float] = None

    def mark(self, stage: str) -> float:
        """Close the interval since the previous mark (or span start) as
        ``stage``; returns the interval in ms."""
        now = self._clock()
        ms = (now - self._last) * 1e3
        self.stages[stage] = self.stages.get(stage, 0.0) + ms
        self._last = now
        return ms

    def stage(self, name: str, ms: float) -> None:
        """Attribute an externally measured duration to ``name``."""
        self.stages[name] = self.stages.get(name, 0.0) + float(ms)

    def end(self) -> "Span":
        if self.total_ms is None:
            self.total_ms = (self._clock() - self.t0) * 1e3
        return self

    def stage_sum_ms(self) -> float:
        return sum(self.stages.values())

    def to_wire(self) -> dict:
        """The response-payload view (id + stages + server total)."""
        self.end()
        return {
            "id": self.trace_id,
            "stages": {k: round(v, 6) for k, v in self.stages.items()},
            "total_ms": round(self.total_ms, 6),
        }

    def to_dict(self) -> dict:
        d = self.to_wire()
        d["name"] = self.name
        return d

    def __repr__(self) -> str:
        return (f"Span({self.name}, trace={self.trace_id}, "
                f"stages={sorted(self.stages)})")


class Tracer:
    """Span factory with deterministic sampling into an event log."""

    def __init__(
        self,
        clock: Callable[[], float] = time.monotonic,
        events: Optional[EventLog] = None,
        sample_every: int = 1,
    ):
        if sample_every < 1:
            raise ValueError(f"sample_every must be >= 1, got {sample_every}")
        self._clock = clock
        self.events = events
        self.sample_every = sample_every
        self._seq = itertools.count()
        self._finished = 0
        self._emitted = 0
        # pid cached at construction: new_id() sits on the traced hot
        # path and os.getpid() is a syscall per call; workers build their
        # tracer post-spawn so the cached pid is the serving process's
        self._id_prefix = f"t{os.getpid():x}-"

    def new_id(self) -> str:
        """Process-unique trace id (pid-prefixed monotonic counter)."""
        return f"{self._id_prefix}{next(self._seq):x}"

    def start(self, name: str, trace_id: Optional[str] = None) -> Span:
        return Span(name, trace_id or self.new_id(), self._clock)

    def finish(self, span: Span) -> Span:
        """End a span and emit it to the event log on the sampling
        cadence (every ``sample_every``-th finished span)."""
        span.end()
        self._finished += 1
        if self.events is not None and self.events.enabled \
                and (self._finished - 1) % self.sample_every == 0:
            self._emitted += 1
            self.events.emit("span", **span.to_dict())
        return span

    def describe(self) -> dict:
        return {
            "finished": self._finished,
            "emitted": self._emitted,
            "sample_every": self.sample_every,
        }

"""Append-only JSONL event log: lifecycle events + sampled spans.

One :class:`EventLog` per process (each worker writes its own file, so
no cross-process locking is needed).  Every record is one JSON object
per line::

    {"ts": <unix seconds>, "kind": "<event kind>", ...fields}

Kinds emitted by the stack: ``boot``, ``respawn``, ``snapshot``,
``resume``, ``migration``, ``adopt``, ``recalibrate``, ``drain``,
``serve_start``, ``bucket_compile`` and ``span`` (a sampled request
trace — see :mod:`repro.obs.trace` for the span schema).

Constructed with ``path=None`` the log is disabled and every ``emit`` is
a cheap no-op, so call sites never need to branch.
"""
from __future__ import annotations

import json
import logging
import os
import time
from typing import Callable, Optional

logger = logging.getLogger(__name__)


class EventLog:
    """JSONL writer with a wall-clock timestamp per record."""

    def __init__(
        self,
        path: Optional[str] = None,
        clock: Callable[[], float] = time.time,
    ):
        self._clock = clock
        self.path = os.fspath(path) if path is not None else None
        self._fh = None
        if self.path is not None:
            parent = os.path.dirname(self.path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")

    @property
    def enabled(self) -> bool:
        return self._fh is not None

    def emit(self, kind: str, **fields) -> None:
        """Append one event; silently drops records once closed/disabled
        (observability must never take the serving path down)."""
        if self._fh is None:
            return
        record = {"ts": round(self._clock(), 6), "kind": str(kind)}
        record.update(fields)
        try:
            self._fh.write(json.dumps(record, default=str) + "\n")
            self._fh.flush()
        except (OSError, ValueError):
            # best-effort sink, but a dead one silently losing every
            # event is worth a (rate-unbounded, debug-only) trace
            logger.debug("event log write failed for %r", self.path,
                         exc_info=True)

    def close(self) -> None:
        if self._fh is not None:
            try:
                self._fh.close()
            finally:
                self._fh = None

    def __repr__(self) -> str:
        state = self.path if self.enabled else "disabled"
        return f"EventLog({state})"

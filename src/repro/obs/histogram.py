"""Mergeable log-linear (HDR-style) latency histograms.

Every histogram in the stack shares ONE fixed bucket layout: each
power-of-two octave of the value range is split into ``_SUBS`` linear
sub-buckets, giving a bounded relative error of ``1/_SUBS`` per bucket
across ~10 decades of dynamic range.  Because the boundaries are fixed
(not data-dependent), merging histograms is exact: summing bucket counts
from N workers yields bit-for-bit the histogram that would have been
built from the union of their samples.  That is what lets
``WorkerFront.stats()`` report true front-wide p50/p95/p99 over the
control pipes instead of the worst worker's percentiles.

Percentiles use the same nearest-rank convention as
:func:`repro.gateway.telemetry.percentile` and return the lower bound of
the bucket holding the ranked sample; values recorded exactly on a
bucket bound round-trip unchanged (``bucket_bound(bucket_index(v)) ==
v``), which the merge-exactness tests exploit.

Counts are stored sparsely (``{bucket_index: count}``) so a histogram
serializes as a small JSON-safe dict that crosses both the workers'
pickled control pipes and the JSON wire protocol.
"""
from __future__ import annotations

import math
from typing import Iterable, Mapping, Optional

# 16 linear sub-buckets per power-of-two octave: <= 6.25% relative error.
_SUBS = 16
# Value range in ms: 2**-10 ms (~1 us) up to 2**24 ms (~4.7 h).  Values
# below the floor land in bucket 0 (bound 0.0); values at or above the
# ceiling land in the overflow bucket.
_E_MIN = -10
_E_MAX = 24
_MIN_VALUE = 2.0 ** _E_MIN

OVERFLOW_INDEX = 1 + (_E_MAX - _E_MIN) * _SUBS
NUM_BUCKETS = OVERFLOW_INDEX + 1


def bucket_index(value: float) -> int:
    """Bucket index for ``value`` (ms).  Total order: higher value ->
    higher (or equal) index; sub-1us, non-finite-small and negative
    values all collapse into bucket 0."""
    if not value >= _MIN_VALUE:  # also catches NaN
        return 0
    m, e = math.frexp(value)  # value = m * 2**e with m in [0.5, 1)
    e -= 1  # value = (2m) * 2**e with 2m in [1, 2)
    if e >= _E_MAX or value == math.inf:
        return OVERFLOW_INDEX
    # (2m - 1) is a binary fraction, so the sub-bucket index is exact for
    # values that sit precisely on a bucket bound (no float drift).
    sub = int((m * 2.0 - 1.0) * _SUBS)
    return 1 + (e - _E_MIN) * _SUBS + sub


def bucket_bound(index: int) -> float:
    """Inclusive lower bound (ms) of bucket ``index`` — the canonical
    representative value reported for samples in that bucket."""
    if index <= 0:
        return 0.0
    if index >= OVERFLOW_INDEX:
        return float(2.0 ** _E_MAX)
    e, sub = divmod(index - 1, _SUBS)
    return (2.0 ** (_E_MIN + e)) * (1.0 + sub / _SUBS)


class Histogram:
    """Sparse fixed-boundary histogram; merge by summing bucket counts."""

    __slots__ = ("counts", "count", "sum")

    def __init__(self):
        self.counts: dict[int, int] = {}
        self.count: int = 0
        self.sum: float = 0.0

    # -- recording --------------------------------------------------------

    def record(self, value: float) -> None:
        idx = bucket_index(value)
        self.counts[idx] = self.counts.get(idx, 0) + 1
        self.count += 1
        self.sum += float(value)

    def record_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.record(v)

    def clear(self) -> None:
        self.counts.clear()
        self.count = 0
        self.sum = 0.0

    # -- merging ----------------------------------------------------------

    def merge_from(self, other: "Histogram") -> "Histogram":
        """Add ``other``'s buckets into this histogram (exact: shared
        fixed boundaries mean no re-binning error).  Returns self."""
        for idx, n in other.counts.items():
            self.counts[idx] = self.counts.get(idx, 0) + n
        self.count += other.count
        self.sum += other.sum
        return self

    @classmethod
    def merged(cls, parts: Iterable["Histogram"]) -> "Histogram":
        out = cls()
        for part in parts:
            out.merge_from(part)
        return out

    # -- reading ----------------------------------------------------------

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile (same convention as
        ``telemetry.percentile``); 0.0 when empty.  Returns the lower
        bound of the bucket containing the ranked sample, so values
        recorded exactly on bucket bounds reproduce raw-sample
        percentiles bit for bit."""
        if self.count == 0:
            return 0.0
        rank = min(self.count - 1,
                   max(0, int(round(p / 100.0 * (self.count - 1)))))
        seen = 0
        for idx in sorted(self.counts):
            seen += self.counts[idx]
            if rank < seen:
                return bucket_bound(idx)
        return bucket_bound(max(self.counts))  # unreachable; counts agree

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def cumulative(self) -> list:
        """Ascending ``[(upper_bound_ms_or_inf, cumulative_count), ...]``
        over occupied buckets — the Prometheus ``le`` view.  The final
        entry is always ``(inf, count)``."""
        out = []
        seen = 0
        for idx in sorted(self.counts):
            seen += self.counts[idx]
            upper = math.inf if idx >= OVERFLOW_INDEX else bucket_bound(idx + 1)
            out.append((upper, seen))
        if not out or out[-1][0] != math.inf:
            out.append((math.inf, self.count))
        return out

    # -- serialization (JSON/pickle-safe) ----------------------------------

    def to_dict(self) -> dict:
        """Plain-dict form (string bucket keys, JSON object compatible)."""
        return {
            "counts": {str(idx): n for idx, n in sorted(self.counts.items())},
            "count": self.count,
            "sum": self.sum,
        }

    @classmethod
    def from_dict(cls, data: Optional[Mapping]) -> "Histogram":
        """Inverse of :meth:`to_dict`; tolerates None/empty/partial dicts
        (wire payloads from a worker mid-boot may omit histograms)."""
        out = cls()
        if not data:
            return out
        counts = data.get("counts") or {}
        for key, n in counts.items():
            out.counts[int(key)] = out.counts.get(int(key), 0) + int(n)
        out.count = int(data.get("count", sum(out.counts.values())))
        out.sum = float(data.get("sum", 0.0))
        return out

    def __repr__(self) -> str:
        return (f"Histogram(count={self.count}, p50={self.percentile(50):.3g}, "
                f"p99={self.percentile(99):.3g})")

"""Prometheus text exposition + a tiny threaded ``/metrics`` endpoint.

:func:`render_stats` turns a ``gateway.stats()``-shaped dict (counters /
gauges / gauge_vecs / histograms plus a few scalar top-levels) into
Prometheus text format 0.0.4.  Metric names are ``repro_<name>`` with
dots mapped to underscores: ``queue.completed`` becomes
``repro_queue_completed_total``, the request histogram becomes
``repro_request_ms_bucket{le="..."}`` / ``_sum`` / ``_count``, and
vector gauges get a ``shard`` label per mesh position.  The same
renderer serves a single gateway, one worker, or the front-aggregated
view — ``WorkerFront.stats()`` has the same shape after histogram
merging.

:class:`MetricsServer` is a daemon-threaded ``http.server`` answering
``GET /metrics`` by calling a ``stats_fn`` and rendering it.  Port 0
binds an ephemeral port (the bound port is on ``.port`` and printed by
``launch/serve.py``); a stats failure answers 500 instead of killing
the scrape loop.
"""
from __future__ import annotations

import json
import logging
import math
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Mapping, Optional

from repro.obs.histogram import Histogram

logger = logging.getLogger(__name__)

_PREFIX = "repro"
_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")

# scalar top-level stats() keys worth exposing as gauges
_SCALAR_GAUGES = (
    "uptime_s", "active_streams", "queue_depth", "capacity", "max_batch",
    "max_seq_len", "features", "threshold",
    "batch_fill_ratio", "mean_batch_wait_ms", "requests_per_s",
    "stream_steps_per_s", "workers",
    "arrival_rps_window", "completed_rps_window",
)


def _san(name: str) -> str:
    return _NAME_RE.sub("_", str(name))


def _labels(labels: Optional[Mapping[str, str]]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{_san(k)}="{str(v)}"' for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


def _fmt(value: float) -> str:
    v = float(value)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v) if v != int(v) else str(int(v))


def render_stats(
    stats: Mapping,
    *,
    labels: Optional[Mapping[str, str]] = None,
) -> str:
    """Render a stats dict as Prometheus text (one trailing newline)."""
    base = dict(labels or {})
    lines: list[str] = []

    def emit(name, kind, value, extra=None):
        metric = f"{_PREFIX}_{_san(name)}"
        lab = dict(base)
        if extra:
            lab.update(extra)
        lines.append(f"# TYPE {metric} {kind}")
        lines.append(f"{metric}{_labels(lab)} {_fmt(value)}")

    for key in _SCALAR_GAUGES:
        if key in stats and isinstance(stats[key], (int, float)):
            emit(key, "gauge", stats[key])
    workers = stats.get("workers")
    if isinstance(workers, Mapping):  # WorkerFront's aggregate section
        for key in ("count", "configured", "target", "restarts",
                    "scale_ups", "scale_downs",
                    "sessions_lost", "sessions_migrated"):
            if isinstance(workers.get(key), (int, float)):
                emit(f"workers_{key}", "gauge", workers[key])
    control = stats.get("control")
    if isinstance(control, Mapping):  # control-plane section (repro.control)
        for key in ("ticks", "tick_interval_s", "slo_p95_ms", "floor_ms"):
            if isinstance(control.get(key), (int, float)):
                emit(f"control_{key}", "gauge", control[key])
    for name, value in sorted((stats.get("counters") or {}).items()):
        emit(f"{name}_total", "counter", value)
    for name, value in sorted((stats.get("gauges") or {}).items()):
        emit(name, "gauge", value)
    for name, vec in sorted((stats.get("gauge_vecs") or {}).items()):
        metric = f"{_PREFIX}_{_san(name)}"
        lines.append(f"# TYPE {metric} gauge")
        for i, value in enumerate(vec):
            lab = dict(base)
            lab["shard"] = str(i)
            lines.append(f"{metric}{_labels(lab)} {_fmt(value)}")
    for name, data in sorted((stats.get("histograms") or {}).items()):
        hist = data if isinstance(data, Histogram) else Histogram.from_dict(data)
        metric = f"{_PREFIX}_{_san(name)}"
        lines.append(f"# TYPE {metric} histogram")
        for upper, cum in hist.cumulative():
            lab = dict(base)
            lab["le"] = _fmt(upper)
            lines.append(f"{metric}_bucket{_labels(lab)} {cum}")
        lines.append(f"{metric}_sum{_labels(base)} {_fmt(hist.sum)}")
        lines.append(f"{metric}_count{_labels(base)} {hist.count}")
    return "\n".join(lines) + "\n"


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-metrics/1"

    def do_GET(self):  # noqa: N802 (http.server API)
        if self.path.split("?", 1)[0] not in ("/metrics", "/metrics/"):
            self.send_error(404, "only /metrics is served here")
            return
        try:
            # the scrape thread reads telemetry dicts the serving loop
            # mutates; a concurrent insert raises "dict changed size
            # during iteration" — retry the snapshot, don't 500
            for attempt in range(3):
                try:
                    stats = self.server.stats_fn()  # type: ignore[attr-defined]
                    body = render_stats(
                        stats,
                        labels=self.server.metric_labels,  # type: ignore[attr-defined]
                    ).encode()
                    break
                except RuntimeError:
                    if attempt == 2:
                        raise
            status, ctype = 200, "text/plain; version=0.0.4; charset=utf-8"
        except Exception as exc:  # scrape must not take serving down
            logger.exception("stats render failed")
            body = json.dumps({"error": type(exc).__name__,
                               "message": str(exc)}).encode()
            status, ctype = 500, "application/json"
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):  # silence per-scrape stderr noise
        pass


class MetricsServer:
    """Threaded ``GET /metrics`` endpoint over a ``stats_fn``."""

    def __init__(
        self,
        stats_fn: Callable[[], Mapping],
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        labels: Optional[Mapping[str, str]] = None,
    ):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.stats_fn = stats_fn  # type: ignore[attr-defined]
        self._httpd.metric_labels = dict(labels or {})  # type: ignore[attr-defined]
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            kwargs={"poll_interval": 0.2},
            name=f"metrics:{self.port}",
            daemon=True,
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __repr__(self) -> str:
        return f"MetricsServer(http://{self.host}:{self.port}/metrics)"

"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
touches no jax device state.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod, or 2x16x16 (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devices)} present; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py does this automatically)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Small emulated mesh for CPU pipeline tests (e.g. (1, 4) stages)."""
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices, have {len(devices)}")
    return jax.make_mesh(shape, axes, devices=devices[:need])

"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Modes, per model family:
- LSTM-AE: anomaly-detection service (``repro.engine.AnomalyService``) on a
  named execution schedule — ``--schedule sequential|wavefront|pipelined``
  (wavefront is the paper's deployment).
- LSTM-AE with ``--gateway``: the streaming gateway — a ``--capacity``-slot
  session pool with admit/evict churn plus a micro-batched one-shot scoring
  queue (``--max-batch`` / ``--max-wait-ms``); prints gateway telemetry.
- LSTM-AE with ``--http``: the same gateway behind the asyncio socket
  transport (``--host`` / ``--port``; bp1 binary frames with per-connection
  JSON-lines fallback, background pump, graceful drain on SIGINT/SIGTERM)
  — drive it with ``examples/gateway_client.py``.
- LSTM-AE with ``--http --workers N``: the multi-worker front
  (``repro.gateway.workers``) — N worker processes share one
  ``SO_REUSEPORT`` port, each with its own engine (and its own
  ``--mesh data=K`` placement shard); the supervisor respawns crashes and
  coordinates the SIGTERM drain (every worker answers all pending
  tickets; the exit line reports per-worker clean exits and dropped
  tickets).  With ``--store-dir`` both transport modes serve DURABLE
  sessions: snapshots + signed resumption tokens, crash-resume on any
  worker, drain-handoff (README §Durability).  With ``--slo-p95-ms`` /
  ``--priority-classes`` / ``--autoscale MIN:MAX`` either transport mode
  runs the ADAPTIVE control plane (``repro.control``): SLO-driven
  batching-knob tuning, priority-aware admission, and (workers mode)
  drain-based worker autoscaling (README §Control plane).
- LM families: batched prefill + greedy decode of a few tokens (reduced
  configs on CPU; full configs need a pod mesh).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, list_archs, reduced_config
from repro.core.latency import PAPER_RH_M
from repro.data import TimeseriesConfig, make_batch
from repro.engine import AnomalyService, EngineConfig, Placement, available_schedules
from repro.models import build_model
from repro.serving import greedy_decode_loop


def engine_cfg_for(args) -> "object":
    """The engine selection for this invocation: the bare schedule name,
    or a full EngineConfig carrying the ``--mesh`` placement (e.g.
    ``--mesh data=2`` shards pool slots and micro-batch rows 2-way)."""
    if not args.mesh:
        return args.schedule
    return EngineConfig(
        schedule=args.schedule, placement=Placement.from_spec(args.mesh)
    )


def parse_autoscale(spec):
    """``--autoscale MIN:MAX`` -> ``(min, max)`` worker bounds (or None)."""
    if not spec:
        return None
    try:
        lo, hi = (int(p) for p in spec.split(":", 1))
    except ValueError:
        raise SystemExit(f"--autoscale expects MIN:MAX, got {spec!r}")
    if lo < 1 or hi < lo:
        raise SystemExit(f"--autoscale needs 1 <= MIN <= MAX, got {spec!r}")
    return lo, hi


def control_cfg_for(args, *, autoscale=None):
    """The :class:`repro.control.ControlConfig` this invocation asked
    for, or None when no control-plane flag is set (legacy behaviour:
    flat admission, static knobs, fixed fleet)."""
    wants = (args.slo_p95_ms is not None or args.priority_classes > 1
             or args.tenant_rate is not None or autoscale is not None)
    if not wants:
        return None
    from repro.control import ControlConfig

    return ControlConfig(
        slo_p95_ms=args.slo_p95_ms,
        tick_interval_s=args.control_tick_s,
        priority_classes=args.priority_classes,
        tenant_rate=args.tenant_rate,
        autoscale_min=autoscale[0] if autoscale else None,
        autoscale_max=autoscale[1] if autoscale else None,
        floor_timesteps=args.seq_len,
        arch=args.arch,
        extra={"max_wait_ms": args.max_wait_ms},
    )


def serve_lstm_ae(cfg, args) -> None:
    svc = AnomalyService(cfg, schedule=engine_cfg_for(args))
    data_cfg = TimeseriesConfig(features=cfg.lstm_ae.input_features,
                                seq_len=args.seq_len, batch=args.batch,
                                anomaly_rate=0.05)
    if args.train_steps:
        fit_cfg = TimeseriesConfig(features=cfg.lstm_ae.input_features,
                                   seq_len=args.seq_len, batch=64)
        metrics = svc.fit(fit_cfg, args.train_steps)
        svc.calibrate(fit_cfg)
        print(f"[serve] fitted {cfg.name}: mse={metrics['mse']:.4f}, "
              f"threshold={svc.threshold:.4f}")

    series, _ = make_batch(data_cfg, 0)
    jax.block_until_ready(svc.score(series))  # compile
    total_alerts = 0
    t0 = time.perf_counter()
    for i in range(args.requests):
        series, _ = make_batch(data_cfg, i)
        errors = jax.block_until_ready(svc.score(series))
        if svc.threshold is not None:
            total_alerts += int((errors > svc.threshold).sum())
    dt = time.perf_counter() - t0
    steps = args.requests * args.batch * args.seq_len
    print(f"[serve] {cfg.name} [{svc.engine.schedule.tag}]: {args.requests} requests, "
          f"{dt/args.requests*1e3:.2f} ms/request, {steps/dt:,.0f} timesteps/s"
          + (f", alerts={total_alerts}" if svc.threshold is not None else ""))
    if cfg.name in PAPER_RH_M:  # Eq-1 is calibrated only for Table-1 archs
        est = svc.latency_model(args.seq_len)
        print(f"[serve] Eq-1 model ({est.schedule}) for one sequence "
              f"T={args.seq_len}: {est.ms:.3f} ms ({est.cycles} cycles)")


def serve_gateway(cfg, args) -> None:
    """Drive the streaming gateway: pooled sessions with churn + a
    micro-batched one-shot request stream, then print its telemetry."""
    svc = AnomalyService(cfg, schedule=engine_cfg_for(args))
    feats = cfg.lstm_ae.input_features
    if args.train_steps:
        fit_cfg = TimeseriesConfig(features=feats, seq_len=args.seq_len, batch=64)
        svc.fit(fit_cfg, args.train_steps)
        svc.calibrate(fit_cfg)
        print(f"[gateway] fitted {cfg.name}: threshold={svc.threshold:.4f}")

    gw = svc.open_gateway(capacity=args.capacity, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms)
    print(f"[gateway] {gw!r}")

    # --- streaming phase: more logical streams than slots, admit/evict churn
    from repro.gateway import drive_stream_churn

    n_streams = args.streams or 2 * args.capacity
    data_cfg = TimeseriesConfig(features=feats, seq_len=args.seq_len,
                                batch=n_streams, anomaly_rate=0.05, seed=7)
    series, _ = make_batch(data_cfg, 0)
    xs = np.asarray(series)                      # (N, T, F)
    t0 = time.perf_counter()
    finals, unserved = drive_stream_churn(gw, xs)
    dt = time.perf_counter() - t0
    stepped = int(gw.stats()["counters"]["pool.stream_steps"])
    print(f"[gateway] streamed {len(finals)}/{n_streams} logical streams over "
          f"{gw.pool.capacity} slots: {stepped/dt:,.0f} stream-steps/s "
          f"({dt*1e3:.1f} ms wall)"
          + (f", {len(unserved)} still waiting at end" if unserved else ""))

    # --- one-shot phase: micro-batched score requests (mixed lengths)
    lens = [max(4, args.seq_len - (i % 3) * 2) for i in range(args.requests)]
    tickets = []
    for i, L in enumerate(lens):
        tickets.append(gw.submit(xs[i % n_streams, :L]))
        gw.pump()
    gw.flush()
    scores = np.array([t.score for t in tickets])
    # NB: "is not None" — a calibrated threshold of 0.0 is a real threshold
    alerts = int((scores > svc.threshold).sum()) if svc.threshold is not None else 0
    s = gw.stats()
    print(f"[gateway] scored {len(tickets)} one-shot requests "
          f"(fill={s['batch_fill_ratio']:.2f}, "
          f"p50={s['latency_ms']['p50']:.2f}ms, "
          f"p95={s['latency_ms']['p95']:.2f}ms)"
          + (f", alerts={alerts}" if svc.threshold is not None else ""))
    # rates: lifetime averages for the run summary, plus the sliding
    # 10 s window the control plane actually steers on
    print(f"[gateway] stats: schedule={s['schedule']} "
          f"stream_steps_per_s={s['stream_steps_per_s']:,.0f} "
          f"requests_per_s={s['requests_per_s']:,.0f} "
          f"arrival_rps_window={s['arrival_rps_window']:,.0f} "
          f"rejected={s['counters'].get('queue.rejected', 0):.0f}")


def serve_http(cfg, args) -> None:
    """Run the socket transport (``repro.gateway.server``) in front of
    the gateway until SIGINT/SIGTERM, then drain gracefully.  Serves the
    bp1 binary frame protocol to clients that negotiate it and falls
    back to JSON lines per connection.  Clients:
    ``examples/gateway_client.py`` or
    ``repro.gateway.client.GatewayClient``."""
    from repro.gateway.server import GatewayServer

    svc = AnomalyService(cfg, schedule=engine_cfg_for(args))
    if args.train_steps:
        fit_cfg = TimeseriesConfig(features=cfg.lstm_ae.input_features,
                                   seq_len=args.seq_len, batch=64)
        svc.fit(fit_cfg, args.train_steps)
        svc.calibrate(fit_cfg)
        print(f"[http] fitted {cfg.name}: threshold={svc.threshold:.4f}",
              flush=True)
    gw = svc.open_gateway(capacity=args.capacity, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms)
    if args.store_dir:
        from repro.gateway.durability import enable_durability

        enable_durability(gw, args.store_dir,
                          snapshot_interval_ms=args.snapshot_interval_ms)
    if args.event_dir:
        gw.attach_event_log(os.path.join(args.event_dir, "server.jsonl"))
        gw.events.emit("boot", pid=os.getpid())
    ccfg = control_cfg_for(args)
    if ccfg is not None:
        from repro.control import enable_control

        enable_control(gw, ccfg, event_dir=args.event_dir or None)
    metrics = None
    if args.metrics_port is not None:
        from repro.obs import MetricsServer

        metrics = MetricsServer(gw.stats, host=args.host,
                                port=args.metrics_port).start()
    server = GatewayServer(gw, host=args.host, port=args.port)

    def _ready(srv) -> None:
        mesh = (f", mesh={gw.placement.data_shards}x{gw.placement.data_axis}"
                if gw.placement.is_sharded else "")
        durable = f", store={args.store_dir}" if args.store_dir else ""
        control = ""
        if gw.control is not None:
            control = (f", slo_p95_ms={args.slo_p95_ms}, "
                       f"priority_classes={args.priority_classes}")
        scrape = f" metrics_port={metrics.port}" if metrics else ""
        print(f"[http] listening on {srv.host}:{srv.port}{scrape} "
              f"protocols=bp1+json "
              f"(schedule={gw.engine.schedule.tag}, capacity={gw.pool.capacity}, "
              f"max_batch={gw.batcher.max_batch}, "
              f"max_wait_ms={gw.batcher.max_wait_ms}{mesh}{durable}"
              f"{control})", flush=True)

    import asyncio

    asyncio.run(server.run_until_signal(on_ready=_ready))
    if metrics is not None:
        metrics.stop()
    s = gw.stats()
    print(f"[http] drained: {s['counters'].get('queue.completed', 0):.0f} one-shot "
          f"scores ({s['counters'].get('queue.failed', 0):.0f} failed, "
          f"{s['counters'].get('queue.rejected', 0):.0f} rejected), "
          f"{s['counters'].get('pool.stream_steps', 0):.0f} stream-steps over "
          f"{s['counters'].get('pool.admitted', 0):.0f} sessions", flush=True)


def serve_workers(cfg, args) -> None:
    """Run the multi-worker front: ``--workers N`` processes behind one
    ``SO_REUSEPORT`` port, each worker on its own ``--mesh`` placement
    shard, until SIGINT/SIGTERM; then coordinated drain with a per-worker
    summary (smoke asserts every worker exits cleanly, zero dropped).

    The per-worker build is ``workers.default_gateway_factory`` (runs IN
    each worker; with ``--train-steps`` every worker re-fits
    deterministically from the same seed, so all workers serve identical
    params without shipping arrays across processes)."""
    import functools

    from repro.gateway.workers import WorkerFront, default_gateway_factory

    mesh_ways = Placement.from_spec(args.mesh).data_shards if args.mesh else 1
    env = {}
    if mesh_ways > 1 and "XLA_FLAGS" not in os.environ:
        # CPU emulation of a per-worker K-device mesh; on real hardware
        # set XLA_FLAGS yourself and this passthrough stays out of the way
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={mesh_ways}")
    autoscale = parse_autoscale(args.autoscale)
    n_workers = args.workers
    if autoscale:
        # start inside the declared bounds; the autoscaler moves from here
        n_workers = min(max(n_workers, autoscale[0]), autoscale[1])
    front = WorkerFront(
        functools.partial(
            default_gateway_factory, args.arch, args.schedule,
            reduced=args.reduced, train_steps=args.train_steps,
            train_seq_len=args.seq_len, capacity=args.capacity,
            max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
            mesh=mesh_ways, warm_seq_len=args.seq_len,
            priority_classes=args.priority_classes,
            tenant_rate=args.tenant_rate,
        ),
        n_workers=n_workers, host=args.host, port=args.port, env=env,
        store_dir=args.store_dir or None,
        snapshot_interval_ms=args.snapshot_interval_ms,
        event_dir=args.event_dir or None,
        metrics_port=args.metrics_port,
    )
    ccfg = control_cfg_for(args, autoscale=autoscale)
    loop = None
    if ccfg is not None and (ccfg.slo_p95_ms is not None or ccfg.autoscaling):
        from repro.control import ControlLoop

        loop = ControlLoop(front, ccfg, lanes=args.max_batch,
                           model_cfg=cfg.lstm_ae,
                           event_dir=args.event_dir or None)

    def _ready(f) -> None:
        scrape = f" metrics_port={f.metrics.port}" if f.metrics else ""
        control = ""
        if loop is not None:
            loop.start()
            bounds = (f" autoscale={autoscale[0]}:{autoscale[1]}"
                      if autoscale else "")
            control = (f" slo_p95_ms={args.slo_p95_ms}{bounds} "
                       f"priority_classes={args.priority_classes}")
        print(f"[workers] listening on {f.host}:{f.port}{scrape} "
              f"protocols=bp1+json workers={n_workers} mesh={mesh_ways}xdata "
              f"(schedule={args.schedule}, capacity={args.capacity} and "
              f"max_batch={args.max_batch} per worker){control}", flush=True)

    summary = front.run_until_signal(on_ready=_ready)
    c = summary["counters"]
    print(f"[workers] drained: {summary['clean_exits']}/{summary['workers']} "
          f"workers exited cleanly, {summary['dropped_tickets']} dropped "
          f"tickets, {c.get('queue.completed', 0):.0f} one-shot scores "
          f"({c.get('queue.failed', 0):.0f} failed, "
          f"{c.get('queue.rejected', 0):.0f} rejected), "
          f"{c.get('pool.stream_steps', 0):.0f} stream-steps over "
          f"{c.get('pool.admitted', 0):.0f} sessions, "
          f"restarts={summary['restarts']}, "
          f"sessions_migrated={summary.get('sessions_migrated', 0)}, "
          f"sessions_lost={summary['sessions_lost']}", flush=True)


def serve_lm(cfg, args) -> None:
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    b, s = args.batch, args.seq_len
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "whisper":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision_stub":
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.vision_patches, cfg.d_model), jnp.bfloat16)

    t0 = time.perf_counter()
    logits, prefill_state = jax.jit(lambda p, bt: api.prefill(p, bt))(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    cache = api.init_cache(b, s + args.decode_tokens)
    first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    out_tokens, _ = jax.jit(
        lambda p, c, f: greedy_decode_loop(api, p, c, f, jnp.int32(s), args.decode_tokens)
    )(params, cache, first)
    jax.block_until_ready(out_tokens)
    t_decode = time.perf_counter() - t0
    print(f"[serve] {cfg.name}: prefill({b}x{s})={t_prefill*1e3:.1f}ms, "
          f"{args.decode_tokens} tokens decoded in {t_decode*1e3:.1f}ms "
          f"({b*args.decode_tokens/t_decode:,.0f} tok/s)")
    print(f"[serve] sample continuation: {out_tokens[0, :8].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--schedule", default="wavefront", choices=available_schedules(),
                    help="LSTM-AE execution schedule (engine registry name)")
    ap.add_argument("--mesh", default=None, metavar="data=N",
                    help="device placement, e.g. 'data=2': shard gateway "
                         "pool slots and micro-batch rows N-way over the "
                         "data mesh axis (needs N devices; see README "
                         "§Placement)")
    ap.add_argument("--train-steps", type=int, default=0,
                    help="fit+calibrate the detector before serving (LSTM-AE)")
    ap.add_argument("--gateway", action="store_true",
                    help="serve through the streaming gateway (LSTM-AE): "
                         "session pool + micro-batched one-shot queue")
    ap.add_argument("--http", action="store_true",
                    help="serve the gateway over the socket transport "
                         "(bp1 binary frames, JSON-lines fallback) until "
                         "SIGTERM (LSTM-AE); see README §Transport")
    ap.add_argument("--workers", type=int, default=0,
                    help="fork N gateway worker processes sharing one "
                         "SO_REUSEPORT port (implies --http); each worker "
                         "gets its own engine and --mesh placement shard; "
                         "see README §Workers")
    ap.add_argument("--host", default="127.0.0.1",
                    help="transport bind host (--http)")
    ap.add_argument("--port", type=int, default=0,
                    help="transport bind port; 0 picks an ephemeral port "
                         "(printed on the 'listening on' line)")
    ap.add_argument("--capacity", type=int, default=32,
                    help="gateway session-pool slots")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="gateway micro-batch flush size")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="gateway micro-batch max queueing delay")
    ap.add_argument("--streams", type=int, default=0,
                    help="gateway logical streams (default 2x capacity)")
    ap.add_argument("--store-dir", default=None,
                    help="enable durable sessions: snapshot pool state into "
                         "this directory and return signed resumption "
                         "tokens on step responses (--http / --workers; "
                         "see README §Durability)")
    ap.add_argument("--snapshot-interval-ms", type=float, default=1000.0,
                    help="durability snapshot cadence (with --store-dir)")
    ap.add_argument("--slo-p95-ms", type=float, default=None,
                    help="declare a p95 one-shot-latency SLO (ms): the "
                         "control plane tunes max_batch/max_wait_ms each "
                         "tick to meet it (--http / --workers; README "
                         "§Control plane)")
    ap.add_argument("--priority-classes", type=int, default=1,
                    help="admission priority classes (1 = flat legacy "
                         "admission).  Clients tag requests with "
                         "'priority' 0..N-1; under overload the HIGHEST "
                         "class number sheds first")
    ap.add_argument("--tenant-rate", type=float, default=None,
                    help="per-tenant token-bucket admission rate "
                         "(requests/s; clients tag requests with "
                         "'tenant')")
    ap.add_argument("--autoscale", default=None, metavar="MIN:MAX",
                    help="with --workers: let the supervisor's control "
                         "loop scale the fleet between MIN and MAX "
                         "workers from measured arrival rate and queue "
                         "saturation (scale-down is a zero-drop "
                         "snapshot-handoff drain)")
    ap.add_argument("--control-tick-s", type=float, default=1.0,
                    help="control-plane tick interval (seconds)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="expose GET /metrics (Prometheus text) on this "
                         "port; 0 picks an ephemeral port (printed as "
                         "metrics_port= on the 'listening on' line).  With "
                         "--workers N the supervisor serves the "
                         "front-aggregated view here and worker i serves "
                         "its own on port+1+i (README §Observability)")
    ap.add_argument("--event-dir", default=None,
                    help="append lifecycle events + sampled request spans "
                         "as JSONL under this directory (one file per "
                         "process; README §Observability)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family == "lstm_ae":
        if args.workers:
            serve_workers(cfg, args)
        elif args.http:
            serve_http(cfg, args)
        elif args.gateway:
            serve_gateway(cfg, args)
        else:
            serve_lstm_ae(cfg, args)
    else:
        serve_lm(cfg, args)


if __name__ == "__main__":
    main()

"""Launchers: mesh construction, multi-pod dry-run, training CLI.

NOTE: do not import repro.launch.dryrun from library code — it sets
XLA_FLAGS at import time (by design, per the dry-run contract).
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  Everything below is ordinary code.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import TrainConfig, get_config, list_archs, shapes_for
from repro.config.core import ShapeConfig
from repro.distributed.sharding import (
    rules_for_mesh,
    spec_tree_to_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, cache_struct, input_specs
from repro.models.api import ModelAPI
from repro.roofline.extract import build_report, model_flops_estimate
from repro.serving import build_decode_step, build_prefill_step
from repro.training import build_train_step, init_train_state, train_state_specs


def _batch_shardings(specs: dict, mesh, rules):
    """Input batches: leading dim is the global batch -> P(batch, ...)."""
    out = {}
    for name, sds in specs.items():
        if name == "cache_len":
            out[name] = NamedSharding(mesh, P())
        else:
            out[name] = NamedSharding(
                mesh, rules.spec(("batch",) + (None,) * (len(sds.shape) - 1))
            )
    return out


def _sanitize(shardings_tree, struct_tree, mesh):
    """Null out sharded dims that don't divide evenly (jit arg shardings
    require divisibility; e.g. whisper's 51866 vocab over 16, or the
    long_500k global_batch=1 over the data axis)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(sh, sds):
        if not isinstance(sh, NamedSharding):
            return sh
        new = []
        changed = False
        for i, axes in enumerate(tuple(sh.spec)):
            if axes is None:
                new.append(None)
                continue
            ax_tuple = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for a in ax_tuple:
                size *= axis_sizes[a]
            if i >= len(sds.shape) or sds.shape[i] % size != 0:
                new.append(None)
                changed = True
            else:
                new.append(axes)
        return NamedSharding(mesh, P(*new)) if changed else sh

    return jax.tree.map(fix, shardings_tree, struct_tree)


def lower_cell(arch: str, shape: ShapeConfig, multi_pod: bool, opt: bool = False):
    """Build + lower + compile one (arch x shape x mesh) cell.

    ``opt=False`` is the baseline configuration (naive settings); ``opt=True``
    applies the §Perf hillclimb levers (causal-wedge q-chunking, unrolled
    decode cache updates).  Returns (compiled, lowered, mesh, api).
    """
    cfg = get_config(arch)
    if opt:
        import dataclasses
        cfg = cfg.with_overrides(decode_loop="unroll", bwd_constrain=True)
        if cfg.rwkv is not None:
            cfg = cfg.with_overrides(
                rwkv=dataclasses.replace(cfg.rwkv, scan_impl="chunked")
            )
        if cfg.moe is not None:
            cfg = cfg.with_overrides(
                moe=dataclasses.replace(cfg.moe, impl="ep_a2a")
            )
    api = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_mesh(mesh)
    specs = input_specs(cfg, shape)
    batch_sh = _sanitize(_batch_shardings(specs, mesh, rules), specs, mesh)
    param_struct_ = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    param_sh = _sanitize(
        spec_tree_to_shardings(mesh, rules, api.param_specs()), param_struct_, mesh
    )
    q_chunks = 8 if (opt and shape.seq_len >= 8192) else 1

    if shape.kind == "train":
        tc = TrainConfig()
        step = build_train_step(api, tc, mesh, rules)
        state_struct = jax.eval_shape(
            lambda: init_train_state(api, jax.random.PRNGKey(0), tc)
        )
        state_sh = _sanitize(
            spec_tree_to_shardings(mesh, rules, train_state_specs(api, tc)),
            state_struct, mesh,
        )
        lowered = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        ).lower(state_struct, specs)

    elif shape.kind == "prefill":
        step = build_prefill_step(api, mesh, rules, q_chunks=q_chunks)
        lowered = jax.jit(
            step,
            in_shardings=(param_sh, batch_sh),
            out_shardings=None,
        ).lower(param_struct_, specs)

    else:  # decode
        step = build_decode_step(api, mesh, rules)
        cache = cache_struct(api, shape.global_batch, shape.seq_len)
        cache_sh = _sanitize(
            spec_tree_to_shardings(mesh, rules, api.cache_specs()), cache, mesh
        )
        logits_struct = jax.ShapeDtypeStruct(
            (shape.global_batch, 1, cfg.vocab_size), jnp.dtype(cfg.compute_dtype)
        )
        logits_sh = _sanitize(
            NamedSharding(mesh, rules.spec(("batch", None, "tp"))), logits_struct, mesh
        )
        lowered = jax.jit(
            step,
            in_shardings=(param_sh, batch_sh["token"], cache_sh, NamedSharding(mesh, P())),
            out_shardings=(logits_sh, cache_sh),
            donate_argnums=(2,),
        ).lower(param_struct_, specs["token"], cache, specs["cache_len"])

    compiled = lowered.compile()
    return compiled, lowered, mesh, api


def run_cell(arch: str, shape: ShapeConfig, multi_pod: bool, out_dir: Path,
             opt: bool = False) -> dict:
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    cell_id = f"{arch}__{shape.name}__{mesh_name}"
    out_path = out_dir / f"{cell_id}.json"
    if out_path.exists():
        return json.loads(out_path.read_text())

    t0 = time.time()
    status = "ok"
    try:
        compiled, lowered, mesh, api = lower_cell(arch, shape, multi_pod, opt=opt)
        chips = mesh.devices.size
        try:
            mem = compiled.memory_analysis()
            mem_str = str(mem)
        except Exception as e:  # CPU backend may not implement it
            mem_str = f"unavailable on backend: {e}"
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        # persist the partitioned HLO (zstd) so the cost model can be
        # re-applied without recompiling
        try:
            import zstandard

            out_dir.mkdir(parents=True, exist_ok=True)
            (out_dir / f"{cell_id}.hlo.zst").write_bytes(
                zstandard.ZstdCompressor(level=6).compress(hlo.encode())
            )
        except Exception:
            pass
        cfg = get_config(arch)
        report = build_report(
            arch=arch,
            shape=shape.name,
            mesh_name=mesh_name,
            chips=chips,
            cost=cost,
            hlo_text=hlo,
            model_flops=model_flops_estimate(cfg, shape),
            memory_analysis=mem_str,
        )
        record = json.loads(report.to_json())
        record["status"] = status
        record["compile_s"] = time.time() - t0
        print(f"[dryrun] memory_analysis: {mem_str[:400]}", flush=True)
        print(
            f"[dryrun] cost_analysis: flops={cost.get('flops')} "
            f"bytes={cost.get('bytes accessed')}",
            flush=True,
        )
    except Exception as e:
        record = {
            "arch": arch,
            "shape": shape.name,
            "mesh": mesh_name,
            "status": f"error: {e}",
            "traceback": traceback.format_exc(),
            "compile_s": time.time() - t0,
        }
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=1))
    flag = record["status"] if record["status"] != "ok" else (
        f"ok  dominant={record['dominant']} compute={record['compute_s']:.4g}s "
        f"memory={record['memory_s']:.4g}s coll={record['collective_s']:.4g}s"
    )
    print(f"[dryrun] {cell_id}: {flag} ({record['compile_s']:.1f}s compile)", flush=True)
    return record


def placement_report(args) -> dict:
    """Offline serving roofline for one gateway placement: per-shard
    micro-batch geometry + the Eq-1 latency floor, then the autoscaler
    bounds (``--autoscale MIN:MAX``) that cover ``--target-rps`` — so a
    control-plane deployment can be sanity-checked before any worker is
    forked.  Purely analytic (latency model, no compile)."""
    import math

    from repro.config import reduced_config
    from repro.core.latency import PAPER_RH_M, serving_floor_ms
    from repro.engine import Placement
    from repro.gateway.queue import bucket_for

    if not args.arch:
        raise SystemExit("--placement needs --arch")
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family != "lstm_ae":
        raise SystemExit(f"--placement reports on LSTM-AE archs, "
                         f"not {cfg.family}")
    pl = Placement.from_spec(args.placement)
    lanes = pl.pad_rows(args.max_batch)
    rows_per_shard = lanes // pl.data_shards
    t_bucket = bucket_for(args.seq_len)
    floor_ms = serving_floor_ms(cfg.lstm_ae, t_bucket, arch=args.arch)
    # per-worker sustainable rate: one full flush per floor, derated 50%
    # for assemble/wire overheads (matches repro.control's estimate)
    worker_rps = 0.5 * lanes / (max(floor_ms, 1e-3) / 1e3)
    report = {
        "arch": args.arch,
        "placement": str(pl),
        "data_shards": pl.data_shards,
        "lanes": lanes,
        "rows_per_shard": rows_per_shard,
        "bucket_T": t_bucket,
        "floor_ms": floor_ms,
        "worker_rps": worker_rps,
        "eq1_calibrated": args.arch in PAPER_RH_M,
    }
    print(f"[dryrun] placement {pl!r}: {lanes} micro-batch lanes "
          f"({rows_per_shard}/shard x {pl.data_shards} shards), "
          f"bucket T={t_bucket}: floor={floor_ms:.3f} ms/flush, "
          f"~{worker_rps:,.0f} req/s per worker", flush=True)
    if args.slo_p95_ms is not None:
        budget = args.slo_p95_ms - floor_ms
        report["slo_p95_ms"] = args.slo_p95_ms
        report["slo_budget_ms"] = budget
        verdict = ("feasible" if budget > 0 else "INFEASIBLE")
        print(f"[dryrun] SLO p95={args.slo_p95_ms:.1f} ms: {verdict} "
              f"(compute floor {floor_ms:.3f} ms leaves "
              f"{budget:.3f} ms queueing budget)", flush=True)
    if args.target_rps is not None:
        lo = max(1, math.ceil(args.target_rps / worker_rps))
        # headroom for 2x bursts, the shape the bursty trace benchmark
        # stresses; never below lo
        hi = max(lo, math.ceil(2.0 * args.target_rps / worker_rps))
        report["target_rps"] = args.target_rps
        report["autoscale_min"] = lo
        report["autoscale_max"] = hi
        print(f"[dryrun] target {args.target_rps:,.0f} req/s: recommend "
              f"--autoscale {lo}:{hi} (steady-state {lo} worker(s) at "
              f"{args.target_rps / (lo * worker_rps):.0%} utilization)",
              flush=True)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"placement__{args.arch}__data{pl.data_shards}.json"
    out_path.write_text(json.dumps(report, indent=1))
    print(f"[dryrun] placement report -> {out_path}", flush=True)
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run launcher")
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all for arch)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", action="store_true",
                    help="apply §Perf optimizations (baseline when absent)")
    ap.add_argument("--list", action="store_true", help="list cells and exit")
    ap.add_argument("--placement", default=None, metavar="data=N",
                    help="serving mode: report the per-shard gateway "
                         "roofline for this placement instead of "
                         "compiling cells (with --arch; see README "
                         "§Control plane)")
    ap.add_argument("--target-rps", type=float, default=None,
                    help="with --placement: arrival rate to cover; "
                         "prints the recommended --autoscale MIN:MAX")
    ap.add_argument("--slo-p95-ms", type=float, default=None,
                    help="with --placement: check the declared p95 SLO "
                         "against the Eq-1 compute floor")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="with --placement: gateway micro-batch flush "
                         "size (pre-padding)")
    ap.add_argument("--seq-len", type=int, default=64,
                    help="with --placement: request length the floor is "
                         "computed for (rounded up to its bucket)")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    args = ap.parse_args()

    if args.placement:
        placement_report(args)
        return

    archs = [args.arch] if args.arch else list_archs()
    out_dir = Path(args.out)
    cells = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            for mesh_flag in ([False, True] if args.mesh == "both" else [args.mesh == "multi"]):
                cells.append((arch, shape, mesh_flag))

    if args.list:
        for arch, shape, mp in cells:
            print(f"{arch} {shape.name} {'multi' if mp else 'single'}")
        print(f"total: {len(cells)} cells")
        return

    n_ok = 0
    for arch, shape, mp in cells:
        rec = run_cell(arch, shape, mp, out_dir, opt=args.opt)
        n_ok += rec["status"] == "ok"
    print(f"[dryrun] {n_ok}/{len(cells)} cells ok")
    if n_ok != len(cells):
        raise SystemExit(1)


if __name__ == "__main__":
    main()

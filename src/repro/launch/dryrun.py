import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  Everything below is ordinary code.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import TrainConfig, get_config, list_archs, shapes_for
from repro.config.core import ShapeConfig
from repro.distributed.sharding import (
    rules_for_mesh,
    spec_tree_to_shardings,
)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, cache_struct, input_specs
from repro.models.api import ModelAPI
from repro.roofline.extract import build_report, model_flops_estimate
from repro.serving import build_decode_step, build_prefill_step
from repro.training import build_train_step, init_train_state, train_state_specs


def _batch_shardings(specs: dict, mesh, rules):
    """Input batches: leading dim is the global batch -> P(batch, ...)."""
    out = {}
    for name, sds in specs.items():
        if name == "cache_len":
            out[name] = NamedSharding(mesh, P())
        else:
            out[name] = NamedSharding(
                mesh, rules.spec(("batch",) + (None,) * (len(sds.shape) - 1))
            )
    return out


def _sanitize(shardings_tree, struct_tree, mesh):
    """Null out sharded dims that don't divide evenly (jit arg shardings
    require divisibility; e.g. whisper's 51866 vocab over 16, or the
    long_500k global_batch=1 over the data axis)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(sh, sds):
        if not isinstance(sh, NamedSharding):
            return sh
        new = []
        changed = False
        for i, axes in enumerate(tuple(sh.spec)):
            if axes is None:
                new.append(None)
                continue
            ax_tuple = axes if isinstance(axes, tuple) else (axes,)
            size = 1
            for a in ax_tuple:
                size *= axis_sizes[a]
            if i >= len(sds.shape) or sds.shape[i] % size != 0:
                new.append(None)
                changed = True
            else:
                new.append(axes)
        return NamedSharding(mesh, P(*new)) if changed else sh

    return jax.tree.map(fix, shardings_tree, struct_tree)


def lower_cell(arch: str, shape: ShapeConfig, multi_pod: bool, opt: bool = False):
    """Build + lower + compile one (arch x shape x mesh) cell.

    ``opt=False`` is the baseline configuration (naive settings); ``opt=True``
    applies the §Perf hillclimb levers (causal-wedge q-chunking, unrolled
    decode cache updates).  Returns (compiled, lowered, mesh, api).
    """
    cfg = get_config(arch)
    if opt:
        import dataclasses
        cfg = cfg.with_overrides(decode_loop="unroll", bwd_constrain=True)
        if cfg.rwkv is not None:
            cfg = cfg.with_overrides(
                rwkv=dataclasses.replace(cfg.rwkv, scan_impl="chunked")
            )
        if cfg.moe is not None:
            cfg = cfg.with_overrides(
                moe=dataclasses.replace(cfg.moe, impl="ep_a2a")
            )
    api = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_mesh(mesh)
    specs = input_specs(cfg, shape)
    batch_sh = _sanitize(_batch_shardings(specs, mesh, rules), specs, mesh)
    param_struct_ = jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))
    param_sh = _sanitize(
        spec_tree_to_shardings(mesh, rules, api.param_specs()), param_struct_, mesh
    )
    q_chunks = 8 if (opt and shape.seq_len >= 8192) else 1

    if shape.kind == "train":
        tc = TrainConfig()
        step = build_train_step(api, tc, mesh, rules)
        state_struct = jax.eval_shape(
            lambda: init_train_state(api, jax.random.PRNGKey(0), tc)
        )
        state_sh = _sanitize(
            spec_tree_to_shardings(mesh, rules, train_state_specs(api, tc)),
            state_struct, mesh,
        )
        lowered = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        ).lower(state_struct, specs)

    elif shape.kind == "prefill":
        step = build_prefill_step(api, mesh, rules, q_chunks=q_chunks)
        lowered = jax.jit(
            step,
            in_shardings=(param_sh, batch_sh),
            out_shardings=None,
        ).lower(param_struct_, specs)

    else:  # decode
        step = build_decode_step(api, mesh, rules)
        cache = cache_struct(api, shape.global_batch, shape.seq_len)
        cache_sh = _sanitize(
            spec_tree_to_shardings(mesh, rules, api.cache_specs()), cache, mesh
        )
        logits_struct = jax.ShapeDtypeStruct(
            (shape.global_batch, 1, cfg.vocab_size), jnp.dtype(cfg.compute_dtype)
        )
        logits_sh = _sanitize(
            NamedSharding(mesh, rules.spec(("batch", None, "tp"))), logits_struct, mesh
        )
        lowered = jax.jit(
            step,
            in_shardings=(param_sh, batch_sh["token"], cache_sh, NamedSharding(mesh, P())),
            out_shardings=(logits_sh, cache_sh),
            donate_argnums=(2,),
        ).lower(param_struct_, specs["token"], cache, specs["cache_len"])

    compiled = lowered.compile()
    return compiled, lowered, mesh, api


def run_cell(arch: str, shape: ShapeConfig, multi_pod: bool, out_dir: Path,
             opt: bool = False) -> dict:
    mesh_name = "multi_pod_2x16x16" if multi_pod else "single_pod_16x16"
    cell_id = f"{arch}__{shape.name}__{mesh_name}"
    out_path = out_dir / f"{cell_id}.json"
    if out_path.exists():
        return json.loads(out_path.read_text())

    t0 = time.time()
    status = "ok"
    try:
        compiled, lowered, mesh, api = lower_cell(arch, shape, multi_pod, opt=opt)
        chips = mesh.devices.size
        try:
            mem = compiled.memory_analysis()
            mem_str = str(mem)
        except Exception as e:  # CPU backend may not implement it
            mem_str = f"unavailable on backend: {e}"
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        # persist the partitioned HLO (zstd) so the cost model can be
        # re-applied without recompiling
        try:
            import zstandard

            out_dir.mkdir(parents=True, exist_ok=True)
            (out_dir / f"{cell_id}.hlo.zst").write_bytes(
                zstandard.ZstdCompressor(level=6).compress(hlo.encode())
            )
        except Exception:
            pass
        cfg = get_config(arch)
        report = build_report(
            arch=arch,
            shape=shape.name,
            mesh_name=mesh_name,
            chips=chips,
            cost=cost,
            hlo_text=hlo,
            model_flops=model_flops_estimate(cfg, shape),
            memory_analysis=mem_str,
        )
        record = json.loads(report.to_json())
        record["status"] = status
        record["compile_s"] = time.time() - t0
        print(f"[dryrun] memory_analysis: {mem_str[:400]}", flush=True)
        print(
            f"[dryrun] cost_analysis: flops={cost.get('flops')} "
            f"bytes={cost.get('bytes accessed')}",
            flush=True,
        )
    except Exception as e:
        record = {
            "arch": arch,
            "shape": shape.name,
            "mesh": mesh_name,
            "status": f"error: {e}",
            "traceback": traceback.format_exc(),
            "compile_s": time.time() - t0,
        }
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=1))
    flag = record["status"] if record["status"] != "ok" else (
        f"ok  dominant={record['dominant']} compute={record['compute_s']:.4g}s "
        f"memory={record['memory_s']:.4g}s coll={record['collective_s']:.4g}s"
    )
    print(f"[dryrun] {cell_id}: {flag} ({record['compile_s']:.1f}s compile)", flush=True)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run launcher")
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all for arch)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", action="store_true",
                    help="apply §Perf optimizations (baseline when absent)")
    ap.add_argument("--list", action="store_true", help="list cells and exit")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    out_dir = Path(args.out)
    cells = []
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            for mesh_flag in ([False, True] if args.mesh == "both" else [args.mesh == "multi"]):
                cells.append((arch, shape, mesh_flag))

    if args.list:
        for arch, shape, mp in cells:
            print(f"{arch} {shape.name} {'multi' if mp else 'single'}")
        print(f"total: {len(cells)} cells")
        return

    n_ok = 0
    for arch, shape, mp in cells:
        rec = run_cell(arch, shape, mp, out_dir, opt=args.opt)
        n_ok += rec["status"] == "ok"
    print(f"[dryrun] {n_ok}/{len(cells)} cells ok")
    if n_ok != len(cells):
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Production training launcher: ``python -m repro.launch.train --arch <id>``.

Composes the whole stack: config registry, sharded train step (pjit over
the active mesh when devices allow), data pipeline with per-host slicing,
AdamW + schedule, async checkpointing with restart, heartbeat monitoring.
On this CPU container it runs reduced configs end-to-end; on a real pod the
same entry point runs the full configs (mesh picked from the device count).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax

from repro.config import TrainConfig, get_config, list_archs, reduced_config
from repro.checkpoint import AsyncCheckpointer, latest_checkpoint, restore_checkpoint
from repro.data import LMDataConfig, LMIterator, TimeseriesConfig, TimeseriesIterator, host_slice
from repro.distributed.fault import HeartbeatMonitor
from repro.distributed.sharding import rules_for_mesh, spec_tree_to_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training import build_train_step, init_train_state, train_state_specs
from repro.utils import tree_size


def pick_mesh():
    """Production mesh when the device count allows, else single-device."""
    n = len(jax.devices())
    if n >= 512:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh(multi_pod=False)
    return None


def make_iterator(cfg, args):
    if cfg.family == "lstm_ae":
        it = TimeseriesIterator(TimeseriesConfig(
            features=cfg.lstm_ae.input_features, seq_len=args.seq_len,
            batch=args.batch, anomaly_rate=0.0,
        ))
        return it, lambda b: {"series": b[0]}
    it = LMIterator(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len, global_batch=args.batch,
    ))
    return it, lambda b: b


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (full configs need a pod)")
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", choices=["none", "int8_ef"], default="none")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    api = build_model(cfg)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     grad_compression=args.grad_compression,
                     loss_chunk=min(2048, args.seq_len))
    mesh = pick_mesh()
    rules = rules_for_mesh(mesh) if mesh else None

    state = init_train_state(api, jax.random.PRNGKey(0), tc)
    print(f"[train] {cfg.name}: {tree_size(state.params)/1e6:.1f}M params, "
          f"mesh={'none' if mesh is None else dict(zip(mesh.axis_names, mesh.devices.shape))}")

    step_fn = build_train_step(api, tc, mesh, rules)
    if mesh is not None:
        state_sh = spec_tree_to_shardings(mesh, rules, train_state_specs(api, tc))
        step_fn = jax.jit(step_fn, in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None), donate_argnums=(0,))
        state = jax.device_put(state, state_sh)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    it, to_batch = make_iterator(cfg, args)
    ckpt_dir = args.ckpt_dir or f"/tmp/repro_ckpt_{args.arch}"
    ckpt = AsyncCheckpointer(ckpt_dir, keep=3)
    resume = latest_checkpoint(ckpt_dir)
    start = 0
    if resume is not None:
        state, meta = restore_checkpoint(resume, state)
        it.load_state_dict(meta["iterator"])
        start = meta["step"]
        print(f"[train] resumed from step {start}")

    monitor = HeartbeatMonitor()
    t_start = time.perf_counter()
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = to_batch(next(it))
        batch = host_slice(batch)
        state, metrics = step_fn(state, batch)
        monitor.report(f"host{jax.process_index()}", time.perf_counter() - t0)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d}  loss={float(metrics['loss']):.4f}  "
                  f"lr={float(metrics['lr']):.2e}  gnorm={float(metrics['grad_norm']):.2f}")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state, extra_meta={"iterator": it.state_dict()})
    ckpt.wait()
    dt = time.perf_counter() - t_start
    tokens = (args.steps - start) * args.batch * args.seq_len
    print(f"[train] done: {dt:.1f}s, {tokens/dt:,.0f} tok/s; stragglers={monitor.stragglers()}")


if __name__ == "__main__":
    main()

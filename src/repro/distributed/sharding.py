"""Logical-axis sharding: one vocabulary for layers, resolved per mesh.

Layer code annotates activations with *logical* axis names via
:func:`constrain` and parameters with logical spec tuples.  A
:class:`ShardingRules` maps logical names -> physical mesh axes; the step
builders install an :class:`ActiveMesh` context so the same model code runs
(a) unsharded on CPU tests, (b) on the 16x16 single pod, (c) on the 2x16x16
multi-pod mesh, without edits.

Logical axes
------------
``batch``   data-parallel batch dim            -> ("pod", "data") / ("data",)
``sp``      sequence-parallel residual stream  -> "model"
``tp``      tensor-parallel (heads/ffn/vocab)  -> "model"
``expert``  expert-parallel MoE dim            -> "model"
``fsdp``    fully-sharded parameter dim        -> "data"
``None``    replicated
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    """Logical name -> physical mesh axis (or tuple of axes)."""
    batch: Any = ("data",)
    sp: Any = "model"
    tp: Any = "model"
    expert: Any = "model"
    fsdp: Any = "data"
    tokens: Any = ("data", "model")  # flattened (batch*seq) token dim

    def physical(self, logical: Optional[str]):
        if logical is None:
            return None
        try:
            return getattr(self, logical)
        except AttributeError:
            raise KeyError(f"unknown logical axis {logical!r}") from None

    def spec(self, logical_axes: Sequence[Optional[str]]) -> P:
        return P(*[self.physical(a) for a in logical_axes])


def rules_for_mesh(mesh: Mesh) -> ShardingRules:
    """Default rules: batch over (pod, data) when a pod axis exists."""
    if "pod" in mesh.axis_names:
        return ShardingRules(batch=("pod", "data"), tokens=("pod", "data", "model"))
    return ShardingRules()


@dataclass
class ActiveMesh:
    mesh: Mesh
    rules: ShardingRules


_STATE = threading.local()


def _current() -> Optional[ActiveMesh]:
    return getattr(_STATE, "active", None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], rules: Optional[ShardingRules] = None):
    """Install the mesh for :func:`constrain`; ``None`` disables constraints."""
    prev = _current()
    if mesh is None:
        _STATE.active = None
    else:
        _STATE.active = ActiveMesh(mesh, rules or rules_for_mesh(mesh))
    try:
        yield
    finally:
        _STATE.active = prev


def active_mesh() -> Optional[Mesh]:
    ctx = _current()
    return ctx.mesh if ctx else None


def active_rules() -> Optional[ShardingRules]:
    ctx = _current()
    return ctx.rules if ctx else None


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    ctx = _current()
    if ctx is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"spec {logical_axes} does not match rank-{x.ndim} array")
    spec = ctx.rules.spec(logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def named_sharding(mesh: Mesh, rules: ShardingRules, logical_axes) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(logical_axes))


def is_spec_leaf(v) -> bool:
    """A logical-axis spec is a tuple of None/str (e.g. ("tp", None)).
    Tuples holding dicts/sub-trees are containers, not specs."""
    return isinstance(v, tuple) and all(e is None or isinstance(e, str) for e in v)


def map_specs(fn, spec_tree):
    """tree.map over spec leaves only."""
    return jax.tree.map(fn, spec_tree, is_leaf=is_spec_leaf)


def spec_tree_to_shardings(mesh: Mesh, rules: ShardingRules, spec_tree):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return map_specs(lambda axes: named_sharding(mesh, rules, axes), spec_tree)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())

from repro.distributed.fault import (
    FailureInjector,
    HeartbeatMonitor,
    SimulatedFailure,
    run_with_recovery,
)
from repro.distributed.sharding import (
    ShardingRules,
    active_mesh,
    active_rules,
    constrain,
    is_spec_leaf,
    map_specs,
    mesh_context,
    rules_for_mesh,
    spec_tree_to_shardings,
)

__all__ = [
    "FailureInjector",
    "HeartbeatMonitor",
    "ShardingRules",
    "SimulatedFailure",
    "active_mesh",
    "active_rules",
    "constrain",
    "is_spec_leaf",
    "map_specs",
    "mesh_context",
    "rules_for_mesh",
    "run_with_recovery",
    "spec_tree_to_shardings",
]

"""Fault tolerance & straggler mitigation for long-running training.

Design (per DESIGN.md §5; exercised by tests/test_fault_tolerance.py):

* **Heartbeat / straggler detection** — the training loop reports per-step
  wall time per participant; a step slower than ``straggler_factor`` x the
  rolling p50 flags that participant.  At pod scale the launcher maps
  participants to hosts; here the unit tests inject synthetic timings.
* **Deterministic restart** — ``run_with_recovery`` wraps the step loop:
  on failure (a real exception, or an injected ``FailureInjector`` fault)
  it restores the latest checkpoint — including the data-iterator index —
  and continues; the resulting loss trajectory must equal the no-failure
  run (test-asserted), which is the property that matters at 1000+ nodes.
* **Elastic scaling** — checkpoints are mesh-agnostic (see checkpoint/),
  so recovery may resume on a different device count.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_checkpoint,
    restore_checkpoint,
)


@dataclass
class HeartbeatMonitor:
    """Rolling per-participant step-time tracking with p50-based flagging."""
    window: int = 64
    straggler_factor: float = 2.0
    history: dict = field(default_factory=dict)

    def report(self, participant: str, step_time_s: float) -> None:
        self.history.setdefault(participant, deque(maxlen=self.window)).append(step_time_s)

    def p50(self) -> float:
        times = sorted(t for h in self.history.values() for t in h)
        if not times:
            return 0.0
        return times[len(times) // 2]

    def stragglers(self) -> list[str]:
        base = self.p50()
        if base <= 0:
            return []
        out = []
        for who, h in self.history.items():
            if h and h[-1] > self.straggler_factor * base:
                out.append(who)
        return sorted(out)


class FailureInjector:
    """Deterministic fault injection for recovery tests: raises
    ``SimulatedFailure`` at the given step indices (once each)."""

    def __init__(self, fail_at_steps: tuple[int, ...] = ()):
        self.fail_at = set(fail_at_steps)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class SimulatedFailure(RuntimeError):
    pass


def run_with_recovery(
    *,
    state,
    train_step: Callable,
    iterator,
    total_steps: int,
    ckpt_dir,
    ckpt_every: int = 10,
    injector: Optional[FailureInjector] = None,
    monitor: Optional[HeartbeatMonitor] = None,
    max_restarts: int = 8,
    state_template=None,
) -> tuple[object, list[float]]:
    """Step loop with checkpoint/restart recovery.

    Returns (final state, per-step losses).  On failure, restores the
    latest checkpoint (state + iterator index) and replays from there —
    losses of replayed steps overwrite the aborted trajectory, giving a
    deterministic final history.
    """
    ckpt = AsyncCheckpointer(ckpt_dir, keep=2)
    losses: dict[int, float] = {}
    step = 0
    restarts = 0
    template = state_template if state_template is not None else state
    # step-0 anchor so pre-first-checkpoint failures restart deterministically
    if latest_checkpoint(ckpt_dir) is None:
        ckpt.save(0, state, extra_meta={"iterator": iterator.state_dict()})
        ckpt.wait()

    while step < total_steps:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.monotonic()
            batch = next(iterator)
            state, metrics = train_step(state, batch)
            dt = time.monotonic() - t0
            if monitor is not None:
                monitor.report("host0", dt)
            losses[step] = float(metrics["loss"])
            step += 1
            if step % ckpt_every == 0:
                ckpt.save(step, state, extra_meta={"iterator": iterator.state_dict()})
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt.wait()
            path = latest_checkpoint(ckpt_dir)
            assert path is not None  # step-0 anchor always exists
            state, meta = restore_checkpoint(path, template)
            iterator.load_state_dict(meta["iterator"])
            step = meta["step"]
    ckpt.wait()
    return state, [losses[i] for i in range(total_steps)]

"""Jamba [arXiv:2403.19887]: hybrid Mamba + attention (1:7) with MoE (every
2nd layer).  Layers are grouped into periods of 8 (attention at offset 4);
params are stacked per period position and the stack is scanned over
periods, keeping HLO compact (4 periods for the 32L config).

Sub-quadratic: only the 4 attention layers carry a KV cache, so the
long_500k decode shape runs for this architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.distributed.sharding import constrain
from repro.layers.attention import (
    apply_attention,
    attention_specs,
    decode_attention,
    init_attention,
    init_kv_cache,
    kv_cache_specs,
)
from repro.layers.embeddings import (
    chunked_xent_loss,
    embed_tokens,
    embedding_specs,
    init_embedding,
    init_unembed,
    unembed_logits,
    unembed_specs,
)
from repro.layers.mamba import (
    apply_mamba,
    apply_mamba_step,
    init_mamba,
    init_mamba_state,
    mamba_specs,
    mamba_state_specs,
)
from repro.layers.mlp import apply_mlp, init_mlp, mlp_specs
from repro.layers.moe import apply_moe, init_moe, moe_specs
from repro.layers.norms import apply_norm, init_norm, norm_specs
from repro.models.transformer import _stack_specs
from repro.utils import Params, split_keys

PERIOD = 8


def _n_periods(cfg: ModelConfig) -> int:
    assert cfg.num_layers % PERIOD == 0, "jamba layer count must be a multiple of 8"
    return cfg.num_layers // PERIOD


def _layer_kind(cfg: ModelConfig, j: int) -> tuple[str, str]:
    """(mixer, ffn) for period position j — static per position."""
    mixer = "attn" if j % cfg.attn_every == cfg.attn_offset else "mamba"
    ffn = "moe" if cfg.is_moe_layer(j) else "mlp"
    return mixer, ffn


def init_position(key: jax.Array, cfg: ModelConfig, j: int) -> Params:
    mixer, ffn = _layer_kind(cfg, j)
    keys = split_keys(key, ["mixer", "ffn"])
    p = {"ln1": init_norm(cfg.norm, cfg.d_model), "ln2": init_norm(cfg.norm, cfg.d_model)}
    p["mixer"] = (
        init_attention(keys["mixer"], cfg) if mixer == "attn" else init_mamba(keys["mixer"], cfg)
    )
    p["ffn"] = init_moe(keys["ffn"], cfg) if ffn == "moe" else init_mlp(keys["ffn"], cfg)
    return p


def position_specs(cfg: ModelConfig, j: int) -> Params:
    mixer, ffn = _layer_kind(cfg, j)
    return {
        "ln1": norm_specs(cfg.norm),
        "ln2": norm_specs(cfg.norm),
        "mixer": attention_specs(cfg) if mixer == "attn" else mamba_specs(cfg),
        "ffn": moe_specs(cfg) if ffn == "moe" else mlp_specs(cfg),
    }


def init_jamba(key: jax.Array, cfg: ModelConfig) -> Params:
    n_p = _n_periods(cfg)
    keys = split_keys(key, ["embed", "layers", "unembed"])
    period_keys = jax.random.split(keys["layers"], n_p * PERIOD).reshape(n_p, PERIOD, 2)
    positions = []
    for j in range(PERIOD):
        stacked = jax.vmap(lambda k, j=j: init_position(k, cfg, j))(period_keys[:, j])
        positions.append(stacked)
    return {
        "embed": init_embedding(keys["embed"], cfg.vocab_size, cfg.d_model),
        "positions": tuple(positions),
        "ln_f": init_norm(cfg.norm, cfg.d_model),
        "unembed": init_unembed(keys["unembed"], cfg.d_model, cfg.vocab_size),
    }


def jamba_specs(cfg: ModelConfig) -> Params:
    return {
        "embed": embedding_specs(),
        "positions": tuple(_stack_specs(position_specs(cfg, j)) for j in range(PERIOD)),
        "ln_f": norm_specs(cfg.norm),
        "unembed": unembed_specs(),
    }


def _ffn(lp: Params, h: jnp.ndarray, cfg: ModelConfig, j: int):
    _, ffn = _layer_kind(cfg, j)
    if ffn == "moe":
        if cfg.moe.impl == "ep_a2a":
            from repro.layers.moe import apply_moe_ep
            return apply_moe_ep(lp["ffn"], h, cfg)
        return apply_moe(lp["ffn"], h, cfg)
    return apply_mlp(lp["ffn"], h, cfg), jnp.float32(0.0)


def init_states(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    """Decode state tree: tuple over period positions, stacked over periods.
    Attention positions hold a KV cache; mamba positions hold (ssm, conv)."""
    n_p = _n_periods(cfg)
    states = []
    for j in range(PERIOD):
        mixer, _ = _layer_kind(cfg, j)
        one = (
            init_kv_cache(cfg, batch, max_len, dtype)
            if mixer == "attn"
            else init_mamba_state(cfg, batch, dtype)
        )
        states.append(jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_p,) + x.shape), one))
    return tuple(states)


def state_specs(cfg: ModelConfig) -> Params:
    from repro.distributed.sharding import map_specs

    out = []
    for j in range(PERIOD):
        mixer, _ = _layer_kind(cfg, j)
        base = kv_cache_specs() if mixer == "attn" else mamba_state_specs()
        out.append(map_specs(lambda axes: (None,) + axes, base))
    return tuple(out)


def forward(
    params: Params,
    h: jnp.ndarray,
    cfg: ModelConfig,
    *,
    remat: bool = True,
    kv_chunk: int = 1024,
    q_chunks: int = 1,
    collect_state: bool = False,
):
    """h: (B, S, D) -> (h, aux, states|None).  Jamba has no positional
    embedding — the SSM layers carry position information."""

    def period_fn(carry, lp_all):
        h, aux = carry
        new_sts = []
        for j in range(PERIOD):
            lp = lp_all[j]
            mixer, _ = _layer_kind(cfg, j)
            hn = apply_norm(lp["ln1"], h, cfg.norm)
            if mixer == "attn":
                y, kv = apply_attention(
                    lp["mixer"], hn, cfg=cfg, causal=True, use_rope=False,
                    kv_chunk=kv_chunk, q_chunks=q_chunks, return_kv=True,
                )
                new_st = {"k": kv[0].astype(h.dtype), "v": kv[1].astype(h.dtype)}
            else:
                y, new_st = apply_mamba(lp["mixer"], hn, cfg)
            h = constrain(h + y, ("batch", "sp", None))
            hn = apply_norm(lp["ln2"], h, cfg.norm)
            f, aux_l = _ffn(lp, hn, cfg, j)
            h = constrain(h + f, ("batch", "sp", None))
            aux = aux + aux_l
            new_sts.append(new_st)
        return (h, aux), (tuple(new_sts) if collect_state else None)

    body = jax.checkpoint(period_fn) if remat else period_fn
    (h, aux), collected = jax.lax.scan(body, (h, jnp.float32(0.0)), params["positions"])
    return h, aux, (collected if collect_state else None)


def train_loss(params: Params, batch: dict, cfg: ModelConfig, *, remat: bool = True,
               loss_chunk: int = 2048, kv_chunk: int = 1024, q_chunks: int = 1,
               aux_weight: float = 0.01, **_) -> tuple[jnp.ndarray, dict]:
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], batch["tokens"], dtype)
    h, aux, _ = forward(params, h, cfg, remat=remat, kv_chunk=kv_chunk, q_chunks=q_chunks)
    h = apply_norm(params["ln_f"], h, cfg.norm)
    loss = chunked_xent_loss(params["unembed"]["w"], h, batch["labels"], chunk=loss_chunk)
    total = loss + aux_weight * aux
    return total, {"xent": loss, "aux": aux}


def prefill(params: Params, batch: dict, cfg: ModelConfig, *, kv_chunk: int = 1024,
            q_chunks: int = 1, **_) -> tuple[jnp.ndarray, Params]:
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], batch["tokens"], dtype)
    h, _, states = forward(
        params, h, cfg, remat=False, kv_chunk=kv_chunk, q_chunks=q_chunks,
        collect_state=True,
    )
    h = apply_norm(params["ln_f"], h, cfg.norm)
    logits = unembed_logits(params["unembed"]["w"], h[:, -1:, :])
    return logits, states


def decode_step(params: Params, token: jnp.ndarray, states: Params,
                cache_len: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, Params]:
    """One-token decode.  token: (B,1); states from :func:`init_states`."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], token, dtype)[:, 0, :]  # (B, D)

    def period_fn(h, inp):
        lp_all, st_all = inp
        new_sts = []
        for j in range(PERIOD):
            lp, st = lp_all[j], st_all[j]
            mixer, _ = _layer_kind(cfg, j)
            hn = apply_norm(lp["ln1"], h, cfg.norm)
            if mixer == "attn":
                y3, new_st = decode_attention(
                    lp["mixer"], hn[:, None, :], st, cache_len, cfg=cfg, use_rope=False
                )
                y = y3[:, 0, :]
            else:
                y, new_st = apply_mamba_step(lp["mixer"], hn, cfg, st)
            h = h + y
            hn = apply_norm(lp["ln2"], h, cfg.norm)
            f, _ = _ffn(lp, hn[:, None, :], cfg, j)
            h = h + f[:, 0, :]
            new_sts.append(new_st)
        return h, tuple(new_sts)

    h, new_states = jax.lax.scan(period_fn, h, (params["positions"], states))
    h = apply_norm(params["ln_f"], h, cfg.norm)
    logits = unembed_logits(params["unembed"]["w"], h[:, None, :])
    return logits, new_states

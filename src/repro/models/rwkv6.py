"""RWKV-6 "Finch" language model [arXiv:2404.05892].

Attention-free: per-token recurrence — the assigned architecture closest to
the paper's own setting (the temporal-parallel pipeline applies directly,
see DESIGN.md §4).  Supports train (chunked WKV scan), prefill (same scan,
emitting final states), and decode (single recurrence step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.distributed.sharding import constrain
from repro.layers.embeddings import (
    chunked_xent_loss,
    embed_tokens,
    embedding_specs,
    init_embedding,
    init_unembed,
    unembed_logits,
    unembed_specs,
)
from repro.layers.norms import apply_norm, init_norm, norm_specs
from repro.layers.rwkv import (
    apply_channel_mix,
    apply_time_mix,
    apply_time_mix_step,
    channel_mix_specs,
    init_channel_mix,
    init_time_mix,
    time_mix_specs,
)
from repro.models.transformer import _stack_specs
from repro.utils import Params, split_keys


def init_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = split_keys(key, ["tm", "cm"])
    return {
        "ln1": init_norm("layernorm", cfg.d_model),
        "tm": init_time_mix(keys["tm"], cfg),
        "ln2": init_norm("layernorm", cfg.d_model),
        "cm": init_channel_mix(keys["cm"], cfg),
    }


def layer_specs(cfg: ModelConfig) -> Params:
    return {
        "ln1": norm_specs("layernorm"),
        "tm": time_mix_specs(cfg),
        "ln2": norm_specs("layernorm"),
        "cm": channel_mix_specs(cfg),
    }


def init_rwkv6(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = split_keys(key, ["embed", "layers", "unembed"])
    layer_keys = jax.random.split(keys["layers"], cfg.num_layers)
    return {
        "embed": init_embedding(keys["embed"], cfg.vocab_size, cfg.d_model),
        "ln0": init_norm("layernorm", cfg.d_model),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "ln_f": init_norm("layernorm", cfg.d_model),
        "unembed": init_unembed(keys["unembed"], cfg.d_model, cfg.vocab_size),
    }


def rwkv6_specs(cfg: ModelConfig) -> Params:
    return {
        "embed": embedding_specs(),
        "ln0": norm_specs("layernorm"),
        "layers": _stack_specs(layer_specs(cfg)),
        "ln_f": norm_specs("layernorm"),
        "unembed": unembed_specs(),
    }


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Params:
    h, hd = cfg.d_model // cfg.rwkv.head_dim, cfg.rwkv.head_dim
    one = {
        "tm_x": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "cm_x": jnp.zeros((batch, cfg.d_model), dtype),
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape), one
    )


def state_specs() -> Params:
    return {
        "tm_x": (None, "batch", None),
        "wkv": (None, "batch", "tp", None, None),
        "cm_x": (None, "batch", None),
    }


def forward(
    params: Params,
    h: jnp.ndarray,
    cfg: ModelConfig,
    state: Params | None = None,
    *,
    remat: bool = True,
    chunk: int = 64,
):
    """h: (B, S, D) embedded inputs -> (h, new_state)."""
    b = h.shape[0]
    if state is None:
        state = init_state(cfg, b, h.dtype)

    def layer_fn(h, inp):
        lp, st = inp
        y, (tm_x, wkv) = apply_time_mix(
            lp["tm"], apply_norm(lp["ln1"], h, "layernorm"), cfg,
            x_prev=st["tm_x"].astype(h.dtype), state=st["wkv"], chunk=chunk,
        )
        h = h + y
        y, cm_x = apply_channel_mix(
            lp["cm"], apply_norm(lp["ln2"], h, "layernorm"), cfg,
            x_prev=st["cm_x"].astype(h.dtype),
        )
        h = h + y
        sp = "sp" if h.shape[1] > 1 else None
        h = constrain(h, ("batch", sp, None))
        new_st = {"tm_x": tm_x.astype(st["tm_x"].dtype), "wkv": wkv, "cm_x": cm_x.astype(st["cm_x"].dtype)}
        return h, new_st

    body = jax.checkpoint(layer_fn) if remat else layer_fn
    h, new_state = jax.lax.scan(body, h, (params["layers"], state))
    return h, new_state


def train_loss(params: Params, batch: dict, cfg: ModelConfig, *,
               remat: bool = True, loss_chunk: int = 2048, **_) -> tuple[jnp.ndarray, dict]:
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], batch["tokens"], dtype)
    h = apply_norm(params["ln0"], h, "layernorm")
    h, _ = forward(params, h, cfg, remat=remat)
    h = apply_norm(params["ln_f"], h, "layernorm")
    loss = chunked_xent_loss(params["unembed"]["w"], h, batch["labels"], chunk=loss_chunk)
    return loss, {"xent": loss}


def prefill(params: Params, batch: dict, cfg: ModelConfig, **_) -> tuple[jnp.ndarray, Params]:
    """Prefill = run the recurrence over the prompt, return final states."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], batch["tokens"], dtype)
    h = apply_norm(params["ln0"], h, "layernorm")
    h, state = forward(params, h, cfg, remat=False)
    h = apply_norm(params["ln_f"], h, "layernorm")
    logits = unembed_logits(params["unembed"]["w"], h[:, -1:, :])
    return logits, state


def decode_step(params: Params, token: jnp.ndarray, state: Params,
                cache_len: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, Params]:
    """One-token decode.  token: (B,1).  State: stacked (L, ...) tree."""
    del cache_len  # recurrent state is position-free
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], token, dtype)[:, 0, :]  # (B, D)
    h = apply_norm(params["ln0"], h, "layernorm")

    def layer_fn(h, inp):
        lp, st = inp
        y, (tm_x, wkv) = apply_time_mix_step(
            lp["tm"], apply_norm(lp["ln1"], h, "layernorm"), cfg,
            st["tm_x"].astype(h.dtype), st["wkv"],
        )
        h = h + y
        y3, cm_x = apply_channel_mix(
            lp["cm"], apply_norm(lp["ln2"], h, "layernorm")[:, None, :], cfg,
            x_prev=st["cm_x"].astype(h.dtype),
        )
        h = h + y3[:, 0, :]
        new_st = {"tm_x": tm_x.astype(st["tm_x"].dtype), "wkv": wkv, "cm_x": cm_x.astype(st["cm_x"].dtype)}
        return h, new_st

    h, new_state = jax.lax.scan(layer_fn, h, (params["layers"], state))
    h = apply_norm(params["ln_f"], h, "layernorm")
    logits = unembed_logits(params["unembed"]["w"], h[:, None, :])
    return logits, new_state

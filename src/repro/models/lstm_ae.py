"""Model-API wrapper for the paper's LSTM-AE family.

Training uses the layer-by-layer schedule (gradient math is schedule-
independent); serving delegates to the execution-engine registry
(``repro.engine``), so any named schedule — "sequential", "wavefront"
(default; the paper's accelerator execution), "pipelined" — can run the
same model.  Streaming decode carries per-layer (h, c) state, one timestep
through all layers per call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.core.lstm import (
    init_lstm_ae,
    lstm_ae_specs,
    lstm_cell,
    lstm_ae_sequential,
)
from repro.utils import Params


def train_loss(params: Params, batch: dict, cfg: ModelConfig, **_) -> tuple[jnp.ndarray, dict]:
    """batch: series (B, T, F) -> mean reconstruction MSE."""
    xs = jnp.swapaxes(batch["series"], 0, 1)  # (T, B, F)
    recon = lstm_ae_sequential(params, xs)
    err = jnp.mean(jnp.square(recon.astype(jnp.float32) - xs.astype(jnp.float32)))
    return err, {"mse": err}


def prefill(
    params: Params, batch: dict, cfg: ModelConfig, schedule: str = "wavefront", **_
) -> tuple[jnp.ndarray, Params]:
    """Serve a batch of sequences on the named execution schedule (resolved
    from the engine registry); returns per-sequence reconstruction errors
    (the anomaly scores)."""
    # lazy import: repro.engine.service imports repro.models at module scope
    from repro.engine.schedules import resolve_forward

    forward = resolve_forward(schedule, cfg)
    xs = jnp.swapaxes(batch["series"], 0, 1)
    recon = forward(params, xs)
    err = jnp.mean(jnp.square(recon.astype(jnp.float32) - xs.astype(jnp.float32)), axis=(0, 2))
    return err, {}


def init_stream_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    sizes = cfg.lstm_ae.layer_sizes()
    return {
        "h": tuple(jnp.zeros((batch, s), dtype) for s in sizes),
        "c": tuple(jnp.zeros((batch, s), jnp.float32) for s in sizes),
    }


def decode_step(params: Params, x_t: jnp.ndarray, state: Params,
                cache_len: jnp.ndarray, cfg: ModelConfig,
                pwl: bool = False) -> tuple[jnp.ndarray, Params]:
    """One streaming timestep x_t (B, F) through all layers.  A single
    timestep admits no temporal parallelism (Eq 1 with T=1), so this one
    cell loop serves every schedule — ``Engine.stream`` delegates here."""
    del cache_len
    hs, cs = [], []
    cur = x_t
    for layer, h, c in zip(params["layers"], state["h"], state["c"]):
        h_new, c_new = lstm_cell(layer, cur, h, c, pwl=pwl)
        hs.append(h_new)
        cs.append(c_new)
        cur = h_new
    return cur, {"h": tuple(hs), "c": tuple(cs)}

"""Uniform model API: one entry point per family, plus the dry-run
``input_specs`` (ShapeDtypeStruct stand-ins; no device allocation).

``build_model(cfg)`` returns a :class:`ModelAPI` with:

- init(key) -> params
- param_specs() -> logical-axis spec tree (mirrors params)
- loss(params, batch) -> (scalar, metrics)        [train shapes]
- prefill(params, batch) -> (logits/scores, cache) [prefill shapes]
- decode(params, token, cache, cache_len) -> (logits, cache) [decode shapes]
- init_cache(batch, max_len) / cache_specs()       [decode state]
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig, ShapeConfig
from repro.core.lstm import init_lstm_ae, lstm_ae_specs
from repro.models import jamba as jamba_m
from repro.models import lstm_ae as lstm_ae_m
from repro.models import rwkv6 as rwkv6_m
from repro.models import transformer as tf_m
from repro.models import whisper as whisper_m
from repro.utils import Params


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[jax.Array], Params]
    param_specs: Callable[[], Params]
    loss: Callable[..., tuple[jnp.ndarray, dict]]
    prefill: Callable[..., tuple[jnp.ndarray, Params]]
    decode: Optional[Callable[..., tuple[jnp.ndarray, Params]]]
    init_cache: Optional[Callable[[int, int], Params]]
    cache_specs: Optional[Callable[[], Params]]


def build_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family == "transformer":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: tf_m.init_transformer(key, cfg),
            param_specs=lambda: tf_m.transformer_specs(cfg),
            loss=lambda p, b, **kw: tf_m.train_loss(p, b, cfg, **kw),
            prefill=lambda p, b, **kw: tf_m.prefill(p, b, cfg, **kw),
            decode=lambda p, t, c, n: tf_m.decode_step(p, t, c, n, cfg),
            init_cache=lambda batch, max_len: tf_m.init_decode_cache(cfg, batch, max_len),
            cache_specs=lambda: tf_m.decode_cache_specs(cfg),
        )
    if cfg.family == "rwkv6":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: rwkv6_m.init_rwkv6(key, cfg),
            param_specs=lambda: rwkv6_m.rwkv6_specs(cfg),
            loss=lambda p, b, **kw: rwkv6_m.train_loss(p, b, cfg, **kw),
            prefill=lambda p, b, **kw: rwkv6_m.prefill(p, b, cfg, **kw),
            decode=lambda p, t, c, n: rwkv6_m.decode_step(p, t, c, n, cfg),
            init_cache=lambda batch, max_len: rwkv6_m.init_state(cfg, batch),
            cache_specs=lambda: rwkv6_m.state_specs(),
        )
    if cfg.family == "jamba":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: jamba_m.init_jamba(key, cfg),
            param_specs=lambda: jamba_m.jamba_specs(cfg),
            loss=lambda p, b, **kw: jamba_m.train_loss(p, b, cfg, **kw),
            prefill=lambda p, b, **kw: jamba_m.prefill(p, b, cfg, **kw),
            decode=lambda p, t, c, n: jamba_m.decode_step(p, t, c, n, cfg),
            init_cache=lambda batch, max_len: jamba_m.init_states(cfg, batch, max_len),
            cache_specs=lambda: jamba_m.state_specs(cfg),
        )
    if cfg.family == "whisper":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: whisper_m.init_whisper(key, cfg),
            param_specs=lambda: whisper_m.whisper_specs(cfg),
            loss=lambda p, b, **kw: whisper_m.train_loss(p, b, cfg, **kw),
            prefill=lambda p, b, **kw: whisper_m.prefill(p, b, cfg, **kw),
            decode=lambda p, t, c, n: whisper_m.decode_step(p, t, c, n, cfg),
            init_cache=lambda batch, max_len: whisper_m.init_decode_cache(cfg, batch, max_len),
            cache_specs=lambda: whisper_m.decode_cache_specs(cfg),
        )
    if cfg.family == "lstm_ae":
        # prefill delegates to the execution-engine registry (repro.engine):
        # pass schedule="sequential" | "wavefront" | "pipelined" through kw.
        return ModelAPI(
            cfg=cfg,
            init=lambda key: init_lstm_ae(key, cfg),
            param_specs=lambda: lstm_ae_specs(cfg),
            loss=lambda p, b, **kw: lstm_ae_m.train_loss(p, b, cfg, **kw),
            prefill=lambda p, b, **kw: lstm_ae_m.prefill(p, b, cfg, **kw),
            decode=lambda p, t, c, n: lstm_ae_m.decode_step(p, t, c, n, cfg),
            init_cache=lambda batch, max_len: lstm_ae_m.init_stream_state(cfg, batch),
            cache_specs=None,
        )
    raise ValueError(f"unknown family {cfg.family!r}")


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct: weak-type-correct, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Model inputs for a given (arch x shape) dry-run cell.

    train/prefill: the token/series batch (+ modality stubs);
    decode: one token + cache_len (the cache itself comes from
    ``cache_struct``).
    """
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "lstm_ae":
        return {"series": _sds((b, s, cfg.lstm_ae.input_features), "float32")}

    if cfg.family == "whisper":
        if shape.kind == "train":
            return {
                "frames": _sds((b, cfg.encoder_seq_len, cfg.d_model), cfg.compute_dtype),
                "tokens": _sds((b, s), "int32"),
                "labels": _sds((b, s), "int32"),
            }
        if shape.kind == "prefill":
            return {
                "frames": _sds((b, cfg.encoder_seq_len, cfg.d_model), cfg.compute_dtype),
                "tokens": _sds((b, s), "int32"),
            }
        return {"token": _sds((b, 1), "int32"), "cache_len": _sds((), "int32")}

    if cfg.frontend == "vision_stub" and shape.kind != "decode":
        p = cfg.vision_patches
        text = s - p
        assert text > 0
        spec = {
            "tokens": _sds((b, text), "int32"),
            "image_embeds": _sds((b, p, cfg.d_model), cfg.compute_dtype),
        }
        if shape.kind == "train":
            spec["labels"] = _sds((b, text), "int32")
        return spec

    if shape.kind == "train":
        return {"tokens": _sds((b, s), "int32"), "labels": _sds((b, s), "int32")}
    if shape.kind == "prefill":
        return {"tokens": _sds((b, s), "int32")}
    return {"token": _sds((b, 1), "int32"), "cache_len": _sds((), "int32")}


def cache_struct(api: ModelAPI, batch: int, max_len: int) -> Params:
    """ShapeDtypeStruct tree of the decode cache (no allocation)."""
    return jax.eval_shape(lambda: api.init_cache(batch, max_len))


def param_struct(api: ModelAPI) -> Params:
    """ShapeDtypeStruct tree of the parameters (no allocation)."""
    return jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))

"""Whisper [arXiv:2212.04356] encoder-decoder backbone.

The mel-spectrogram/conv frontend is a STUB per the assignment:
``input_specs()`` feeds precomputed frame embeddings (B, T_enc, D).
Encoder: bidirectional attention + sinusoidal positions.  Decoder: causal
self-attention + cross-attention + learned positions.  Decode shapes lower
the decoder (self-KV cache + precomputed cross-KV).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.distributed.sharding import constrain
from repro.layers.attention import (
    apply_attention,
    attention_specs,
    decode_attention,
    init_attention,
    init_kv_cache,
    kv_cache_specs,
)
from repro.layers.embeddings import (
    chunked_xent_loss,
    embed_tokens,
    embedding_specs,
    init_embedding,
    unembed_logits,
)
from repro.layers.linear import apply_linear
from repro.layers.mlp import apply_mlp, init_mlp, mlp_specs
from repro.layers.norms import apply_norm, init_norm, norm_specs
from repro.layers.rotary import sinusoidal_embedding
from repro.models.transformer import _stack_specs
from repro.utils import Params, split_keys, truncated_normal_init

MAX_DECODER_LEN = 32_768  # sized for the assigned decode_32k shape


def init_enc_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = split_keys(key, ["attn", "mlp"])
    return {
        "ln1": init_norm("layernorm", cfg.d_model),
        "attn": init_attention(keys["attn"], cfg),
        "ln2": init_norm("layernorm", cfg.d_model),
        "mlp": init_mlp(keys["mlp"], cfg),
    }


def enc_layer_specs(cfg: ModelConfig) -> Params:
    return {
        "ln1": norm_specs("layernorm"),
        "attn": attention_specs(cfg),
        "ln2": norm_specs("layernorm"),
        "mlp": mlp_specs(cfg),
    }


def init_dec_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = split_keys(key, ["self", "cross", "mlp"])
    return {
        "ln1": init_norm("layernorm", cfg.d_model),
        "self_attn": init_attention(keys["self"], cfg),
        "ln_x": init_norm("layernorm", cfg.d_model),
        "cross_attn": init_attention(keys["cross"], cfg),
        "ln2": init_norm("layernorm", cfg.d_model),
        "mlp": init_mlp(keys["mlp"], cfg),
    }


def dec_layer_specs(cfg: ModelConfig) -> Params:
    return {
        "ln1": norm_specs("layernorm"),
        "self_attn": attention_specs(cfg),
        "ln_x": norm_specs("layernorm"),
        "cross_attn": attention_specs(cfg),
        "ln2": norm_specs("layernorm"),
        "mlp": mlp_specs(cfg),
    }


def init_whisper(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = split_keys(key, ["embed", "pos", "enc", "dec"])
    enc_keys = jax.random.split(keys["enc"], cfg.encoder_layers)
    dec_keys = jax.random.split(keys["dec"], cfg.num_layers)
    return {
        "embed": init_embedding(keys["embed"], cfg.vocab_size, cfg.d_model),
        "dec_pos": truncated_normal_init(keys["pos"], (MAX_DECODER_LEN, cfg.d_model), fan_in=cfg.d_model),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "ln_enc": init_norm("layernorm", cfg.d_model),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "ln_dec": init_norm("layernorm", cfg.d_model),
    }


def whisper_specs(cfg: ModelConfig) -> Params:
    return {
        "embed": embedding_specs(),
        "dec_pos": (None, "fsdp"),
        "enc_layers": _stack_specs(enc_layer_specs(cfg)),
        "ln_enc": norm_specs("layernorm"),
        "dec_layers": _stack_specs(dec_layer_specs(cfg)),
        "ln_dec": norm_specs("layernorm"),
    }


def encode(params: Params, frames: jnp.ndarray, cfg: ModelConfig, *, remat: bool = True) -> jnp.ndarray:
    """frames: (B, T_enc, D) stub frame embeddings -> encoder memory."""
    h = frames + sinusoidal_embedding(frames.shape[1], cfg.d_model).astype(frames.dtype)
    h = constrain(h, ("batch", "sp", None))

    def layer_fn(h, lp):
        hn = apply_norm(lp["ln1"], h, "layernorm")
        y = apply_attention(lp["attn"], hn, cfg=cfg, causal=False, use_rope=False)
        h = constrain(h + y, ("batch", "sp", None))
        hn = apply_norm(lp["ln2"], h, "layernorm")
        h = constrain(h + apply_mlp(lp["mlp"], hn, cfg), ("batch", "sp", None))
        return h, None

    body = jax.checkpoint(layer_fn) if remat else layer_fn
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return apply_norm(params["ln_enc"], h, "layernorm")


def decode_train(
    params: Params, tokens: jnp.ndarray, memory: jnp.ndarray, cfg: ModelConfig,
    *, remat: bool = True, kv_chunk: int = 1024, q_chunks: int = 1,
) -> jnp.ndarray:
    """Teacher-forced decoder pass -> final hidden states (B, S, D)."""
    dtype = memory.dtype
    h = embed_tokens(params["embed"], tokens, dtype)
    h = h + params["dec_pos"][: tokens.shape[1]].astype(dtype)[None]
    h = constrain(h, ("batch", "sp", None))

    def layer_fn(h, lp):
        hn = apply_norm(lp["ln1"], h, "layernorm")
        y = apply_attention(
            lp["self_attn"], hn, cfg=cfg, causal=True, use_rope=False,
            kv_chunk=kv_chunk, q_chunks=q_chunks,
        )
        h = constrain(h + y, ("batch", "sp", None))
        hn = apply_norm(lp["ln_x"], h, "layernorm")
        y = apply_attention(lp["cross_attn"], hn, cfg=cfg, causal=False, use_rope=False, x_kv=memory)
        h = constrain(h + y, ("batch", "sp", None))
        hn = apply_norm(lp["ln2"], h, "layernorm")
        h = constrain(h + apply_mlp(lp["mlp"], hn, cfg), ("batch", "sp", None))
        return h, None

    body = jax.checkpoint(layer_fn) if remat else layer_fn
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    return apply_norm(params["ln_dec"], h, "layernorm")


def train_loss(params: Params, batch: dict, cfg: ModelConfig, *, remat: bool = True,
               loss_chunk: int = 2048, kv_chunk: int = 1024, q_chunks: int = 1,
               **_) -> tuple[jnp.ndarray, dict]:
    """batch: frames (B,T_enc,D), tokens (B,S), labels (B,S)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    memory = encode(params, batch["frames"].astype(dtype), cfg, remat=remat)
    h = decode_train(params, batch["tokens"], memory, cfg, remat=remat,
                     kv_chunk=kv_chunk, q_chunks=q_chunks)
    loss = chunked_xent_loss(params["embed"]["table"].T, h, batch["labels"], chunk=loss_chunk)
    return loss, {"xent": loss}


# --- serving -----------------------------------------------------------

def _cross_kv(lp: Params, memory: jnp.ndarray, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder memory (per layer)."""
    hd = cfg.resolved_head_dim()
    b, t, _ = memory.shape
    k = apply_linear(lp["cross_attn"]["k"], memory).reshape(b, t, cfg.num_kv_heads, hd)
    v = apply_linear(lp["cross_attn"]["v"], memory).reshape(b, t, cfg.num_kv_heads, hd)
    return k, v


def prefill(params: Params, batch: dict, cfg: ModelConfig, *, kv_chunk: int = 1024,
            q_chunks: int = 1, **_) -> tuple[jnp.ndarray, Params]:
    """Encode audio + teacher-forced prompt pass; emit self-KV and cross-KV."""
    dtype = jnp.dtype(cfg.compute_dtype)
    memory = encode(params, batch["frames"].astype(dtype), cfg, remat=False)
    tokens = batch["tokens"]
    h = embed_tokens(params["embed"], tokens, dtype)
    h = h + params["dec_pos"][: tokens.shape[1]].astype(dtype)[None]

    def layer_fn(h, lp):
        hn = apply_norm(lp["ln1"], h, "layernorm")
        y, kv = apply_attention(
            lp["self_attn"], hn, cfg=cfg, causal=True, use_rope=False,
            kv_chunk=kv_chunk, q_chunks=q_chunks, return_kv=True,
        )
        h = constrain(h + y, ("batch", "sp", None))
        hn = apply_norm(lp["ln_x"], h, "layernorm")
        y = apply_attention(lp["cross_attn"], hn, cfg=cfg, causal=False, use_rope=False, x_kv=memory)
        h = constrain(h + y, ("batch", "sp", None))
        hn = apply_norm(lp["ln2"], h, "layernorm")
        h = constrain(h + apply_mlp(lp["mlp"], hn, cfg), ("batch", "sp", None))
        ck, cv = _cross_kv(lp, memory, cfg)
        return h, {"k": kv[0].astype(dtype), "v": kv[1].astype(dtype),
                   "ck": ck.astype(dtype), "cv": cv.astype(dtype)}

    h, cache = jax.lax.scan(layer_fn, h, params["dec_layers"])
    h = apply_norm(params["ln_dec"], h, "layernorm")
    logits = unembed_logits(params["embed"]["table"].T, h[:, -1:, :])
    return logits, cache


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim()
    self_kv = init_kv_cache(cfg, batch, max_len, dtype)
    one = {
        "k": self_kv["k"],
        "v": self_kv["v"],
        "ck": jnp.zeros((batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dtype),
        "cv": jnp.zeros((batch, cfg.encoder_seq_len, cfg.num_kv_heads, hd), dtype),
    }
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape), one)


def decode_cache_specs(cfg: ModelConfig) -> Params:
    base = kv_cache_specs()
    return {
        "k": (None,) + base["k"],
        "v": (None,) + base["v"],
        "ck": (None, "batch", "tp", None, None),
        "cv": (None, "batch", "tp", None, None),
    }


def decode_step(params: Params, token: jnp.ndarray, cache: Params,
                cache_len: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, Params]:
    """One decoder token against self-KV cache + fixed cross-KV."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], token, dtype)
    h = h + jax.lax.dynamic_slice_in_dim(params["dec_pos"], cache_len, 1, 0).astype(dtype)[None]

    def layer_fn(h, inp):
        lp, cache_l = inp
        hn = apply_norm(lp["ln1"], h, "layernorm")
        y, new_self = decode_attention(
            lp["self_attn"], hn, {"k": cache_l["k"], "v": cache_l["v"]},
            cache_len, cfg=cfg, use_rope=False,
        )
        h = h + y
        hn = apply_norm(lp["ln_x"], h, "layernorm")
        y, _ = decode_attention(
            lp["cross_attn"], hn, {"k": cache_l["ck"], "v": cache_l["cv"]},
            jnp.int32(cfg.encoder_seq_len - 1), cfg=cfg, use_rope=False,
            update_cache=False,
        )
        h = h + y
        hn = apply_norm(lp["ln2"], h, "layernorm")
        h = h + apply_mlp(lp["mlp"], hn, cfg)
        new_cache_l = {"k": new_self["k"], "v": new_self["v"],
                       "ck": cache_l["ck"], "cv": cache_l["cv"]}
        return h, new_cache_l

    h, new_cache = jax.lax.scan(layer_fn, h, (params["dec_layers"], cache))
    h = apply_norm(params["ln_dec"], h, "layernorm")
    logits = unembed_logits(params["embed"]["table"].T, h)
    return logits, new_cache

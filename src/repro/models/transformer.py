"""Decoder-only transformer LM: dense or MoE, GQA + RoPE, pre-norm.

Covers moonshot / dbrx / olmo / phi4-mini / tinyllama / internlm2 /
phi-3-vision (backbone).  Layers are homogeneous and stacked, executed with
``lax.scan`` + per-layer remat so the HLO stays compact for the 512-device
dry-run compiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.distributed.sharding import constrain
from repro.layers.attention import (
    apply_attention,
    attention_specs,
    decode_attention,
    init_attention,
    init_kv_cache,
    kv_cache_specs,
)
from repro.layers.embeddings import (
    chunked_xent_loss,
    embed_tokens,
    embedding_specs,
    init_embedding,
    init_unembed,
    unembed_logits,
    unembed_specs,
)
from repro.layers.mlp import apply_mlp, init_mlp, mlp_specs
from repro.layers.moe import apply_moe, init_moe, moe_specs
from repro.layers.norms import apply_norm, init_norm, norm_specs
from repro.utils import Params, split_keys


def _is_moe(cfg: ModelConfig) -> bool:
    return cfg.moe is not None


def init_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = split_keys(key, ["attn", "ffn"])
    p = {
        "ln1": init_norm(cfg.norm, cfg.d_model),
        "attn": init_attention(keys["attn"], cfg),
        "ln2": init_norm(cfg.norm, cfg.d_model),
    }
    if _is_moe(cfg):
        p["moe"] = init_moe(keys["ffn"], cfg)
    else:
        p["mlp"] = init_mlp(keys["ffn"], cfg)
    return p


def layer_specs(cfg: ModelConfig) -> Params:
    s = {
        "ln1": norm_specs(cfg.norm),
        "attn": attention_specs(cfg),
        "ln2": norm_specs(cfg.norm),
    }
    if _is_moe(cfg):
        s["moe"] = moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg)
    return s


def _stack_specs(specs: Params) -> Params:
    """Prepend the stacked-layer dim (replicated) to every leaf spec."""
    from repro.distributed.sharding import map_specs

    return map_specs(lambda axes: (None,) + axes, specs)


def init_transformer(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = split_keys(key, ["embed", "layers", "unembed"])
    layer_keys = jax.random.split(keys["layers"], cfg.num_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p = {
        "embed": init_embedding(keys["embed"], cfg.vocab_size, cfg.d_model),
        "layers": layers,
        "ln_f": init_norm(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = init_unembed(keys["unembed"], cfg.d_model, cfg.vocab_size)
    return p


def transformer_specs(cfg: ModelConfig) -> Params:
    s = {
        "embed": embedding_specs(),
        "layers": _stack_specs(layer_specs(cfg)),
        "ln_f": norm_specs(cfg.norm),
    }
    if not cfg.tie_embeddings:
        s["unembed"] = unembed_specs()
    return s


def _unembed_w(params: Params, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["unembed"]["w"]


def _ffn(lp: Params, h: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    if _is_moe(cfg):
        if cfg.moe.impl == "ep_a2a":
            from repro.layers.moe import apply_moe_ep
            return apply_moe_ep(lp["moe"], h, cfg)
        return apply_moe(lp["moe"], h, cfg)
    return apply_mlp(lp["mlp"], h, cfg), jnp.float32(0.0)


def forward(
    params: Params,
    h: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    remat: bool = True,
    kv_chunk: int = 1024,
    q_chunks: int = 1,
    collect_cache: bool = False,
):
    """Run the layer stack on embedded inputs h (B, S, D).

    Returns (h, aux_loss) or, with ``collect_cache``, (h, aux, {"k","v"}
    stacked (L, B, S, Hkv, hd)) for prefill.
    """
    if positions is None:
        positions = jnp.arange(h.shape[1])

    def layer_fn(carry, lp):
        h, aux = carry
        if cfg.bwd_constrain:
            # entry constraint: its transpose pins the incoming COTANGENT to
            # the same (batch, sp) sharding, stopping XLA from materialising
            # replicated full-sequence gradients in the layer backward (§Perf)
            h = constrain(h, ("batch", "sp", None))
        hn = apply_norm(lp["ln1"], h, cfg.norm)
        attn_out, kv = apply_attention(
            lp["attn"], hn, cfg=cfg, causal=causal, positions=positions,
            kv_chunk=kv_chunk, q_chunks=q_chunks, return_kv=True,
        )
        h = constrain(h + attn_out, ("batch", "sp", None))
        hn = apply_norm(lp["ln2"], h, cfg.norm)
        f, aux_l = _ffn(lp, hn, cfg)
        h = constrain(h + f, ("batch", "sp", None))
        return (h, aux + aux_l), (kv if collect_cache else None)

    body = jax.checkpoint(layer_fn) if remat else layer_fn
    (h, aux), caches = jax.lax.scan(body, (h, jnp.float32(0.0)), params["layers"])
    h = apply_norm(params["ln_f"], h, cfg.norm)
    if collect_cache:
        return h, aux, {"k": caches[0], "v": caches[1]}
    return h, aux


def embed_inputs(params: Params, batch: dict, cfg: ModelConfig, dtype) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token (+ optional modality-stub) embedding.  Returns (h, labels_mask_offset)."""
    h = embed_tokens(params["embed"], batch["tokens"], dtype)
    if cfg.frontend == "vision_stub" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(dtype)  # (B, P, D) precomputed patches
        h = jnp.concatenate([img, h], axis=1)
        h = constrain(h, ("batch", "sp", None))
    return h


def train_loss(
    params: Params,
    batch: dict,
    cfg: ModelConfig,
    *,
    remat: bool = True,
    loss_chunk: int = 2048,
    kv_chunk: int = 1024,
    q_chunks: int = 1,
    aux_weight: float = 0.01,
) -> tuple[jnp.ndarray, dict]:
    """Next-token LM loss.  batch: tokens (B,S), labels (B,S) [-1 = pad],
    optionally image_embeds (B,P,D) (labels already sized to S + P?  No —
    labels cover the FULL residual stream; vision positions are -1)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_inputs(params, batch, cfg, dtype)
    labels = batch["labels"]
    if cfg.frontend == "vision_stub" and "image_embeds" in batch:
        pad = -jnp.ones((labels.shape[0], batch["image_embeds"].shape[1]), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    h, aux = forward(
        params, h, cfg, remat=remat, kv_chunk=kv_chunk, q_chunks=q_chunks
    )
    loss = chunked_xent_loss(_unembed_w(params, cfg), h, labels, chunk=loss_chunk)
    total = loss + aux_weight * aux
    return total, {"xent": loss, "aux": aux}


def prefill(
    params: Params,
    batch: dict,
    cfg: ModelConfig,
    *,
    kv_chunk: int = 1024,
    q_chunks: int = 1,
) -> tuple[jnp.ndarray, Params]:
    """Prefill: full forward, emit the KV cache + last-position logits."""
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_inputs(params, batch, cfg, dtype)
    h, _, cache = forward(
        params, h, cfg, remat=False, kv_chunk=kv_chunk, q_chunks=q_chunks,
        collect_cache=True,
    )
    logits = unembed_logits(_unembed_w(params, cfg), h[:, -1:, :])
    return logits, cache


def decode_step(
    params: Params,
    token: jnp.ndarray,
    cache: Params,
    cache_len: jnp.ndarray,
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, Params]:
    """One-token decode.  token: (B, 1) int32; cache: {"k","v"} stacked
    (L, B, S_max, Hkv, hd); cache_len: scalar int32 (tokens already cached).
    """
    dtype = jnp.dtype(cfg.compute_dtype)
    h = embed_tokens(params["embed"], token, dtype)
    h = constrain(h, ("batch", None, None))

    def layer_fn(h, inp):
        lp, cache_l = inp
        hn = apply_norm(lp["ln1"], h, cfg.norm)
        attn_out, new_cache_l = decode_attention(
            lp["attn"], hn, cache_l, cache_len, cfg=cfg
        )
        h = h + attn_out
        hn = apply_norm(lp["ln2"], h, cfg.norm)
        f, _ = _ffn(lp, hn, cfg)
        h = h + f
        return h, new_cache_l

    if cfg.decode_loop == "unroll":
        # tuple-of-layers cache: each layer's buffers are independent jit
        # inputs/outputs, so donation aliases every DUS in place — no full
        # stacked-cache intermediary ever exists (§Perf cell 3 iteration 3)
        new_layers = []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            h, nc = layer_fn(h, (lp, cache[i]))
            new_layers.append(nc)
        new_cache = tuple(new_layers)
    else:
        h, new_cache = jax.lax.scan(layer_fn, h, (params["layers"], cache))
    h = apply_norm(params["ln_f"], h, cfg.norm)
    logits = unembed_logits(_unembed_w(params, cfg), h)
    return logits, new_cache


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    if cfg.decode_loop == "unroll":
        # independent per-layer buffers (see decode_step)
        return tuple(
            init_kv_cache(cfg, batch, max_len, dtype) for _ in range(cfg.num_layers)
        )
    one = init_kv_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.num_layers,) + x.shape), one
    )


def decode_cache_specs(cfg: ModelConfig) -> Params:
    from repro.distributed.sharding import map_specs

    if cfg.decode_loop == "unroll":
        return tuple(kv_cache_specs() for _ in range(cfg.num_layers))
    return map_specs(lambda axes: (None,) + axes, kv_cache_specs())

from repro.models.api import ModelAPI, build_model, cache_struct, input_specs, param_struct

__all__ = ["ModelAPI", "build_model", "cache_struct", "input_specs", "param_struct"]

"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE — a
10-layer scanned transformer reports one layer of FLOPs.  Every model here
scans over layers (and attention scans over KV chunks), so we re-derive the
three roofline quantities from the compiled module text, multiplying each
while body by its ``known_trip_count`` annotation:

* flops            — dot ops: 2 * prod(out_dims) * K (contracting size from
                     the printed dims); fusion wrappers recursed.
* bytes            — per *top-level* instruction (fusion boundaries =
                     buffer traffic): operands + outputs, for all opcodes
                     except free ones (tuple/gte/parameter/bitcast/constant).
* collective bytes — operand payloads of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute.

All quantities are per-device (the SPMD-partitioned module is the
per-device program).  Unknown trip counts fall back to 1 and are recorded
in ``notes``.  Operand shapes are resolved through a per-computation symbol
table (HLO prints operands by name, not type).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9a-z]+)?|pred)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_OPCODE_RE = re.compile(r"=\s*[^=]*?\s([a-z][a-z0-9-]*)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "u4": 1, "s4": 1,
}

_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shapes_bytes(text: str) -> int:
    return sum(
        _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
        for dt, dims in _SHAPE_RE.findall(text)
    )


@dataclass
class Instruction:
    name: str
    opcode: str
    out_text: str          # LHS type section, e.g. "f32[128,128]{1,0}" or tuple
    operand_names: list
    line: str
    is_root: bool = False

    def out_bytes(self) -> int:
        return _shapes_bytes(self.out_text)

    def out_shapes(self) -> list:
        return _SHAPE_RE.findall(self.out_text)


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)
    notes: list = field(default_factory=list)

    def add(self, other: "CostTotals", factor: float = 1.0):
        self.flops += other.flops * factor
        self.bytes += other.bytes * factor
        self.coll_bytes += other.coll_bytes * factor
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0) + v * factor
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0) + v * factor
        for n in other.notes:
            if n not in self.notes:
                self.notes.append(n)


_RHS_OPCODE_RE = re.compile(r"(?:^|[\s)])([a-z][a-z0-9-]*)\(")


def _parse_instruction(line: str):
    if "=" not in line:
        return None
    lhs, rhs = line.split("=", 1)
    name_m = _NAME_RE.search(lhs)
    if not name_m:
        return None
    # the opcode is the identifier immediately before the operand paren; the
    # output type section may itself contain parens (tuple types), so search
    # for the first "word(" not inside a type (types start with dtype[ which
    # never precedes "(").
    m = _RHS_OPCODE_RE.search(rhs)
    if not m:
        return None
    opcode = m.group(1)
    out_text = rhs[: m.start()].strip()
    lp = rhs.find("(", m.start())
    rp = rhs.find(")", lp)
    operand_names = _NAME_RE.findall(rhs[lp : rp + 1]) if rp > lp else []
    return Instruction(
        name=name_m.group(1), opcode=opcode, out_text=out_text,
        operand_names=operand_names, line=line,
        is_root=lhs.lstrip().startswith("ROOT"),
    )


def parse_computations(hlo: str) -> dict[str, list[Instruction]]:
    comps: dict[str, list[Instruction]] = {}
    current = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("ENTRY"):
            name = line.split("%", 1)[1].split(" ", 1)[0].split("(")[0].rstrip()
            current = name
            comps[current] = []
            continue
        if line.startswith("%") and line.endswith("{") and "= " not in line.split("{")[0]:
            name = line[1:].split(" ", 1)[0].split("(")[0]
            current = name
            comps[current] = []
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is not None:
            instr = _parse_instruction(line)
            if instr is not None:
                comps[current].append(instr)
    return comps


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        # symbol tables: per-computation name -> Instruction, plus global
        self.symbols: dict[str, dict[str, Instruction]] = {}
        self.global_symbols: dict[str, Instruction] = {}
        for cname, instrs in self.comps.items():
            table = {}
            for i in instrs:
                table[i.name] = i
                self.global_symbols.setdefault(i.name, i)
            self.symbols[cname] = table
        self._memo: dict[str, CostTotals] = {}
        self._fusion_flops_memo: dict[str, float] = {}

    def _operand_bytes(self, comp: str, instr: Instruction) -> int:
        table = self.symbols.get(comp, {})
        total = 0
        for nm in instr.operand_names:
            src = table.get(nm) or self.global_symbols.get(nm)
            if src is not None:
                total += src.out_bytes()
        return total

    def _operand_shapes(self, comp: str, instr: Instruction) -> list:
        table = self.symbols.get(comp, {})
        shapes = []
        for nm in instr.operand_names:
            src = table.get(nm) or self.global_symbols.get(nm)
            shapes.append(src.out_shapes() if src is not None else [])
        return shapes

    def _dot_flops(self, comp: str, instr: Instruction) -> float:
        op_shapes = self._operand_shapes(comp, instr)
        if not op_shapes or not op_shapes[0]:
            return 0.0
        lhs = op_shapes[0][0]
        lhs_dims = [int(d) for d in lhs[1].split(",")] if lhs[1] else []
        m = _CONTRACT_RE.search(instr.line)
        if not m:
            return 0.0
        k = 1
        if m.group(1):
            for idx in m.group(1).split(","):
                if int(idx) < len(lhs_dims):
                    k *= lhs_dims[int(idx)]
        outs = instr.out_shapes()
        if not outs:
            return 0.0
        return 2.0 * _shape_elems(outs[0][1]) * k

    def _conv_flops(self, comp: str, instr: Instruction) -> float:
        op_shapes = self._operand_shapes(comp, instr)
        outs = instr.out_shapes()
        if len(op_shapes) < 2 or not op_shapes[1] or not outs:
            return 0.0
        kshape = op_shapes[1][0]
        kdims = [int(d) for d in kshape[1].split(",")] if kshape[1] else [1]
        kernel_elems = _shape_elems(kshape[1])
        cout = kdims[-1] if kdims else 1
        return 2.0 * _shape_elems(outs[0][1]) * (kernel_elems / max(1, cout))

    def fusion_inplace_bytes(self, callee: str):
        """In-place adjustment for fusions whose root is (or is a tuple
        containing) dynamic-update-slice: XLA aliases the big buffer and
        writes only the update slice, so counting the full fusion output
        overstates traffic by the buffer/update ratio (orders of magnitude
        for scan-ys accumulation).  Returns adjusted bytes or None."""
        instrs = self.comps.get(callee)
        if not instrs:
            return None
        root = next((i for i in instrs if i.is_root), instrs[-1])
        table = self.symbols.get(callee, {})

        def dus_update(instr) -> int:
            if len(instr.operand_names) >= 2:
                src = table.get(instr.operand_names[1])
                if src is not None:
                    return src.out_bytes()
            return 0

        if root.opcode == "dynamic-update-slice":
            return 2 * dus_update(root)
        if root.opcode == "tuple":
            total, any_dus = 0, False
            for nm in root.operand_names:
                src = table.get(nm)
                if src is None:
                    continue
                if src.opcode == "dynamic-update-slice":
                    any_dus = True
                    total += 2 * dus_update(src)
                else:
                    total += 2 * src.out_bytes()
            return total if any_dus else None
        return None

    def fusion_flops(self, comp: str) -> float:
        if comp in self._fusion_flops_memo:
            return self._fusion_flops_memo[comp]
        self._fusion_flops_memo[comp] = 0.0  # cycle guard
        total = 0.0
        for instr in self.comps.get(comp, []):
            if instr.opcode == "dot":
                total += self._dot_flops(comp, instr)
            elif instr.opcode == "convolution":
                total += self._conv_flops(comp, instr)
            else:
                m = _CALLS_RE.search(instr.line) or _TO_APPLY_RE.search(instr.line)
                if m and m.group(1) in self.comps:
                    total += self.fusion_flops(m.group(1))
        self._fusion_flops_memo[comp] = total
        return total

    def computation_cost(self, comp: str) -> CostTotals:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = CostTotals()  # cycle guard
        total = CostTotals()
        for instr in self.comps.get(comp, []):
            op = instr.opcode
            if op == "while":
                body = _BODY_RE.search(instr.line)
                cond = _COND_RE.search(instr.line)
                trip_m = _TRIP_RE.search(instr.line)
                trip = int(trip_m.group(1)) if trip_m else 1
                if not trip_m:
                    total.notes.append(f"while without known_trip_count in {comp}")
                if body and body.group(1) in self.comps:
                    total.add(self.computation_cost(body.group(1)), trip)
                if cond and cond.group(1) in self.comps:
                    total.add(self.computation_cost(cond.group(1)), trip)
                continue
            if op == "conditional":
                m = _BRANCHES_RE.search(instr.line)
                if m:
                    branch_costs = [
                        self.computation_cost(b.strip().lstrip("%"))
                        for b in m.group(1).split(",")
                        if b.strip().lstrip("%") in self.comps
                    ]
                    if branch_costs:
                        total.add(max(branch_costs, key=lambda c: c.flops + c.bytes))
                continue
            if op in ("call", "async-start"):
                m = _CALLS_RE.search(instr.line) or _TO_APPLY_RE.search(instr.line)
                if m and m.group(1) in self.comps:
                    total.add(self.computation_cost(m.group(1)))

            base = op.removesuffix("-start").removesuffix("-done")
            if base in _COLLECTIVES:
                if not op.endswith("-done"):
                    payload = self._operand_bytes(comp, instr)
                    total.coll_bytes += payload
                    total.coll_by_op[base] = total.coll_by_op.get(base, 0) + payload
                    total.coll_count[base] = total.coll_count.get(base, 0) + 1
                    total.bytes += payload + instr.out_bytes()
                continue

            if op == "dot":
                total.flops += self._dot_flops(comp, instr)
            elif op == "convolution":
                total.flops += self._conv_flops(comp, instr)
            elif op == "fusion":
                m = _CALLS_RE.search(instr.line)
                if m and m.group(1) in self.comps:
                    total.flops += self.fusion_flops(m.group(1))
                    adj = self.fusion_inplace_bytes(m.group(1))
                    if adj is not None:
                        total.bytes += adj
                        continue

            if op in _FREE_OPS:
                continue
            # HBM-traffic model: reads = operand bytes, writes = output
            # bytes, at post-fusion instruction granularity.  In-place /
            # aliasing ops only move their slice, not the whole buffer:
            if op == "dynamic-update-slice":
                # reads update, writes slice (big operand+output aliased)
                upd = 0
                table = self.symbols.get(comp, {})
                if len(instr.operand_names) >= 2:
                    src = table.get(instr.operand_names[1]) or self.global_symbols.get(
                        instr.operand_names[1]
                    )
                    if src is not None:
                        upd = src.out_bytes()
                total.bytes += 2 * upd
            elif op == "dynamic-slice":
                total.bytes += 2 * instr.out_bytes()  # read + write the slice
            elif op == "scatter":
                # reads updates+indices, writes touched rows (~updates)
                table = self.symbols.get(comp, {})
                upd = 0
                for nm in instr.operand_names[1:]:
                    src = table.get(nm) or self.global_symbols.get(nm)
                    if src is not None:
                        upd += src.out_bytes()
                total.bytes += 2 * upd
            elif op == "gather":
                total.bytes += 2 * instr.out_bytes()
            else:
                total.bytes += self._operand_bytes(comp, instr) + instr.out_bytes()
        self._memo[comp] = total
        return total

    def entry_cost(self) -> CostTotals:
        referenced = set()
        for name, instrs in self.comps.items():
            for i in instrs:
                for rx in (_CALLS_RE, _COND_RE, _BODY_RE, _TO_APPLY_RE):
                    m = rx.search(i.line)
                    if m:
                        referenced.add(m.group(1))
                m = _BRANCHES_RE.search(i.line)
                if m:
                    for b in m.group(1).split(","):
                        referenced.add(b.strip().lstrip("%"))
        candidates = [n for n in self.comps if n not in referenced]
        entry = None
        for c in candidates:
            if "main" in c:
                entry = c
                break
        if entry is None and candidates:
            entry = candidates[0]
        if entry is None:
            return CostTotals(notes=["no entry computation found"])
        return self.computation_cost(entry)


def analyze_hlo(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).entry_cost()

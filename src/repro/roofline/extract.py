"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), per EXPERIMENTS.md §Roofline:

    compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes   / (chips * HBM_BW)
    collective = coll_bytes  / (chips * LINK_BW)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; collective bytes are
NOT in cost_analysis, so we parse the (SPMD-partitioned, i.e. per-device)
HLO text and sum operand payloads of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute ops.

Accounting convention (documented here once): the partitioned module IS the
per-device program, so parsed quantities are per-chip.  We report
``X_total = X_per_chip * chips`` so the formulas above hold verbatim with
the chips factor cancelling.  cost_analysis FLOPs on the CPU backend count
the per-device module the same way.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Optional

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "u4": 1, "s4": 1,
}

# e.g. "bf16[256,4096]{1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9a-z]+)?|pred)\[([0-9,]*)\]")
# LHS of an HLO instruction: "%name = <shape-or-tuple> <opcode>("
_INSTR_RE = re.compile(r"=\s*(\(?[a-z0-9_\[\],{}\s/]*\)?)\s*([a-z0-9-]+)\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _operand_bytes(line: str) -> int:
    """Sum payload bytes of typed operand references inside the parens."""
    lparen = line.find("(")
    if lparen < 0:
        return 0
    args = line[lparen:]
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(args))


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Per-device collective payload bytes from partitioned HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        opcode = m.group(2)
        base = opcode.removesuffix("-start").removesuffix("-done")
        if base not in COLLECTIVE_OPS:
            continue
        if opcode.endswith("-done"):
            continue  # the -start carries the operands; don't double count
        b = _operand_bytes(line)
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + b
        stats.count_by_op[base] = stats.count_by_op.get(base, 0) + 1
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-chip quantities (partitioned module)
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # usefulness
    model_flops: float          # 6*N*D (dense) / 6*N_active*D (MoE); 2*N*D decode
    flops_ratio: float          # MODEL_FLOPS / HLO_FLOPs_total
    memory_analysis: Optional[str] = None
    note: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def build_report(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    hlo_text: str,
    model_flops: float,
    memory_analysis: Optional[str] = None,
    note: str = "",
) -> RooflineReport:
    """Derive the three terms from the compiled module.

    FLOPs/bytes/collective-bytes come from the trip-count-aware HLO cost
    model (roofline/hlo_cost.py); XLA's raw cost_analysis (which counts scan
    bodies once) is kept in the note for reference.
    """
    from repro.roofline.hlo_cost import analyze_hlo

    totals = analyze_hlo(hlo_text)
    flops = float(totals.flops)
    byts = float(totals.bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = totals.coll_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_flops = flops * chips
    xla_raw = (
        f"xla_cost_analysis(scan-bodies-once): flops={cost.get('flops')} "
        f"bytes={cost.get('bytes accessed')}"
    )
    notes = "; ".join([note, xla_raw] + totals.notes) if note else "; ".join([xla_raw] + totals.notes)
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        coll_bytes_per_chip=float(totals.coll_bytes),
        coll_breakdown={k: int(v) for k, v in totals.coll_by_op.items()},
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        flops_ratio=(model_flops / total_flops) if total_flops else 0.0,
        memory_analysis=memory_analysis,
        note=notes,
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D train (N = active params, D = tokens);
    2*N*D for single-token decode; 2*N*D for prefill forward-only."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def active_param_count(cfg) -> float:
    """Active (per-token) parameter count from the config's dims."""
    if cfg.family == "lstm_ae":
        total = 0
        for lx, lh in zip(cfg.lstm_ae.layer_input_sizes(), cfg.lstm_ae.layer_sizes()):
            total += 4 * lh * (lx + lh) + 8 * lh
        return float(total)

    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.num_layers
    hd = cfg.resolved_head_dim()
    attn = d * hd * cfg.num_heads + 2 * d * hd * cfg.num_kv_heads + hd * cfg.num_heads * d

    def ffn_active():
        if cfg.moe is not None:
            dense = 3 * d * f
            return cfg.moe.top_k * dense
        if cfg.activation == "swiglu":
            return 3 * d * f
        return 2 * d * f

    total = 0.0
    if cfg.family == "whisper":
        enc = cfg.encoder_layers * (attn + 2 * d * f)
        dec = L * (2 * attn + 2 * d * f)
        total = enc + dec
    elif cfg.family == "rwkv6":
        tm = 5 * d * d + 2 * d * cfg.rwkv.decay_lora
        cm = 2 * d * f + d * d
        total = L * (tm + cm)
    elif cfg.family == "jamba":
        from repro.layers.mamba import mamba_dims
        d_inner, d_state, dt_rank = mamba_dims(cfg)
        mamba_p = 2 * d * d_inner + d_inner * (dt_rank + 2 * d_state) + dt_rank * d_inner + d_inner * d
        n_attn = L // cfg.attn_every
        n_mamba = L - n_attn
        n_moe = L // cfg.moe.every if cfg.moe else 0
        n_mlp = L - n_moe
        moe_active = cfg.moe.top_k * 3 * d * f if cfg.moe else 0
        total = n_attn * attn + n_mamba * mamba_p + n_moe * moe_active + n_mlp * 3 * d * f
    else:
        total = L * (attn + ffn_active())
    total += 2 * v * d  # embed + unembed (tied counts once for compute anyway)
    return float(total)

"""Re-apply the (possibly updated) HLO cost model to saved dry-run
artifacts without recompiling: reads ``<cell>.hlo.zst`` next to each JSON,
rebuilds the roofline record, and rewrites the JSON in place.

Usage: PYTHONPATH=src python -m repro.roofline.reanalyze [dir ...]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

import zstandard

from repro.config import get_config, shapes_for
from repro.roofline.extract import build_report, model_flops_estimate


def reanalyze_dir(d: Path) -> int:
    n = 0
    for hlo_path in sorted(d.glob("*.hlo.zst")):
        cell_id = hlo_path.name.removesuffix(".hlo.zst")
        json_path = d / f"{cell_id}.json"
        if not json_path.exists():
            continue
        rec = json.loads(json_path.read_text())
        if rec.get("status") != "ok":
            continue
        hlo = zstandard.ZstdDecompressor().decompress(hlo_path.read_bytes()).decode()
        arch, shape_name, mesh_name = cell_id.split("__")
        cfg = get_config(arch)
        shape = next(s for s in shapes_for(cfg) if s.name == shape_name)
        report = build_report(
            arch=arch,
            shape=shape_name,
            mesh_name=mesh_name,
            chips=rec["chips"],
            cost={},
            hlo_text=hlo,
            model_flops=model_flops_estimate(cfg, shape),
            memory_analysis=rec.get("memory_analysis"),
        )
        new_rec = json.loads(report.to_json())
        new_rec["status"] = "ok"
        new_rec["compile_s"] = rec.get("compile_s")
        json_path.write_text(json.dumps(new_rec, indent=1))
        n += 1
    return n


def main() -> None:
    dirs = [Path(p) for p in (sys.argv[1:] or ["experiments/dryrun", "experiments/dryrun_opt"])]
    for d in dirs:
        if d.exists():
            n = reanalyze_dir(d)
            print(f"[reanalyze] {d}: {n} cells updated")


if __name__ == "__main__":
    main()

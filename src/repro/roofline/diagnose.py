"""Hillclimbing diagnostics: which instructions dominate each roofline term.

``top_contributors`` walks the trip-count-weighted HLO (same accounting as
hlo_cost.py) and returns the largest byte/flop/collective contributors —
the §Perf loop's "profile" (there is no wall-clock trace on this host)."""
from __future__ import annotations

import re
from collections import Counter

from repro.roofline.hlo_cost import (
    _BODY_RE,
    _CALLS_RE,
    _COLLECTIVES,
    _FREE_OPS,
    _TRIP_RE,
    HloCostModel,
)


def _trip_multipliers(model: HloCostModel) -> dict[str, float]:
    referenced = set()
    for name, instrs in model.comps.items():
        for i in instrs:
            m = _BODY_RE.search(i.line)
            if m:
                referenced.add(m.group(1))
    entries = [c for c in model.comps if "main" in c] or list(model.comps)
    mult: dict[str, float] = {}

    def walk(comp, factor):
        mult[comp] = mult.get(comp, 0.0) + factor
        for i in model.comps.get(comp, []):
            if i.opcode == "while":
                b = _BODY_RE.search(i.line)
                t = _TRIP_RE.search(i.line)
                trip = int(t.group(1)) if t else 1
                if b and b.group(1) in model.comps:
                    walk(b.group(1), factor * trip)

    walk(entries[0], 1.0)
    return mult


def top_contributors(hlo_text: str, k: int = 15, kind: str = "bytes"):
    """kind: "bytes" | "collective".  Returns [(value, opcode, out_shape,
    computation), ...] sorted descending."""
    model = HloCostModel(hlo_text)
    mult = _trip_multipliers(model)
    contrib: Counter = Counter()
    skip = _FREE_OPS | {"while", "conditional", "call"}
    for comp, f in mult.items():
        for i in model.comps[comp]:
            if i.opcode in skip:
                continue
            base = i.opcode.removesuffix("-start").removesuffix("-done")
            if kind == "collective":
                if base not in _COLLECTIVES or i.opcode.endswith("-done"):
                    continue
                val = model._operand_bytes(comp, i)
            else:
                if base in _COLLECTIVES:
                    val = model._operand_bytes(comp, i) + i.out_bytes()
                elif i.opcode == "dynamic-update-slice":
                    continue
                else:
                    val = model._operand_bytes(comp, i) + i.out_bytes()
            contrib[(i.opcode, i.out_text[:60], comp[:30])] += val * f
    return [(v,) + key for key, v in contrib.most_common(k)]


def print_top(hlo_text: str, k: int = 15, kind: str = "bytes") -> None:
    for v, op, shape, comp in top_contributors(hlo_text, k, kind):
        print(f"{v / 1e9:10.2f} GB  {op:22s} {shape:55s} {comp}")

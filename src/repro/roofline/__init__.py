from repro.roofline.extract import (
    HBM_BW,
    LINK_BW,
    PEAK_FLOPS,
    RooflineReport,
    active_param_count,
    build_report,
    model_flops_estimate,
    parse_collectives,
)
from repro.roofline.hlo_cost import HloCostModel, analyze_hlo

__all__ = [
    "HBM_BW",
    "HloCostModel",
    "LINK_BW",
    "PEAK_FLOPS",
    "RooflineReport",
    "active_param_count",
    "analyze_hlo",
    "build_report",
    "model_flops_estimate",
    "parse_collectives",
]

"""Markdown roofline report generator for EXPERIMENTS.md §Roofline.

Reads experiments/dryrun artifacts and renders the per-(arch x shape)
table: three terms, dominant bottleneck, MODEL_FLOPS ratio, and the
one-line movement note derived from the dominant term + breakdown.
"""
from __future__ import annotations

import json
from pathlib import Path


def _movement_note(r: dict) -> str:
    dom = r["dominant"]
    coll = r.get("coll_breakdown", {})
    if dom == "collective":
        worst = max(coll, key=coll.get) if coll else "all-reduce"
        return f"cut {worst} payloads (dominant collective op)"
    if dom == "memory":
        if r["shape"].startswith("train"):
            return "keep recurrent/attn intermediates tile-resident (kernel/chunked form)"
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            return "in-place per-layer cache updates; shrink cache dtype"
        return "fuse softmax chain; bf16 intermediates"
    return "increase per-chip work (batch) or cut redundant FLOPs (wedge/remat)"


def render_table(dryrun_dir: str = "experiments/dryrun",
                 mesh: str = "single_pod_16x16") -> str:
    rows = []
    for f in sorted(Path(dryrun_dir).glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | FAILED: {r['status'][:40]} ||||||")
            continue
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        frac = r["compute_s"] / total if total else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | {r['memory_s']:.3g} "
            f"| {r['collective_s']:.3g} | **{r['dominant']}** | {frac:.3f} "
            f"| {r['flops_ratio']:.2f} | {_movement_note(r)} |"
        )
    header = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant "
        "| compute-frac | MODEL/HLO flops | what moves the dominant term |\n"
        "|---|---|---|---|---|---|---|---|---|"
    )
    return header + "\n" + "\n".join(rows)


if __name__ == "__main__":
    import sys
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    print(render_table(d))

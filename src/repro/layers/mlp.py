"""Feed-forward blocks: SwiGLU [arXiv:2002.05202], GELU, squared-ReLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.distributed.sharding import constrain
from repro.layers.linear import apply_linear, init_linear, linear_specs
from repro.utils import Params, split_keys


def init_mlp(key: jax.Array, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    if cfg.activation == "swiglu":
        keys = split_keys(key, ["gate", "up", "down"])
        return {
            "gate": init_linear(keys["gate"], cfg.d_model, d_ff),
            "up": init_linear(keys["up"], cfg.d_model, d_ff),
            "down": init_linear(keys["down"], d_ff, cfg.d_model),
        }
    keys = split_keys(key, ["up", "down"])
    return {
        "up": init_linear(keys["up"], cfg.d_model, d_ff, bias=cfg.qkv_bias),
        "down": init_linear(keys["down"], d_ff, cfg.d_model, bias=cfg.qkv_bias),
    }


def mlp_specs(cfg: ModelConfig) -> Params:
    if cfg.activation == "swiglu":
        return {
            "gate": linear_specs("fsdp", "tp"),
            "up": linear_specs("fsdp", "tp"),
            "down": linear_specs("tp", "fsdp"),
        }
    return {
        "up": linear_specs("fsdp", "tp", bias=cfg.qkv_bias),
        "down": linear_specs("tp", "fsdp", bias=cfg.qkv_bias),
    }


def _act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    if kind == "silu":
        return jax.nn.silu(x)
    raise ValueError(f"unknown activation {kind!r}")


def apply_mlp(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (..., D) -> (..., D); hidden activations sharded over tp."""
    if cfg.activation == "swiglu":
        h = jax.nn.silu(apply_linear(params["gate"], x)) * apply_linear(params["up"], x)
    else:
        h = _act(apply_linear(params["up"], x), cfg.activation)
    h = constrain(h, ("batch",) + (None,) * (x.ndim - 2) + ("tp",))
    y = apply_linear(params["down"], h)
    return constrain(y, ("batch", "sp", None) if x.ndim == 3 else ("batch",) + (None,) * (x.ndim - 1))

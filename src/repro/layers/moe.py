"""Mixture-of-Experts with top-k routing.

Two interchangeable implementations (cfg.moe.impl):

* ``scatter`` — production path: capacity-bounded token dispatch into an
  (E, C, D) buffer via scatter-add, batched expert GEMMs, gather-combine.
  Dropped tokens (over capacity) contribute zero, matching Switch/GShard
  semantics [arXiv:2101.03961, arXiv:2006.16668].
* ``dense`` — oracle: every expert runs on every token, outputs weighted by
  the (renormalised) top-k gates.  O(E) FLOPs — smoke tests only, and the
  correctness reference for the scatter path when nothing is dropped.

* ``ep_a2a`` — expert-parallel shard_map path: tokens stay on their
  (data x model) shard, routing is local, and dispatch/combine move through
  ``jax.lax.all_to_all`` over the model(=expert) axis — the collective is
  O(tokens x D / chips) instead of the all-reduce of the full (E, C, D)
  buffer XLA emits for the cross-shard scatter (measured 4.9 TB/chip on
  dbrx train_4k; see EXPERIMENTS.md §Perf iteration 2).

Returns (y, aux_loss): aux is the Switch load-balance loss
``E * sum_e f_e * P_e`` (fraction-dispatched x mean router prob).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.config.core import ModelConfig
from repro.distributed.sharding import active_mesh, constrain
from repro.utils import Params, split_keys, truncated_normal_init


def init_moe(key: jax.Array, cfg: ModelConfig) -> Params:
    moe = cfg.moe
    keys = split_keys(key, ["router", "gate", "up", "down"])
    e, d, f = moe.num_experts, cfg.d_model, cfg.d_ff
    return {
        "router": truncated_normal_init(keys["router"], (d, e), fan_in=d),
        "gate": truncated_normal_init(keys["gate"], (e, d, f), fan_in=d),
        "up": truncated_normal_init(keys["up"], (e, d, f), fan_in=d),
        "down": truncated_normal_init(keys["down"], (e, f, d), fan_in=f),
    }


def moe_specs(cfg: ModelConfig) -> Params:
    return {
        "router": (None, None),
        "gate": ("expert", "fsdp", None),
        "up": ("expert", "fsdp", None),
        "down": ("expert", None, "fsdp"),
    }


def _router(params: Params, x: jnp.ndarray, top_k: int):
    """x: (N, D) -> (weights (N,k) f32, indices (N,k) i32, probs (N,E) f32)."""
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, indices = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights, indices, probs


def _aux_loss(probs: jnp.ndarray, indices: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Switch/GShard load-balance loss, normalised so that perfectly uniform
    dispatch + uniform router probs give exactly 1.0 (f_e is the fraction of
    the N*k dispatch slots assigned to expert e)."""
    dispatch = jax.nn.one_hot(indices, num_experts, dtype=jnp.float32)  # (N,k,E)
    k = indices.shape[-1]
    frac_dispatched = jnp.mean(jnp.sum(dispatch, axis=1), axis=0) / k   # (E,)
    mean_prob = jnp.mean(probs, axis=0)                                 # (E,)
    return num_experts * jnp.sum(frac_dispatched * mean_prob)


def _expert_ffn(params: Params, h: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Batched per-expert SwiGLU: h (E, C, D) -> (E, C, D)."""
    dt = h.dtype
    g = jnp.einsum("ecd,edf->ecf", h, params["gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", h, params["up"].astype(dt))
    a = jax.nn.silu(g) * u
    # experts already occupy the model axis; hidden dim stays local
    a = constrain(a, ("expert", None, None))
    return jnp.einsum("ecf,efd->ecd", a, params["down"].astype(dt))


def capacity(num_tokens: int, cfg: ModelConfig) -> int:
    moe = cfg.moe
    c = math.ceil(num_tokens * moe.top_k / moe.num_experts * moe.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def apply_moe(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (B, S, D), aux loss (scalar f32)."""
    moe = cfg.moe
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    xf = constrain(xf, ("tokens", None))
    weights, indices, probs = _router(params, xf, moe.top_k)
    aux = _aux_loss(probs, indices, moe.num_experts)

    if moe.impl == "dense":
        y = _dense_combine(params, xf, weights, indices, cfg)
    else:
        y = _scatter_combine(params, xf, weights, indices, cfg)
    return y.reshape(b, s, d), aux


def apply_moe_ep(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map + all_to_all (the §Perf fix).

    Layout: x (B, S, D) with B over batch axes and S over the model axis
    (sequence-parallel residual); experts over the model axis; expert
    weights FSDP-sharded over "data" (all-gathered locally per layer).
    Requires an active mesh — callers fall back to :func:`apply_moe`
    otherwise (CPU tests).
    """
    mesh = active_mesh()
    if mesh is None:
        return apply_moe(params, x, cfg)

    from repro.distributed.sharding import active_rules
    rules = active_rules()
    moe = cfg.moe
    batch_axes = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    model_axis = rules.tp
    n_exp_shards = mesh.shape[model_axis]
    assert moe.num_experts % n_exp_shards == 0
    e_loc = moe.num_experts // n_exp_shards

    if x.shape[1] % n_exp_shards != 0:
        # decode shapes (S=1): too few tokens to amortise the EP exchange +
        # per-layer weight gathers (measured REGRESSION on moonshot/dbrx
        # decode_32k — §Perf cell 3 iteration 2, refuted hypothesis); the
        # scatter path's small (E, C, D) buffer is the better trade here.
        return apply_moe(params, x, cfg)

    def local_moe(router_w, gate_w, up_w, down_w, x_loc):
        # x_loc: (B_loc, S_loc, D); weights: router (D, E) replicated,
        # gate/up/down (E_loc, D_loc, F)/(E_loc, F, D_loc) — fsdp-sharded
        b_loc, s_loc, d = x_loc.shape
        n_loc = b_loc * s_loc
        xf = x_loc.reshape(n_loc, d)
        weights, indices, probs = _router({"router": router_w}, xf, moe.top_k)
        # aux from GLOBAL sufficient statistics (pmean the per-expert
        # fractions first; pmean of local products would differ)
        disp = jax.nn.one_hot(indices, moe.num_experts, dtype=jnp.float32)
        f_e = jnp.mean(jnp.sum(disp, axis=1), axis=0) / moe.top_k
        p_e = jnp.mean(probs, axis=0)
        for ax in (model_axis,) + tuple(batch_axes):
            f_e = jax.lax.pmean(f_e, ax)
            p_e = jax.lax.pmean(p_e, ax)
        aux = moe.num_experts * jnp.sum(f_e * p_e)

        # capacity per (source shard, expert)
        cap = max(8, -(-math.ceil(n_loc * moe.top_k / moe.num_experts
                                  * moe.capacity_factor) // 8) * 8)

        # local dispatch into a per-expert send buffer (E, cap, D)
        flat_e = indices.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, moe.num_experts, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        flat_p = jnp.sum(pos * onehot, axis=-1)
        dropped = flat_p >= cap
        flat_p = jnp.where(dropped, cap, flat_p)
        upd = jnp.repeat(xf, moe.top_k, axis=0)
        send = jnp.zeros((moe.num_experts, cap + 1, d), xf.dtype)
        send = send.at[flat_e, flat_p].add(upd)[:, :cap]      # (E, cap, D)

        # exchange: expert-major blocks to their owning shard
        # (E, cap, D) -> (n_shards, E_loc, cap, D) -> a2a -> recv blocks
        send = send.reshape(n_exp_shards, e_loc, cap, d)
        recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0,
                                  tiled=False)
        # recv: (n_shards, E_loc, cap, D) — tokens from every source shard
        recv = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_exp_shards * cap, d)

        # expert FFN with fsdp all-gathered weights
        gather = lambda w, ax: jax.lax.all_gather(w, "data", axis=ax, tiled=True)
        g_w = gather(gate_w, 1)
        u_w = gather(up_w, 1)
        d_w = gather(down_w, 2)
        dt = recv.dtype
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, g_w.astype(dt))) * jnp.einsum(
            "ecd,edf->ecf", recv, u_w.astype(dt))
        out = jnp.einsum("ecf,efd->ecd", h, d_w.astype(dt))

        # return path: reverse the exchange
        out = out.reshape(e_loc, n_exp_shards, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(out, model_axis, split_axis=0, concat_axis=0,
                                  tiled=False)
        back = back.reshape(moe.num_experts, cap, d)
        back = jnp.concatenate([back, jnp.zeros((moe.num_experts, 1, d), dt)], axis=1)

        gathered = back[flat_e, flat_p].reshape(n_loc, moe.top_k, d)
        w_mask = jnp.where(dropped.reshape(n_loc, moe.top_k), 0.0, weights)
        y = jnp.einsum("nkd,nk->nd", gathered.astype(jnp.float32),
                       w_mask.astype(jnp.float32))
        return y.astype(x_loc.dtype).reshape(b_loc, s_loc, d), aux

    x_spec = P(batch_axes, model_axis, None)
    fn = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(
            P(None, None),                      # router replicated
            P(model_axis, "data", None),        # gate (E, D, F)
            P(model_axis, "data", None),        # up
            P(model_axis, None, "data"),        # down (E, F, D)
            x_spec,
        ),
        out_specs=(x_spec, P()),
        check_rep=False,
    )
    return fn(params["router"], params["gate"], params["up"], params["down"], x)


def _apply_moe_ep_replicated(params, x, cfg: ModelConfig, mesh, rules):
    """EP for token counts too small to shard over the model axis (decode):
    tokens replicated over model; each shard computes its local experts and
    the outputs psum-combine.  Collective = one psum of (N, D).

    STATUS: kept as the measured-REFUTED §Perf cell-3 iteration-1 variant
    (per-layer weight gathers + capacity padding dominate at decode token
    counts; see EXPERIMENTS.md).  Production decode uses the scatter path;
    this function remains test-covered reference material."""
    moe = cfg.moe
    batch_axes = rules.batch if isinstance(rules.batch, tuple) else (rules.batch,)
    model_axis = rules.tp
    n_exp_shards = mesh.shape[model_axis]
    e_loc = moe.num_experts // n_exp_shards

    def local_moe(router_w, gate_w, up_w, down_w, x_loc):
        b_loc, s_loc, d = x_loc.shape
        n_loc = b_loc * s_loc
        xf = x_loc.reshape(n_loc, d)
        weights, indices, probs = _router({"router": router_w}, xf, moe.top_k)
        disp = jax.nn.one_hot(indices, moe.num_experts, dtype=jnp.float32)
        f_e = jnp.mean(jnp.sum(disp, axis=1), axis=0) / moe.top_k
        p_e = jnp.mean(probs, axis=0)
        for ax in tuple(batch_axes):
            f_e = jax.lax.pmean(f_e, ax)
            p_e = jax.lax.pmean(p_e, ax)
        aux = moe.num_experts * jnp.sum(f_e * p_e)

        sid = jax.lax.axis_index(model_axis)
        local = (indices // e_loc) == sid                      # (N, k) mine?
        local_idx = jnp.where(local, indices % e_loc, e_loc)   # park others
        cap = max(8, -(-math.ceil(n_loc * moe.top_k / moe.num_experts
                                  * moe.capacity_factor) // 8) * 8)
        flat_e = local_idx.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, e_loc + 1, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        flat_p = jnp.sum(pos * onehot, axis=-1)
        dropped = (flat_p >= cap) | (flat_e == e_loc)
        flat_p = jnp.where(dropped, cap, flat_p)
        flat_e = jnp.where(flat_e == e_loc, 0, flat_e)

        upd = jnp.repeat(xf, moe.top_k, axis=0)
        upd = jnp.where(dropped[:, None], 0.0, upd)
        buf = jnp.zeros((e_loc, cap + 1, d), xf.dtype)
        buf = buf.at[flat_e, flat_p].add(upd)[:, :cap]

        gather = lambda w, ax: jax.lax.all_gather(w, "data", axis=ax, tiled=True)
        g_w, u_w, d_w = gather(gate_w, 1), gather(up_w, 1), gather(down_w, 2)
        dt = buf.dtype
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, g_w.astype(dt))) * jnp.einsum(
            "ecd,edf->ecf", buf, u_w.astype(dt))
        out = jnp.einsum("ecf,efd->ecd", h, d_w.astype(dt))
        out = jnp.concatenate([out, jnp.zeros((e_loc, 1, d), dt)], axis=1)

        gathered = out[flat_e, jnp.where(dropped, cap, flat_p)].reshape(
            n_loc, moe.top_k, d)
        w_mask = jnp.where(dropped.reshape(n_loc, moe.top_k), 0.0, weights)
        y = jnp.einsum("nkd,nk->nd", gathered.astype(jnp.float32),
                       w_mask.astype(jnp.float32))
        y = jax.lax.psum(y, model_axis)                        # combine experts
        return y.astype(x_loc.dtype).reshape(b_loc, s_loc, d), aux

    x_spec = P(batch_axes, None, None)
    fn = shard_map(
        local_moe,
        mesh=mesh,
        in_specs=(
            P(None, None),
            P(model_axis, "data", None),
            P(model_axis, "data", None),
            P(model_axis, None, "data"),
            x_spec,
        ),
        out_specs=(x_spec, P()),
        check_rep=False,
    )
    return fn(params["router"], params["gate"], params["up"], params["down"], x)


def _dense_combine(params, xf, weights, indices, cfg: ModelConfig) -> jnp.ndarray:
    moe = cfg.moe
    n, d = xf.shape
    # every expert on every token: (E, N, D)
    h = jnp.broadcast_to(xf[None], (moe.num_experts, n, d))
    out = _expert_ffn(params, h, cfg)                        # (E, N, D)
    gates = jnp.zeros((n, moe.num_experts), jnp.float32)
    gates = gates.at[jnp.arange(n)[:, None], indices].add(weights)
    y = jnp.einsum("end,ne->nd", out.astype(jnp.float32), gates)
    return y.astype(xf.dtype)


def _scatter_combine(params, xf, weights, indices, cfg: ModelConfig) -> jnp.ndarray:
    moe = cfg.moe
    n, d = xf.shape
    e, k = moe.num_experts, moe.top_k
    cap = capacity(n, cfg)

    # position of each (token, choice) within its expert, in flat order
    flat_e = indices.reshape(-1)                                  # (N*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (N*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                     # exclusive cumsum
    flat_p = jnp.sum(pos * onehot, axis=-1)                       # (N*k,)
    dropped = flat_p >= cap
    flat_p = jnp.where(dropped, cap, flat_p)                      # park dropped in slot `cap`

    # dispatch: (E, cap+1, D) buffer; slot `cap` is the drop bin
    upd = jnp.repeat(xf, k, axis=0)                               # (N*k, D)
    buf = jnp.zeros((e, cap + 1, d), xf.dtype)
    buf = buf.at[flat_e, flat_p].add(upd)
    buf = constrain(buf, ("expert", None, None))

    out = _expert_ffn(params, buf[:, :cap], cfg)                  # (E, cap, D)
    out = jnp.concatenate([out, jnp.zeros((e, 1, d), out.dtype)], axis=1)
    out = constrain(out, ("expert", None, None))

    # combine: gather each (token, choice) result, weight, sum over k
    gathered = out[flat_e, flat_p].reshape(n, k, d)               # dropped -> zeros
    w = jnp.where(dropped.reshape(n, k), 0.0, weights).astype(jnp.float32)
    y = jnp.einsum("nkd,nk->nd", gathered.astype(jnp.float32), w)
    return y.astype(xf.dtype)

"""Rotary position embeddings (RoPE) [arXiv:2104.09864]."""
from __future__ import annotations

import jax.numpy as jnp


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies for each rotated pair: (head_dim // 2,)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate ``x`` (..., S, H, head_dim) by per-position angles.

    ``positions`` broadcasts against the sequence dim: (S,) or (B, S).
    Uses the half-split convention (rotate_half), matching llama-family
    checkpoints.
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                   # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rotated.astype(x.dtype)


def sinusoidal_embedding(seq_len: int, dim: int, max_timescale: float = 10000.0) -> jnp.ndarray:
    """Fixed sinusoidal table (seq_len, dim) — whisper encoder positions."""
    half = dim // 2
    positions = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    timescales = max_timescale ** (jnp.arange(half, dtype=jnp.float32) / max(1, half - 1))
    args = positions / timescales[None, :]
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)

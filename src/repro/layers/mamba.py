"""Mamba selective-SSM block [arXiv:2312.00752] (jamba's recurrent mixer).

State h in R^{d_inner x d_state} per batch element:

    h_t = exp(dt_t * A) . h_{t-1} + dt_t * B_t * x_t     (A diagonal, <0)
    y_t = C_t . h_t + D * x_t

with data-dependent (dt_t, B_t, C_t) — the "selective" part.  Sequence form
uses a chunked nested scan (outer over S/chunk, inner over steps) so nothing
of shape (S, d_inner, d_state) is ever materialised; d_inner is
tensor-parallel (the recurrence is diagonal, so the scan stays local to each
shard — the TPU analogue of the paper's per-module locality).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.distributed.sharding import constrain
from repro.layers.linear import apply_linear, init_linear, linear_specs
from repro.utils import Params, split_keys, truncated_normal_init


def mamba_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    dt_rank = ssm.dt_rank or -(-cfg.d_model // 16)
    return d_inner, ssm.d_state, dt_rank


def init_mamba(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_inner, d_state, dt_rank = mamba_dims(cfg)
    k = split_keys(key, ["in_x", "in_z", "conv", "x_bc_dt", "dt_up", "out", "a"])
    return {
        "in_x": init_linear(k["in_x"], d, d_inner),
        "in_z": init_linear(k["in_z"], d, d_inner),  # gate branch
        "conv_w": truncated_normal_init(k["conv"], (cfg.ssm.d_conv, d_inner), fan_in=cfg.ssm.d_conv),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        # x -> (dt_rank + 2*d_state): dt low-rank + B + C
        "x_proj": init_linear(k["x_bc_dt"], d_inner, dt_rank + 2 * d_state),
        "dt_proj": init_linear(k["dt_up"], dt_rank, d_inner, bias=True),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, 1))),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out": init_linear(k["out"], d_inner, d),
    }


def mamba_specs(cfg: ModelConfig) -> Params:
    return {
        "in_x": linear_specs("fsdp", "tp"),
        "in_z": linear_specs("fsdp", "tp"),
        "conv_w": (None, "tp"),
        "conv_b": ("tp",),
        "x_proj": linear_specs("tp", None),
        "dt_proj": linear_specs(None, "tp", bias=True),
        "a_log": ("tp", None),
        "d_skip": ("tp",),
        "out": linear_specs("tp", "fsdp"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 conv_state: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).

    conv_state: (B, K-1, C) history for decode; returns (y, new_state).
    """
    k = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # (B, S+K-1, C)
    # sum_j w[j] * x[t + j - (K-1)]  via K shifted adds (K=4: cheap, fusion-friendly)
    y = sum(w[j].astype(x.dtype) * xp[:, j : j + x.shape[1], :] for j in range(k))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(k - 1):, :] if k > 1 else conv_state
    return y, new_state


def _ssm_inputs(params: Params, xc: jnp.ndarray, cfg: ModelConfig):
    """xc: (B, S, d_inner) post-conv activations -> dt, B_t, C_t."""
    d_inner, d_state, dt_rank = mamba_dims(cfg)
    proj = apply_linear(params["x_proj"], xc)
    dt_lr, b_t, c_t = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(apply_linear(params["dt_proj"], dt_lr).astype(jnp.float32))
    return dt, b_t.astype(jnp.float32), c_t.astype(jnp.float32)


def ssm_scan(dt, b_t, c_t, xc, a, state, chunk: int = 256):
    """Selective scan.  dt/xc: (B,S,d_inner); b_t/c_t: (B,S,d_state);
    a: (d_inner, d_state) (negative); state: (B,d_inner,d_state) f32.
    Returns (y (B,S,d_inner) f32, final state)."""
    bsz, s, d_inner = xc.shape
    d_state = b_t.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        b_t = jnp.pad(b_t, ((0, 0), (0, pad), (0, 0)))
        c_t = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0)))
    n = (s + pad) // chunk

    def chunks(t):  # (B, S, F) -> (n, chunk, B, F)
        return jnp.moveaxis(t.reshape(bsz, n, chunk, t.shape[-1]), (1, 2), (0, 1))

    dtc, xcc, btc, ctc = map(chunks, (dt, xc, b_t, c_t))

    def inner(h, step):
        dt_t, x_t, bt_t, ct_t = step          # (B,di) (B,di) (B,ds) (B,ds)
        da = jnp.exp(dt_t[..., None] * a[None])            # (B,di,ds)
        db = dt_t[..., None] * bt_t[:, None, :]            # (B,di,ds)
        h = da * h + db * x_t.astype(jnp.float32)[..., None]
        y_t = jnp.einsum("bds,bs->bd", h, ct_t)
        return h, y_t

    def outer(h, blk):
        h, y_blk = jax.lax.scan(inner, h, blk)
        return h, y_blk

    state, y = jax.lax.scan(outer, state, (dtc, xcc, btc, ctc))
    y = y.reshape(n * chunk, bsz, d_inner)[:s]
    return jnp.moveaxis(y, 0, 1), state


def ssm_step(dt, b_t, c_t, xc, a, state):
    """One decode step: dt/xc (B,d_inner); b_t/c_t (B,d_state)."""
    da = jnp.exp(dt[..., None] * a[None])
    db = dt[..., None] * b_t[:, None, :]
    state = da * state + db * xc.astype(jnp.float32)[..., None]
    y = jnp.einsum("bds,bs->bd", state, c_t)
    return y, state


def apply_mamba(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                state: Params | None = None, chunk: int = 256):
    """Sequence form.  x: (B,S,D) -> (y, new_state dict)."""
    bsz, s, _ = x.shape
    d_inner, d_state, _ = mamba_dims(cfg)
    if state is None:
        state = {
            "ssm": jnp.zeros((bsz, d_inner, d_state), jnp.float32),
            "conv": jnp.zeros((bsz, cfg.ssm.d_conv - 1, d_inner), x.dtype),
        }
    xz = apply_linear(params["in_x"], x)
    z = apply_linear(params["in_z"], x)
    xz = constrain(xz, ("batch", None, "tp"))
    xc, conv_state = _causal_conv(xz, params["conv_w"], params["conv_b"], state["conv"])
    xc = jax.nn.silu(xc)
    dt, b_t, c_t = _ssm_inputs(params, xc, cfg)
    a = -jnp.exp(params["a_log"])
    y, ssm_state = ssm_scan(dt, b_t, c_t, xc, a, state["ssm"], chunk=chunk)
    y = (y.astype(x.dtype) + params["d_skip"].astype(x.dtype) * xc) * jax.nn.silu(z)
    out = apply_linear(params["out"], y)
    sp = "sp" if s > 1 else None
    return constrain(out, ("batch", sp, None)), {"ssm": ssm_state, "conv": conv_state}


def apply_mamba_step(params: Params, x: jnp.ndarray, cfg: ModelConfig, state: Params):
    """Decode step.  x: (B, D) -> (y (B,D), new_state)."""
    y, new_state = apply_mamba(params, x[:, None, :], cfg, state)
    return y[:, 0, :], new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> Params:
    d_inner, d_state, _ = mamba_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, d_inner, d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, d_inner), dtype),
    }


def mamba_state_specs() -> Params:
    return {"ssm": ("batch", "tp", None), "conv": ("batch", None, "tp")}

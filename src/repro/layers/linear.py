"""Plain linear layers with logical sharding specs."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils import Params, truncated_normal_init


def init_linear(key: jax.Array, d_in: int, d_out: int, bias: bool = False) -> Params:
    p = {"w": truncated_normal_init(key, (d_in, d_out), fan_in=d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear_specs(in_axis: Optional[str], out_axis: Optional[str], bias: bool = False) -> Params:
    s = {"w": (in_axis, out_axis)}
    if bias:
        s["b"] = (out_axis,)
    return s


def apply_linear(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y

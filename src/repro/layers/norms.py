"""Normalisation layers: RMSNorm, LayerNorm, non-parametric LN (olmo)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import Params


def init_norm(kind: str, dim: int) -> Params:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), jnp.float32)}
    if kind == "layernorm":
        return {"scale": jnp.ones((dim,), jnp.float32), "bias": jnp.zeros((dim,), jnp.float32)}
    if kind == "nonparametric_ln":
        return {}
    raise ValueError(f"unknown norm kind {kind!r}")


def norm_specs(kind: str) -> Params:
    """Logical-axis specs matching :func:`init_norm` (all replicated)."""
    if kind == "rmsnorm":
        return {"scale": (None,)}
    if kind == "layernorm":
        return {"scale": (None,), "bias": (None,)}
    if kind == "nonparametric_ln":
        return {}
    raise ValueError(f"unknown norm kind {kind!r}")


def apply_norm(params: Params, x: jnp.ndarray, kind: str, eps: float = 1e-5) -> jnp.ndarray:
    """Normalise over the trailing dim; statistics in fp32 for stability."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32)
    elif kind in ("layernorm", "nonparametric_ln"):
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) / jnp.sqrt(var + eps)
        if kind == "layernorm":
            y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(f"unknown norm kind {kind!r}")
    return y.astype(dtype)

"""Token embedding + unembedding with vocab sharding, and chunked
cross-entropy (never materialises full (B, S, V) logits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.distributed.sharding import constrain
from repro.utils import Params, truncated_normal_init


def init_embedding(key: jax.Array, vocab: int, d_model: int) -> Params:
    return {"table": truncated_normal_init(key, (vocab, d_model), fan_in=d_model)}


def embedding_specs() -> Params:
    return {"table": ("tp", "fsdp")}


def embed_tokens(params: Params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    """tokens (B, S) int32 -> (B, S, D)."""
    y = params["table"].astype(dtype)[tokens]
    return constrain(y, ("batch", "sp", None))


def init_unembed(key: jax.Array, d_model: int, vocab: int) -> Params:
    return {"w": truncated_normal_init(key, (d_model, vocab), fan_in=d_model)}


def unembed_specs() -> Params:
    return {"w": ("fsdp", "tp")}


def chunked_xent_loss(
    unembed_w: jnp.ndarray,
    h: jnp.ndarray,
    labels: jnp.ndarray,
    *,
    chunk: int = 2048,
    z_loss: float = 0.0,
) -> jnp.ndarray:
    """Mean next-token cross-entropy, scanning over sequence chunks.

    h: (B, S, D) final hidden states; labels: (B, S) int32 (-1 = ignore).
    Never materialises more than (B, chunk, V) logits, which is what keeps
    the 163k/200k-vocab archs inside HBM at train_4k.
    """
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n_chunks = (s + pad) // chunk
    hc = jnp.moveaxis(h.reshape(b, n_chunks, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n_chunks, chunk), 1, 0)

    def step(carry, blk):
        total, count = carry
        hb, lb = blk
        logits = hb @ unembed_w.astype(hb.dtype)              # (B, c, V)
        logits = constrain(logits, ("batch", None, "tp"))
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1
        )[..., 0]
        nll = lse - gold
        if z_loss:
            nll = nll + z_loss * jnp.square(lse)
        mask = (lb >= 0).astype(jnp.float32)
        return (total + jnp.sum(nll * mask), count + jnp.sum(mask)), None

    (total, count), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), (hc, lc))
    return total / jnp.maximum(count, 1.0)


def unembed_logits(unembed_w: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Logits for decode (B, 1, D) -> (B, 1, V)."""
    logits = h @ unembed_w.astype(h.dtype)
    return constrain(logits, ("batch", None, "tp"))

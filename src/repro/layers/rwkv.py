"""RWKV-6 "Finch" block [arXiv:2404.05892]: time-mix with data-dependent
per-channel decay + squared-ReLU channel-mix.

The WKV recurrence per head (state S in R^{dk x dv}):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (diag(u) k_t^T v_t + S_{t-1})

with w_t = exp(-exp(w_base + lora_w(x_t))) — the data-dependent decay that
distinguishes v6 from v5.  Sequence form runs a chunked scan (outer scan over
chunks, inner scan over steps) so HLO stays small and no (S, dk, dv) tensor
is ever materialised; step form serves decode.  kernels/wkv6.py holds the
Pallas chunk kernel; this module is its jnp oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.distributed.sharding import constrain
from repro.layers.linear import apply_linear, init_linear, linear_specs
from repro.layers.norms import apply_norm, init_norm, norm_specs
from repro.utils import Params, split_keys, truncated_normal_init


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.rwkv.head_dim
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def init_time_mix(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h, hd = _heads(cfg)
    r = cfg.rwkv.decay_lora
    keys = split_keys(key, ["r", "k", "v", "g", "o", "w1", "w2", "mix", "u", "wbase", "ln"])
    return {
        "r": init_linear(keys["r"], d, d),
        "k": init_linear(keys["k"], d, d),
        "v": init_linear(keys["v"], d, d),
        "g": init_linear(keys["g"], d, d),
        "o": init_linear(keys["o"], d, d),
        # data-dependent decay LoRA: w_t = wbase + tanh(x W1) W2
        "w1": truncated_normal_init(keys["w1"], (d, r), fan_in=d),
        "w2": truncated_normal_init(keys["w2"], (r, d), fan_in=r),
        "wbase": jnp.full((d,), -6.0, jnp.float32),  # exp(-exp(-6)) ~ slow decay
        "u": truncated_normal_init(keys["u"], (h, hd), fan_in=hd),  # bonus
        "mix": 0.5 * jnp.ones((5, d), jnp.float32),  # token-shift mixes (r,k,v,g,w)
        "gn": init_norm("layernorm", hd),            # per-head group norm
    }


def time_mix_specs(cfg: ModelConfig) -> Params:
    return {
        "r": linear_specs("fsdp", "tp"),
        "k": linear_specs("fsdp", "tp"),
        "v": linear_specs("fsdp", "tp"),
        "g": linear_specs("fsdp", "tp"),
        "o": linear_specs("tp", "fsdp"),
        "w1": ("fsdp", None),
        "w2": (None, "tp"),
        "wbase": ("tp",),
        "u": ("tp", None),
        "mix": (None, "tp"),
        "gn": norm_specs("layernorm"),
    }


def _token_shift(x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    """Shift sequence right by one; x_prev fills position 0. x: (B,S,D)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _projections(params: Params, x: jnp.ndarray, shifted: jnp.ndarray, cfg: ModelConfig):
    """Compute r,k,v,g,w streams with per-stream token-shift mixing."""
    mix = params["mix"].astype(x.dtype)  # (5, D)
    streams = [x + m[None, None, :] * (shifted - x) for m in mix]
    xr, xk, xv, xg, xw = streams
    h, hd = _heads(cfg)

    def split_heads(t):
        return t.reshape(t.shape[0], t.shape[1], h, hd)

    r = split_heads(apply_linear(params["r"], xr))
    k = split_heads(apply_linear(params["k"], xk))
    v = split_heads(apply_linear(params["v"], xv))
    g = jax.nn.silu(apply_linear(params["g"], xg))
    w_log = params["wbase"].astype(jnp.float32) + (
        jnp.tanh(xw.astype(jnp.float32) @ params["w1"].astype(jnp.float32))
        @ params["w2"].astype(jnp.float32)
    )
    w = jnp.exp(-jnp.exp(w_log))  # in (0,1), per channel; (B,S,D) f32
    w = split_heads(w)
    return r, k, v, g, w


def wkv_scan(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray, u: jnp.ndarray,
    state: jnp.ndarray, chunk: int = 64,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run the WKV recurrence over a full sequence.

    r,k,v: (B,S,H,hd); w: (B,S,H,hd) f32 decay in (0,1); u: (H,hd) bonus;
    state: (B,H,hd,hd) f32.  Returns (y (B,S,H,hd) f32, final state).
    Nested chunked scan: outer over S/chunk, inner over chunk.
    """
    b, s, h, hd = r.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    n = (s + pad) // chunk

    def reshape_chunks(t):  # (B, S, H, hd) -> (n, chunk, B, H, hd)
        return jnp.moveaxis(t.reshape(b, n, chunk, h, hd), (1, 2), (0, 1))

    rc, kc, vc, wc = map(reshape_chunks, (r, k, v, w))

    def inner(state, step):
        r_t, k_t, v_t, w_t = step  # each (B,H,hd)
        kv = k_t[..., :, None].astype(jnp.float32) * v_t[..., None, :].astype(jnp.float32)
        y_t = jnp.einsum(
            "bhk,bhkv->bhv", r_t.astype(jnp.float32),
            state + u[None, :, :, None].astype(jnp.float32) * kv,
        )
        state = w_t[..., :, None] * state + kv
        return state, y_t

    def outer(state, blk):
        state, y_blk = jax.lax.scan(inner, state, blk)
        return state, y_blk

    state, y = jax.lax.scan(outer, state, (rc, kc, vc, wc))
    y = y.reshape(n * chunk, b, h, hd)[:s]
    return jnp.moveaxis(y, 0, 1), state  # (B,S,H,hd)


def wkv_scan_chunked(
    r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray, u: jnp.ndarray,
    state: jnp.ndarray, sub_chunk: int = 16, w_min_log: float = -4.0,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked MATMUL form of the WKV recurrence (GLA-style [arXiv:2312.06635],
    the XLA-side analogue of the Pallas wkv6 kernel).

    Replaces T sequential per-step outer products with T/16 dense tiles:

        scores[t,s] = (r_t * Q_{t-1}) . (k_s / Q_s)   (strictly lower tri)
        y = scores @ V + (r * Q_prev) @ S_in + diag bonus
        S_out = diag(Q_C) S_in + (k * (Q_C / Q_s))^T V

    where Q = intra-tile cumprod(w).  Per-step intermediates never leave the
    tile (registers/VMEM), cutting HBM traffic ~20x on train_4k (§Perf).

    Numerics: the 1/Q factor is bounded by clamping the per-step decay to
    w >= exp(w_min_log); with tiles of 16 the largest exponent is
    16*|w_min_log| = 64 < log(f32max) ~ 88.  Channels decaying faster than
    e^-4/step forget within ~2 steps, so the clamp is semantically inert; it
    is validated against the exact scan in tests.
    """
    b, s, h, hd = r.shape
    c = min(sub_chunk, s)
    pad = (-s) % c
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    n = (s + pad) // c

    def chunks(t):  # (B, S, H, hd) -> (n, B, H, c, hd)
        return jnp.moveaxis(t.reshape(b, n, c, h, hd), (1, 3), (0, 2))

    rc, kc, vc, wc = map(chunks, (r, k, v, w))
    u_f = u.astype(jnp.float32)[None, :, None, :]          # (1, H, 1, hd)
    tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)    # strict lower

    def tile(s_in, blk):
        r_t, k_t, v_t, w_t = blk                           # (B, H, c, hd)
        r_f = r_t.astype(jnp.float32)
        k_f = k_t.astype(jnp.float32)
        v_f = v_t.astype(jnp.float32)
        w_f = jnp.clip(w_t.astype(jnp.float32), jnp.exp(w_min_log), 1.0)
        logq = jnp.cumsum(jnp.log(w_f), axis=2)            # (B, H, c, hd), <= 0
        q = jnp.exp(logq)
        q_prev = jnp.exp(logq - jnp.log(w_f))              # Q_{t-1} = Q_t / w_t
        r_dec = r_f * q_prev                               # r_t * Q_{t-1}
        k_dec = k_f * jnp.exp(-logq)                       # k_s / Q_s  (bounded)
        scores = jnp.einsum("bhtd,bhsd->bhts", r_dec, k_dec) * tri[None, None]
        y = jnp.einsum("bhts,bhsv->bhtv", scores, v_f)     # intra-tile history
        y = y + jnp.einsum("bhtk,bhkv->bhtv", r_dec, s_in)  # carried state
        y = y + jnp.sum(r_f * u_f * k_f, axis=-1, keepdims=True) * v_f  # bonus
        q_end = q[:, :, -1:, :]                            # Q_C
        k_tail = k_f * jnp.exp(logq[:, :, -1:, :] - logq)  # k_s * Q_C/Q_s <= k_s
        s_out = q_end.swapaxes(2, 3) * s_in + jnp.einsum(
            "bhsk,bhsv->bhkv", k_tail, v_f
        )
        return s_out, y

    state, ys = jax.lax.scan(tile, state, (rc, kc, vc, wc))
    # (n, B, H, c, hd) -> (B, n*c, H, hd)
    ys = jnp.moveaxis(ys, (0, 3), (1, 2)).reshape(b, n * c, h, hd)[:, :s]
    return ys, state


def wkv_step(
    r, k, v, w, u, state
):
    """Single decode step: r,k,v,w (B,H,hd); state (B,H,hd,hd) f32."""
    kv = k[..., :, None].astype(jnp.float32) * v[..., None, :].astype(jnp.float32)
    y = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                   state + u[None, :, :, None].astype(jnp.float32) * kv)
    new_state = w[..., :, None].astype(jnp.float32) * state + kv
    return y, new_state


def apply_time_mix(
    params: Params, x: jnp.ndarray, cfg: ModelConfig,
    x_prev: jnp.ndarray | None = None, state: jnp.ndarray | None = None,
    chunk: int = 64,
):
    """Sequence form. x: (B,S,D).  Returns (y, (last_x, final_state))."""
    b, s, d = x.shape
    h, hd = _heads(cfg)
    if x_prev is None:
        x_prev = jnp.zeros((b, d), x.dtype)
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    shifted = _token_shift(x, x_prev)
    r, k, v, g, w = _projections(params, x, shifted, cfg)
    r = constrain(r, ("batch", None, "tp", None))
    k = constrain(k, ("batch", None, "tp", None))
    v = constrain(v, ("batch", None, "tp", None))
    if cfg.rwkv.scan_impl == "chunked":
        y, state = wkv_scan_chunked(r, k, v, w, params["u"], state)
    else:
        y, state = wkv_scan(r, k, v, w, params["u"], state, chunk=chunk)
    y = apply_norm(params["gn"], y, "layernorm")  # per-head norm
    y = y.reshape(b, s, d).astype(x.dtype) * g
    out = apply_linear(params["o"], y)
    sp = "sp" if s > 1 else None
    return constrain(out, ("batch", sp, None)), (x[:, -1, :], state)


def apply_time_mix_step(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                        x_prev: jnp.ndarray, state: jnp.ndarray):
    """Decode step. x: (B, D).  Returns (y (B,D), (x, new_state))."""
    b, d = x.shape
    h, hd = _heads(cfg)
    x3 = x[:, None, :]
    shifted = x_prev[:, None, :]
    r, k, v, g, w = _projections(params, x3, shifted, cfg)
    y, state = wkv_step(r[:, 0], k[:, 0], v[:, 0], w[:, 0], params["u"], state)
    y = apply_norm(params["gn"], y, "layernorm")  # (B,H,hd), per-head norm
    y = y.reshape(b, d).astype(x.dtype) * g[:, 0]
    return apply_linear(params["o"], y), (x, state)


def init_channel_mix(key: jax.Array, cfg: ModelConfig) -> Params:
    keys = split_keys(key, ["up", "down", "recv"])
    return {
        "up": init_linear(keys["up"], cfg.d_model, cfg.d_ff),
        "down": init_linear(keys["down"], cfg.d_ff, cfg.d_model),
        "recv": init_linear(keys["recv"], cfg.d_model, cfg.d_model),
        "mix": 0.5 * jnp.ones((2, cfg.d_model), jnp.float32),
    }


def channel_mix_specs(cfg: ModelConfig) -> Params:
    return {
        "up": linear_specs("fsdp", "tp"),
        "down": linear_specs("tp", "fsdp"),
        "recv": linear_specs("fsdp", "tp"),
        "mix": (None, "tp"),
    }


def apply_channel_mix(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                      x_prev: jnp.ndarray | None = None):
    """x: (B,S,D) (or (B,1,D) step).  Returns (y, last_x)."""
    b = x.shape[0]
    if x_prev is None:
        x_prev = jnp.zeros((b, x.shape[-1]), x.dtype)
    shifted = _token_shift(x, x_prev)
    mix = params["mix"].astype(x.dtype)
    xk = x + mix[0][None, None, :] * (shifted - x)
    xr = x + mix[1][None, None, :] * (shifted - x)
    k = jnp.square(jax.nn.relu(apply_linear(params["up"], xk)))
    k = constrain(k, ("batch", None, "tp"))
    kv = apply_linear(params["down"], k)
    r = jax.nn.sigmoid(apply_linear(params["recv"], xr))
    y = r * kv
    sp = "sp" if x.shape[1] > 1 else None
    return constrain(y, ("batch", sp, None)), x[:, -1, :]

"""Grouped-query attention: training/prefill (memory-bounded blocked softmax),
decode (KV cache, flash-decode-style partial-softmax combine), and whisper
cross-attention.

Design notes
------------
* **Blocked causal attention** (`blocked_attention`): an online-softmax scan
  over KV chunks.  Scores for (all-q x one-kv-chunk) are materialised per
  step, so peak memory is O(S * chunk) instead of O(S^2) — this is what lets
  the 32k prefill shapes fit HBM in the dry-run.  It is also the jnp oracle
  for the Pallas flash kernel (kernels/flash_attention.py).
* **Wedge skip** (`q_chunks > 1`): splits queries into chunks and lets chunk
  i attend only kv-chunks <= i, recovering the ~2x triangular FLOP saving at
  the cost of a slightly larger HLO.  This is one of the §Perf hillclimb
  levers.
* **Decode** uses one fused step over the full cache with a max-subtracted
  softmax; sharding: batch over ``batch``, kv-sequence over ``tp`` with a
  partial-softmax combine left to XLA's reduce (see serving/decode.py for
  the shard_map flash-decode used at scale).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config.core import ModelConfig
from repro.distributed.sharding import constrain
from repro.layers.linear import apply_linear, init_linear, linear_specs
from repro.layers.rotary import apply_rope
from repro.utils import Params, split_keys

NEG_INF = -1e30


def init_attention(key: jax.Array, cfg: ModelConfig, cross: bool = False) -> Params:
    hd = cfg.resolved_head_dim()
    keys = split_keys(key, ["q", "k", "v", "o"])
    return {
        "q": init_linear(keys["q"], cfg.d_model, cfg.num_heads * hd, bias=cfg.qkv_bias),
        "k": init_linear(keys["k"], cfg.d_model, cfg.num_kv_heads * hd, bias=False),
        "v": init_linear(keys["v"], cfg.d_model, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "o": init_linear(keys["o"], cfg.num_heads * hd, cfg.d_model, bias=cfg.qkv_bias),
    }


def attention_specs(cfg: ModelConfig) -> Params:
    return {
        "q": linear_specs("fsdp", "tp", bias=cfg.qkv_bias),
        "k": linear_specs("fsdp", "tp", bias=False),
        "v": linear_specs("fsdp", "tp", bias=cfg.qkv_bias),
        "o": linear_specs("tp", "fsdp", bias=cfg.qkv_bias),
    }


def _project_qkv(params: Params, x_q: jnp.ndarray, x_kv: jnp.ndarray, cfg: ModelConfig):
    hd = cfg.resolved_head_dim()
    bq, sq, _ = x_q.shape
    bk, sk, _ = x_kv.shape
    q = apply_linear(params["q"], x_q).reshape(bq, sq, cfg.num_heads, hd)
    k = apply_linear(params["k"], x_kv).reshape(bk, sk, cfg.num_kv_heads, hd)
    v = apply_linear(params["v"], x_kv).reshape(bk, sk, cfg.num_kv_heads, hd)
    q = constrain(q, ("batch", None, "tp", None))
    k = constrain(k, ("batch", None, "tp", None))
    v = constrain(v, ("batch", None, "tp", None))
    return q, k, v


def _expand_kv(k: jnp.ndarray, num_heads: int) -> jnp.ndarray:
    """Broadcast kv heads to query heads: (B,S,Hkv,d) -> (B,S,Hq,d)."""
    b, s, hkv, d = k.shape
    group = num_heads // hkv
    if group == 1:
        return k
    return jnp.repeat(k, group, axis=2)


def blocked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    kv_chunk: int = 1024,
    q_chunks: int = 1,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Online-softmax attention, O(S*chunk) memory.

    q: (B, Sq, H, d); k/v: (B, Sk, H, d) (kv heads already expanded).
    ``q_chunks > 1`` enables the causal wedge skip (chunk i of queries only
    scans kv chunks that intersect its causal window).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)

    if q_chunks > 1 and causal and sq == sk and q_offset == 0:
        assert sq % q_chunks == 0
        cq = sq // q_chunks
        outs = []
        for i in range(q_chunks):
            qi = q[:, i * cq : (i + 1) * cq]
            hi = (i + 1) * cq  # causal horizon for this q chunk
            outs.append(
                blocked_attention(
                    qi,
                    k[:, :hi],
                    v[:, :hi],
                    causal=True,
                    kv_chunk=min(kv_chunk, hi),
                    q_chunks=1,
                    q_offset=i * cq,
                )
            )
        return jnp.concatenate(outs, axis=1)

    kv_chunk = min(kv_chunk, sk)
    pad = (-sk) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (sk + pad) // kv_chunk
    kc = k.reshape(b, n_chunks, kv_chunk, h, d)
    vc = v.reshape(b, n_chunks, kv_chunk, h, d)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, blk):
        m_prev, l_prev, o_prev = carry
        k_blk, v_blk, blk_idx = blk
        kv_pos = blk_idx * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k_blk, preferred_element_type=jnp.float32)
        s = s * scale
        valid = kv_pos[None, :] < sk  # mask zero padding
        if causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(valid[None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        o_new = o_prev * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk, preferred_element_type=jnp.float32
        )
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    o0 = jnp.zeros((b, h, sq, d), jnp.float32)
    blk_ids = jnp.arange(n_chunks)
    (m, l, o), _ = jax.lax.scan(
        step, (m0, l0, o0), (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), blk_ids)
    )
    out = o / jnp.maximum(l[..., None], 1e-30)
    return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)  # (B, Sq, H, d)


def apply_attention(
    params: Params,
    x: jnp.ndarray,
    *,
    cfg: ModelConfig,
    causal: bool,
    positions: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
    x_kv: Optional[jnp.ndarray] = None,
    kv_chunk: int = 1024,
    q_chunks: int = 1,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill). x: (B, S, D).

    With ``return_kv`` also returns the (post-RoPE, un-expanded) K/V for KV
    cache population at prefill.
    """
    x_kv = x if x_kv is None else x_kv
    q, k, v = _project_qkv(params, x, x_kv, cfg)
    if use_rope:
        if positions is None:
            positions = jnp.arange(x.shape[1])
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kv = (k, v) if return_kv else None
    k = _expand_kv(k, cfg.num_heads)
    v = _expand_kv(v, cfg.num_heads)
    out = blocked_attention(q, k, v, causal=causal, kv_chunk=kv_chunk, q_chunks=q_chunks)
    out = constrain(out, ("batch", None, "tp", None))
    y = apply_linear(params["o"], out.reshape(x.shape[0], x.shape[1], -1))
    y = constrain(y, ("batch", "sp", None))
    if return_kv:
        return y, kv
    return y


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim()
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
    }


def kv_cache_specs() -> Params:
    # batch over data, kv sequence over the model axis (flash-decode layout)
    return {"k": ("batch", "tp", None, None), "v": ("batch", "tp", None, None)}


def decode_attention(
    params: Params,
    x: jnp.ndarray,
    cache: Params,
    cache_len: jnp.ndarray,
    *,
    cfg: ModelConfig,
    use_rope: bool = True,
    update_cache: bool = True,
) -> tuple[jnp.ndarray, Params]:
    """One-token decode: x (B, 1, D) against cache (B, S_max, Hkv, hd).

    Returns (y, new_cache).  The softmax over the cached sequence is computed
    in fp32 with explicit masking of positions >= cache_len + 1.
    """
    b, one, _ = x.shape
    assert one == 1
    hd = cfg.resolved_head_dim()
    q, k_new, v_new = _project_qkv(params, x, x, cfg)
    if use_rope:
        pos = jnp.full((1,), 0, jnp.int32) + cache_len
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)

    if update_cache:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, cache_len, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, cache_len, 0, 0)
        )
    else:
        k_cache, v_cache = cache["k"], cache["v"]
    k_cache = constrain(k_cache, ("batch", "tp", None, None))
    v_cache = constrain(v_cache, ("batch", "tp", None, None))

    s_max = k_cache.shape[1]
    group = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(b, cfg.num_kv_heads, group, hd)  # (B, Hkv, G, d) (Sq==1 folded)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_cache.astype(q.dtype), preferred_element_type=jnp.float32
    ) / math.sqrt(hd)
    valid = jnp.arange(s_max)[None, :] <= cache_len  # includes the new token
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache, preferred_element_type=jnp.float32
    )
    out = out.reshape(b, 1, cfg.num_heads * hd).astype(x.dtype)
    y = apply_linear(params["o"], out)
    y = constrain(y, ("batch", None, None))
    new_cache = {"k": k_cache, "v": v_cache} if update_cache else cache
    return y, new_cache

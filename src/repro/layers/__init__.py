"""Substrate layers: attention, MLP, MoE, norms, embeddings, RWKV6, Mamba."""

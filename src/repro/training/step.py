"""Train-step builder: value_and_grad + AdamW under pjit, with optional
microbatch gradient accumulation and int8+error-feedback gradient
compression.  All sharding constraints in the model code activate through
the mesh context captured at build time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config.core import TrainConfig
from repro.distributed.sharding import ShardingRules, mesh_context, rules_for_mesh
from repro.models.api import ModelAPI
from repro.optim import (
    AdamWState,
    adamw_update,
    compress_grads,
    init_error_feedback,
    init_opt_state,
    opt_state_specs,
)
from repro.utils import Params


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TrainState:
    params: Params
    opt: AdamWState
    ef: Optional[Params]  # error-feedback buffers (grad compression) or None


def init_train_state(api: ModelAPI, key: jax.Array, tc: TrainConfig) -> TrainState:
    params = api.init(key)
    return TrainState(
        params=params,
        opt=init_opt_state(params),
        ef=init_error_feedback(params) if tc.grad_compression == "int8_ef" else None,
    )


def train_state_specs(api: ModelAPI, tc: TrainConfig) -> TrainState:
    ps = api.param_specs()
    return TrainState(
        params=ps,
        opt=opt_state_specs(ps),
        ef=ps if tc.grad_compression == "int8_ef" else None,
    )


def _split_microbatches(batch: dict, n: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatch {n}"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def build_train_step(
    api: ModelAPI,
    tc: TrainConfig,
    mesh=None,
    rules: Optional[ShardingRules] = None,
):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_kwargs = dict(
        remat=(tc.remat != "none"),
        loss_chunk=tc.loss_chunk,
    )

    def grads_of(params: Params, batch: dict):
        def loss_fn(p):
            return api.loss(p, batch, **loss_kwargs)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return grads, metrics

    def train_step(state: TrainState, batch: dict):
        ctx = mesh_context(mesh, rules or (rules_for_mesh(mesh) if mesh else None))
        with ctx:
            if tc.microbatch > 1:
                micro = _split_microbatches(batch, tc.microbatch)

                def acc_fn(g_acc, mb):
                    g, m = grads_of(state.params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g
                    )
                    return g_acc, m

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), state.params
                )
                g_sum, ms = jax.lax.scan(acc_fn, g0, micro)
                grads = jax.tree.map(lambda g: g / tc.microbatch, g_sum)
                metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
            else:
                grads, metrics = grads_of(state.params, batch)

            ef = state.ef
            if tc.grad_compression == "int8_ef":
                grads, ef = compress_grads(grads, ef)

            new_params, new_opt, opt_metrics = adamw_update(
                state.params, grads, state.opt, tc
            )
            metrics.update(opt_metrics)
        return TrainState(params=new_params, opt=new_opt, ef=ef), metrics

    return train_step

from repro.training.step import (
    TrainState,
    build_train_step,
    init_train_state,
    train_state_specs,
)

__all__ = ["TrainState", "build_train_step", "init_train_state", "train_state_specs"]

"""Pytree utilities used across the framework.

The framework is deliberately flax-free: parameters are nested dicts of
jnp arrays, and every module exposes ``init(key, cfg) -> params`` plus an
``apply(params, ...)`` function.  These helpers keep that style ergonomic.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict of arrays


def tree_size(tree: Params) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Params) -> int:
    """Total bytes across all leaves."""
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_map_with_path(fn: Callable[[tuple, Any], Any], tree: Params) -> Params:
    """jax.tree_util.tree_map_with_path with string paths."""

    def _fn(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else getattr(p, "idx", str(p)) for p in path
        )
        return fn(keys, leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def flatten_with_names(tree: Params, sep: str = "/") -> Iterator[tuple[str, Any]]:
    """Yield (dotted-name, leaf) pairs in deterministic order."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        name = sep.join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
        )
        yield name, leaf


def split_keys(key: jax.Array, names: list[str]) -> dict[str, jax.Array]:
    """Split a PRNG key into a dict keyed by ``names`` (order-stable)."""
    keys = jax.random.split(key, len(names))
    return {n: k for n, k in zip(names, keys)}


def truncated_normal_init(
    key: jax.Array, shape: tuple[int, ...], fan_in: int | None = None, dtype=jnp.float32
) -> jax.Array:
    """He-style truncated normal initialisation (std = 1/sqrt(fan_in))."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) >= 1 else 1
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def cast_floating(tree: Params, dtype) -> Params:
    """Cast floating-point leaves to ``dtype`` (non-float leaves untouched)."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)


def assert_finite(tree: Params, name: str = "tree") -> None:
    """Raise if any leaf contains NaN/Inf (host-side check for tests)."""
    for path, leaf in flatten_with_names(tree):
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating) and not np.all(np.isfinite(arr)):
            raise FloatingPointError(f"non-finite values in {name}/{path}")

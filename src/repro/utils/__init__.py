from repro.utils.tree import (
    Params,
    assert_finite,
    cast_floating,
    flatten_with_names,
    split_keys,
    tree_bytes,
    tree_size,
    truncated_normal_init,
)

__all__ = [
    "Params",
    "assert_finite",
    "cast_floating",
    "flatten_with_names",
    "split_keys",
    "tree_bytes",
    "tree_size",
    "truncated_normal_init",
]

"""Synthetic multivariate time-series for LSTM-AE anomaly detection.

Benign data: mixtures of per-feature sinusoids (random frequency/phase) +
correlated noise — the "normal behaviour" an LSTM-AE overfits.  Anomalies
inject one of three published-in-domain patterns (spike, level shift,
frequency break) into a contiguous window.  Deterministic per (seed, index)
so iterator state is just an integer (checkpointable, restart-exact).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TimeseriesConfig:
    features: int = 32
    seq_len: int = 64
    batch: int = 64
    anomaly_rate: float = 0.0   # fraction of anomalous sequences
    seed: int = 0


def _benign_batch(rng: np.random.Generator, b: int, t: int, f: int) -> np.ndarray:
    freq = rng.uniform(0.05, 0.45, size=(b, 1, f))
    phase = rng.uniform(0, 2 * np.pi, size=(b, 1, f))
    amp = rng.uniform(0.5, 1.0, size=(b, 1, f))
    steps = np.arange(t)[None, :, None]
    base = amp * np.sin(2 * np.pi * freq * steps + phase)
    noise = 0.05 * rng.standard_normal((b, t, f))
    return (base + noise).astype(np.float32)


def _inject_anomalies(rng: np.random.Generator, x: np.ndarray, mask: np.ndarray) -> np.ndarray:
    b, t, f = x.shape
    out = x.copy()
    for i in np.nonzero(mask)[0]:
        kind = rng.integers(0, 3)
        w0 = rng.integers(0, max(1, t - t // 4))
        w1 = min(t, w0 + rng.integers(max(2, t // 8), max(3, t // 3)))
        feats = rng.choice(f, size=max(1, f // 4), replace=False)
        if kind == 0:    # spike
            out[i, w0:w1, feats] += rng.uniform(2.0, 4.0)
        elif kind == 1:  # level shift
            out[i, w0:, feats] += rng.uniform(1.0, 2.0)
        else:            # frequency break -> white noise segment
            # fancy-index dim comes first: result is (len(feats), w1-w0)
            out[i, w0:w1, feats] = rng.standard_normal(
                (len(feats), int(w1 - w0))
            ).astype(np.float32)
    return out


def make_batch(cfg: TimeseriesConfig, index: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Deterministic batch #index -> (series (B,T,F), labels (B,) 1=anomaly)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, index]))
    x = _benign_batch(rng, cfg.batch, cfg.seq_len, cfg.features)
    labels = (rng.uniform(size=cfg.batch) < cfg.anomaly_rate).astype(np.int32)
    if labels.any():
        x = _inject_anomalies(rng, x, labels)
    return jnp.asarray(x), jnp.asarray(labels)


@dataclass
class TimeseriesIterator:
    """Checkpointable iterator: state == (cfg, next_index)."""
    cfg: TimeseriesConfig
    index: int = 0

    def __next__(self):
        batch = make_batch(self.cfg, self.index)
        self.index += 1
        return batch

    def __iter__(self) -> "TimeseriesIterator":
        return self

    def state_dict(self) -> dict:
        return {"index": self.index, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict):
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        self.index = int(state["index"])

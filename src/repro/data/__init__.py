from repro.data.lm import LMDataConfig, LMIterator, host_slice, make_lm_batch
from repro.data.timeseries import TimeseriesConfig, TimeseriesIterator, make_batch

__all__ = [
    "LMDataConfig",
    "LMIterator",
    "TimeseriesConfig",
    "TimeseriesIterator",
    "host_slice",
    "make_batch",
    "make_lm_batch",
]

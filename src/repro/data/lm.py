"""Synthetic LM token pipeline: Zipf-distributed tokens with induced
bigram structure (so the loss actually falls during the example runs),
deterministic per (seed, index), sharding-aware.

At scale each data-parallel host reads its own slice: ``host_slice``
partitions the global batch by (process_index, process_count); on this
single-process container that is the identity, but the launcher calls it
unconditionally so the multi-host path is exercised structurally.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class LMDataConfig:
    vocab_size: int = 512
    seq_len: int = 128
    global_batch: int = 16
    seed: int = 0


def make_lm_batch(cfg: LMDataConfig, index: int) -> dict[str, jnp.ndarray]:
    """Batch #index -> {"tokens": (B,S), "labels": (B,S)} (labels = next token)."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, index]))
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # zipf-ish marginal + deterministic "grammar": token_{t+1} is a fixed
    # permutation of token_t half the time (learnable bigram signal)
    ranks = np.arange(1, v + 1)
    probs = 1.0 / ranks**1.1
    probs /= probs.sum()
    perm = np.random.default_rng(cfg.seed).permutation(v)
    toks = np.empty((b, s + 1), np.int64)
    toks[:, 0] = rng.choice(v, size=b, p=probs)
    for t in range(1, s + 1):
        follow = perm[toks[:, t - 1]]
        fresh = rng.choice(v, size=b, p=probs)
        use_gram = rng.uniform(size=b) < 0.5
        toks[:, t] = np.where(use_gram, follow, fresh)
    return {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }


def host_slice(batch: dict, process_index: int | None = None,
               process_count: int | None = None) -> dict:
    """Per-host slice of the global batch (multi-host data loading)."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    def sl(x):
        b = x.shape[0]
        assert b % pc == 0
        shard = b // pc
        return x[pi * shard : (pi + 1) * shard]
    return jax.tree.map(sl, batch)


@dataclass
class LMIterator:
    cfg: LMDataConfig
    index: int = 0

    def __next__(self):
        batch = make_lm_batch(self.cfg, self.index)
        self.index += 1
        return batch

    def __iter__(self) -> "LMIterator":
        return self

    def state_dict(self) -> dict:
        return {"index": self.index, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict):
        assert state["seed"] == self.cfg.seed
        self.index = int(state["index"])

"""repro: temporal-parallel dataflow execution for recurrent autoencoders.

JAX/TPU reproduction + extension of "Exploiting temporal parallelism for
LSTM Autoencoder acceleration on FPGA" — see DESIGN.md.
"""

__version__ = "0.1.0"

"""CLI for the static-analysis gate: ``python -m repro.analysis``.

Mirrors ``benchmarks/check.py``'s conventions — exit 0 when the tree is
clean against the committed baseline, exit 1 on any non-baselined
finding, and an ``--update-baseline`` flag that admits the current
finding set instead of comparing (commit the result with reasons; the
loader rejects entries whose reason is missing, and fresh entries carry
an explicit "unreviewed" placeholder so nothing is suppressed silently).

Usage::

    PYTHONPATH=src python -m repro.analysis                 # gate the repo
    PYTHONPATH=src python -m repro.analysis --format json   # machine output
    PYTHONPATH=src python -m repro.analysis path/to/file.py # explicit files
                                                            # (all rules run,
                                                            # no targeting)
    PYTHONPATH=src python -m repro.analysis --update-baseline

Stale baseline entries (their finding no longer occurs — it was fixed)
are reported as NOTEs and do not fail the run; prune them with
``--update-baseline``.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.engine import AnalysisEngine, Baseline, default_rules


def _default_root() -> Path:
    """The repo root: nearest ancestor of this file carrying ROADMAP.md
    (falls back to CWD for out-of-tree installs)."""
    here = Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "ROADMAP.md").exists() and (parent / "src").is_dir():
            return parent
    return Path.cwd()


def _report_payload(new, suppressed, stale) -> dict:
    return {
        "ok": not new,
        "findings": [f.to_dict() for f in new],
        "suppressed": [
            {**f.to_dict(), "reason": e["reason"]}
            for f, e in suppressed
        ],
        "stale_baseline_entries": stale,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-aware static analysis: JAX trace-safety, "
                    "concurrency-hazard and contract lints with a "
                    "committed-baseline gate",
    )
    ap.add_argument("paths", nargs="*", metavar="FILE",
                    help="explicit files to analyse (every rule runs, "
                         "targeting globs are bypassed); default: the "
                         "targeted src/repro walk")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="baseline file (default: <root>/analysis/"
                         "baseline.json); pass an empty string to gate "
                         "with no baseline at all")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the current finding set to the baseline "
                         "instead of comparing (keeps reviewed reasons, "
                         "prunes fixed entries, marks new ones "
                         "'unreviewed' for you to justify before "
                         "committing)")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="stdout format (default text)")
    ap.add_argument("--report", default=None, metavar="FILE",
                    help="also write the JSON findings report here "
                         "(CI uploads this as an artifact)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print every registered rule id and exit")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve() if args.root else _default_root()
    engine = AnalysisEngine(root)

    if args.list_rules:
        file_rules, repo_rules = default_rules()
        for rule in sorted(file_rules + repo_rules, key=lambda r: r.id):
            scope = ", ".join(getattr(rule, "targets", ())) or "repo-wide"
            print(f"{rule.id}  {rule.title}  [{scope}]")
        return 0

    findings = engine.run(args.paths or None)

    baseline_path = (Path(args.baseline) if args.baseline
                     else root / "analysis" / "baseline.json"
                     if args.baseline is None else None)
    baseline = Baseline.load(baseline_path) if baseline_path else Baseline()

    if args.update_baseline:
        if baseline_path is None:
            ap.error("--update-baseline needs a baseline path")
        baseline.update(findings)
        baseline.save(baseline_path)
        unreviewed = sum(1 for e in baseline.entries.values()
                         if e["reason"].startswith("unreviewed"))
        print(f"baseline written: {baseline_path} "
              f"({len(baseline.entries)} entries, {unreviewed} awaiting a "
              f"review reason)")
        return 0

    new, suppressed_findings, stale = baseline.split(findings)
    suppressed = [(f, baseline.entries[f.fingerprint])
                  for f in suppressed_findings]
    payload = _report_payload(new, suppressed, stale)

    if args.report:
        report_path = Path(args.report)
        report_path.parent.mkdir(parents=True, exist_ok=True)
        report_path.write_text(json.dumps(payload, indent=2) + "\n")

    if args.format == "json":
        print(json.dumps(payload, indent=2))
    else:
        for f in new:
            print(f"FAIL  {f.render()}")
            if f.snippet:
                print(f"      > {f.snippet}")
        for f, entry in suppressed:
            print(f"OK    {f.render()} [baselined: {entry['reason']}]")
        for entry in stale:
            print(f"NOTE  stale baseline entry {entry['fingerprint']} "
                  f"({entry['rule']} {entry['path']}): finding no longer "
                  f"occurs — prune with --update-baseline")
        print(f"\nanalysis: {len(new)} new finding(s), "
              f"{len(suppressed)} baselined, {len(stale)} stale "
              f"baseline entr{'y' if len(stale) == 1 else 'ies'}")
        if new:
            print("new findings fail the gate — fix them, or add a "
                  "reasoned baseline entry (--update-baseline, then "
                  "replace the 'unreviewed' placeholder)")
        else:
            print("analysis gate: OK")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())

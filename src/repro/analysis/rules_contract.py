"""Cross-module contract rules (CON3xx).

The wire protocol and the telemetry pipeline are contracts with no
shared schema object — the client, the server and the Prometheus
renderer each hard-code their half as string keys.  Nothing fails at
import time when the halves drift; a consumed-but-never-produced field
just reads ``None`` forever and a stats key the renderer doesn't know
silently vanishes from every scrape.  These rules diff the halves:

  CON301  response field read by a ``client.py`` but never produced by
          any ``server.py``/``workers.py`` — the read is dead (always
          missing), usually a renamed or deleted field
  CON302  request field sent by a ``client.py`` but never read by any
          ``server.py``/``workers.py`` — dead bytes on every request
  CON303  top-level scalar ``stats()`` key emitted by a gateway but
          absent from the Prometheus renderer's vocabulary — counters /
          gauges / histograms render generically, so the exposed
          contract surface is exactly the scalar top-level keys
          (``_SCALAR_GAUGES`` plus the section names)
  CON304  bare ``except:`` or an except whose whole body is ``pass`` on
          a serving path — failures vanish without even a debug line
          (per-file rule; the only non-cross-file one in this pack)

Role detection is by basename (``client.py``/``*_client.py`` consume,
``server.py``/``workers.py`` produce, ``prometheus.py`` renders), so
fixture trees exercise the rules without living under ``src/repro``.
A rule that is missing one side of its contract in the analysed file
set reports nothing — a lone fixture file never misfires.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.engine import (
    FileContext, Finding, RepoContext, RepoRule, Rule, call_name, const_str,
    dotted_name,
)

_SERVING_TARGETS = (
    "src/repro/gateway/**",
    "src/repro/obs/**",
    "src/repro/control/**",
)

# framing fields both sides handle generically — never part of a diff
_FRAMING = {"op", "id"}

# local variable names that (by repo convention) hold a wire response /
# request on the consuming side
_RESPONSE_VARS = {"resp", "response", "reply", "out"}
_REQUEST_VARS = {"req", "request", "msg"}
# calls whose result is a wire response (client.request(...)["score"])
_RESPONSE_CALLS = {"request", "collect", "step"}


def _read_key(node: ast.AST, varnames: set,
              calls: Optional[set] = None) -> Optional[str]:
    """The constant field name if ``node`` reads a key off a wire dict:
    ``resp["k"]`` / ``resp.get("k", ...)`` / ``"k" in req``."""
    if isinstance(node, ast.Subscript) and isinstance(node.ctx, ast.Load):
        if _is_wire_value(node.value, varnames, calls):
            return const_str(node.slice)
    elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        if node.func.attr == "get" and node.args and \
                _is_wire_value(node.func.value, varnames, calls):
            return const_str(node.args[0])
    elif isinstance(node, ast.Compare) and len(node.ops) == 1 and \
            isinstance(node.ops[0], (ast.In, ast.NotIn)):
        if _is_wire_value(node.comparators[0], varnames, calls):
            return const_str(node.left)
    return None


def _is_wire_value(node: ast.AST, varnames: set,
                   calls: Optional[set]) -> bool:
    if isinstance(node, ast.Name) and node.id in varnames:
        return True
    if calls and isinstance(node, ast.Call):
        name = call_name(node)
        return name.rsplit(".", 1)[-1] in calls
    return False


# -- consumer side (client.py) ---------------------------------------------


def _client_response_reads(ctx: FileContext) -> list:
    """``(field, node)`` for every response-field read in a client."""
    out = []
    for node in ast.walk(ctx.tree):
        key = _read_key(node, _RESPONSE_VARS, _RESPONSE_CALLS)
        if key is not None:
            out.append((key, node))
    return out


def _client_request_fields(ctx: FileContext) -> list:
    """``(field, node)`` for every request field a client sends: keys of
    dict literals that carry an ``"op"`` key, plus keyword names on the
    generic ``request(op, **fields)`` helper."""
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Dict):
            keys = [const_str(k) for k in node.keys if k is not None]
            if "op" in keys:
                out.extend((k, node) for k in keys if k)
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("request",):
            out.extend((kw.arg, node) for kw in node.keywords
                       if kw.arg is not None)
    return out


# -- producer side (server.py / workers.py) --------------------------------


def _producer_response_fields(ctx: FileContext) -> set:
    """Every field a producer can put on the wire: dict-literal keys plus
    constant-key subscript assigns (``payload["alert"] = ...``)."""
    fields: set = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Dict):
            fields.update(k for k in (const_str(key) for key in node.keys
                                      if key is not None) if k)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Subscript):
                    k = const_str(t.slice)
                    if k:
                        fields.add(k)
    return fields


def _producer_request_reads(ctx: FileContext) -> set:
    fields: set = set()
    for node in ast.walk(ctx.tree):
        key = _read_key(node, _REQUEST_VARS)
        if key is not None:
            fields.add(key)
    return fields


def _wire_roles(repo: RepoContext):
    consumers = repo.by_basename("client.py")
    producers = repo.by_basename("server.py", "workers.py")
    # one-sided file set (a lone fixture): nothing to diff against
    if not consumers or not producers:
        return [], []
    return consumers, producers


def check_wire_responses(repo: RepoContext) -> Iterable[Finding]:
    consumers, producers = _wire_roles(repo)
    produced: set = set()
    for p in producers:
        produced |= _producer_response_fields(p)
    for c in consumers:
        for field, node in _client_response_reads(c):
            if field not in produced and field not in _FRAMING:
                yield c.finding(
                    "CON301", node,
                    f"response field {field!r} is read here but no "
                    f"producer ({', '.join(p.path for p in producers)}) "
                    f"ever puts it on the wire — this read is always "
                    f"missing (renamed or deleted field?)",
                )


def check_wire_requests(repo: RepoContext) -> Iterable[Finding]:
    consumers, producers = _wire_roles(repo)
    consumed: set = set()
    for p in producers:
        consumed |= _producer_request_reads(p)
    for c in consumers:
        for field, node in _client_request_fields(c):
            if field not in consumed and field not in _FRAMING:
                yield c.finding(
                    "CON302", node,
                    f"request field {field!r} is sent here but no "
                    f"producer ({', '.join(p.path for p in producers)}) "
                    f"ever reads it — dead bytes on every request",
                )


# -- telemetry rendering contract ------------------------------------------


_SCALARISH_CALLS = {"int", "float", "len", "sum", "round", "min", "max",
                    "bool", "abs"}


def _scalarish(value: ast.AST) -> bool:
    """Statically plausible scalar: the shapes ``stats()`` methods use
    for gauge-able values.  Container literals/comprehensions are nested
    sections (rendered by their own handlers) and bare Names are opaque
    — neither is flagged."""
    if isinstance(value, ast.Constant):
        return isinstance(value.value, (int, float)) and \
            not isinstance(value.value, bool)
    if isinstance(value, ast.Attribute):
        return True
    if isinstance(value, (ast.BinOp, ast.UnaryOp, ast.IfExp)):
        return True
    if isinstance(value, ast.Call):
        return call_name(value).rsplit(".", 1)[-1] in _SCALARISH_CALLS
    return False


def _stats_emissions(ctx: FileContext) -> list:
    """``(key, value, node)`` for every top-level key a ``stats()``
    method emits: return-dict literals, ``out.update(k=v)`` keywords and
    ``out["k"] = v`` assigns."""
    out = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) or \
                fn.name != "stats":
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Return) and \
                    isinstance(node.value, ast.Dict):
                for key, value in zip(node.value.keys, node.value.values):
                    k = const_str(key) if key is not None else None
                    if k:
                        out.append((k, value, node.value))
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "update":
                for kw in node.keywords:
                    if kw.arg is not None:
                        out.append((kw.arg, kw.value, node))
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Subscript):
                k = const_str(node.targets[0].slice)
                if k:
                    out.append((k, node.value, node))
    return out


def _renderer_vocabulary(ctx: FileContext) -> set:
    """Every string constant in the renderer module — a superset of the
    keys it can render (``_SCALAR_GAUGES`` entries, section names,
    label names).  A key absent from this set cannot be rendered."""
    return {node.value for node in ast.walk(ctx.tree)
            if isinstance(node, ast.Constant)
            and isinstance(node.value, str)}


def check_telemetry_contract(repo: RepoContext) -> Iterable[Finding]:
    renderers = repo.by_basename("prometheus.py")
    if not renderers:
        return
    vocab: set = set()
    for r in renderers:
        vocab |= _renderer_vocabulary(r)
    rendered_in = ", ".join(r.path for r in renderers)
    for ctx in repo.files:
        if ctx in renderers:
            continue
        for key, value, node in _stats_emissions(ctx):
            if key not in vocab and _scalarish(value):
                yield ctx.finding(
                    "CON303", node,
                    f"stats key {key!r} emitted here is never rendered "
                    f"by the Prometheus exposition ({rendered_in}): "
                    f"scalar top-level keys only render when listed in "
                    f"_SCALAR_GAUGES, so every scrape silently drops it",
                )


# -- swallowed exceptions (per-file) ---------------------------------------


def _pass_only(body: list) -> bool:
    return all(isinstance(s, ast.Pass)
               or (isinstance(s, ast.Expr)
                   and isinstance(s.value, ast.Constant))
               for s in body)


def check_swallowed_except(ctx: FileContext) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            yield ctx.finding(
                "CON304", node,
                "bare `except:` on a serving path also traps "
                "KeyboardInterrupt/SystemExit and hides the failure — "
                "catch a concrete exception type and at least "
                "debug-log it",
            )
        elif _pass_only(node.body):
            if isinstance(node.type, ast.Tuple):
                typ = "(" + ", ".join(
                    dotted_name(e) or "?" for e in node.type.elts) + ")"
            else:
                typ = dotted_name(node.type) or "Exception"
            yield ctx.finding(
                "CON304", node,
                f"`except {typ}: pass` swallows the failure with no "
                f"trace at all — log at debug level (or narrow the "
                f"type) so field incidents stay diagnosable",
            )


FILE_RULES = [
    Rule("CON304", "bare/swallowed except on a serving path",
         check_swallowed_except, _SERVING_TARGETS),
]

REPO_RULES = [
    RepoRule("CON301", "response field consumed but never produced",
             check_wire_responses),
    RepoRule("CON302", "request field sent but never consumed",
             check_wire_requests),
    RepoRule("CON303", "stats key emitted but never rendered",
             check_telemetry_contract),
]

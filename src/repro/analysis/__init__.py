"""Repo-aware static analysis: JAX trace-safety, concurrency-hazard and
wire/telemetry-contract lints with a committed-baseline gate.

Run as ``python -m repro.analysis`` (see ``__main__.py``); the engine
and rule packs are importable for the fixture tests::

    from repro.analysis import AnalysisEngine, Baseline, default_rules
"""
from repro.analysis.engine import (
    AnalysisEngine, Baseline, FileContext, Finding, RepoContext, RepoRule,
    Rule, default_rules,
)

__all__ = [
    "AnalysisEngine", "Baseline", "FileContext", "Finding", "RepoContext",
    "RepoRule", "Rule", "default_rules",
]

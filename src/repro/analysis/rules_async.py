"""Concurrency-hazard rules (ASY2xx) for the serving tier.

The gateway's concurrency contract is narrow and documented — one event
loop owns the gateway, supervisor threads own the control plane, worker
processes are spawned (never forked, JAX state does not survive a fork).
Each rule here flags a way that contract silently erodes:

  ASY201  blocking call (time.sleep / subprocess / sync socket / sync
          file I/O / Future.result) inside an ``async def`` — stalls
          every connection on the loop, not just the caller
  ASY202  a sync lock held across an ``await`` — the loop suspends with
          the lock held; any thread then contending deadlocks the loop
  ASY203  ``create_task``/``ensure_future`` result dropped — asyncio
          keeps only weak refs to tasks, a GC can cancel it mid-flight
          (and its exception is swallowed either way)
  ASY204  a dict attribute shared with a spawned thread mutated outside
          any lock — dict ops are GIL-atomic individually, but
          check-then-act sequences interleave
  ASY205  fork-method multiprocessing in a module that imports JAX —
          forked XLA runtime state hangs or corrupts silently

Scope: ``gateway/`` and ``obs/`` (the modules that own threads, loops
and processes).
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.engine import (
    FileContext, Finding, Rule, call_name, const_str, dotted_name,
)

_TARGETS = (
    "src/repro/gateway/**",
    "src/repro/obs/**",
    "src/repro/control/**",
)

# dotted call names that block the calling thread
_BLOCKING_CALLS = {
    "time.sleep",
    "subprocess.run", "subprocess.call", "subprocess.check_call",
    "subprocess.check_output", "subprocess.getoutput",
    "socket.create_connection", "socket.getaddrinfo",
    "requests.get", "requests.post", "requests.put", "requests.request",
    "urllib.request.urlopen",
    "os.waitpid", "os.wait",
}
# method names that block when called on obvious blocking carriers
_BLOCKING_METHODS = {
    # concurrent.futures / multiprocessing results and joins
    "result", "join",
    # sync socket/file surface
    "recv", "accept", "sendall", "makefile",
}
_BLOCKING_METHOD_HINTS = ("sock", "socket", "proc", "process", "thread",
                          "future", "fut", "conn")


def _lockish(expr: ast.AST) -> bool:
    name = dotted_name(expr)
    last = name.rsplit(".", 1)[-1].lower()
    return "lock" in last or "sem" in last or last in ("mutex",)


def _iter_async_defs(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, ast.AsyncFunctionDef):
            yield node


def _walk_same_async(fn: ast.AsyncFunctionDef):
    """Walk an async def's body without descending into nested *sync*
    defs (their bodies run on whatever thread calls them, not the
    loop) — nested async defs stay in scope."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.Lambda, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def check_blocking_in_async(ctx: FileContext) -> Iterable[Finding]:
    for fn in _iter_async_defs(ctx.tree):
        for node in _walk_same_async(fn):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name in _BLOCKING_CALLS or name == "open":
                label = ("sync file I/O `open(...)`" if name == "open"
                         else f"`{name}`")
                yield ctx.finding(
                    "ASY201", node,
                    f"{label} inside `async def {fn.name}`: blocks the "
                    f"event loop (every connection stalls, the pump "
                    f"stops flushing) — use the asyncio equivalent or "
                    f"run_in_executor",
                )
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _BLOCKING_METHODS:
                base = dotted_name(node.func.value)
                last = base.rsplit(".", 1)[-1].lower()
                if any(h in last for h in _BLOCKING_METHOD_HINTS):
                    yield ctx.finding(
                        "ASY201", node,
                        f"`{base}.{node.func.attr}(...)` looks like a "
                        f"blocking call inside `async def {fn.name}` — "
                        f"await the async form or move it off the loop",
                    )


def check_lock_across_await(ctx: FileContext) -> Iterable[Finding]:
    for fn in _iter_async_defs(ctx.tree):
        for node in _walk_same_async(fn):
            if not isinstance(node, ast.With):  # sync `with` only: an
                continue                        # async with lock is fine
            if not any(_lockish(item.context_expr)
                       or (isinstance(item.context_expr, ast.Call)
                           and _lockish(item.context_expr.func))
                       for item in node.items):
                continue
            for sub in ast.walk(node):
                if isinstance(sub, ast.Await):
                    yield ctx.finding(
                        "ASY202", node,
                        f"sync lock held across `await` in `async def "
                        f"{fn.name}`: the loop suspends while holding "
                        f"it; a thread contending on the same lock "
                        f"deadlocks the loop — release before awaiting "
                        f"or use asyncio.Lock",
                    )
                    break


_TASK_SPAWNERS = ("create_task", "ensure_future")


def check_dropped_task(ctx: FileContext) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Expr):
            continue
        call = node.value
        if isinstance(call, ast.Call) and \
                isinstance(call.func, ast.Attribute) and \
                call.func.attr in _TASK_SPAWNERS:
            shown = call_name(call) or f"...{call.func.attr}"
            yield ctx.finding(
                "ASY203", node,
                f"`{shown}(...)` result dropped: the event "
                f"loop keeps only a weak reference to tasks, so GC can "
                f"cancel this one mid-flight and its exception is never "
                f"observed — keep a reference (add to a set, discard in "
                f"a done callback)",
            )


class _ClassThreads(ast.NodeVisitor):
    """Per-class facts for ASY204: dict-typed attrs, lock attrs, thread
    entry points, and self-method call edges."""

    def __init__(self, cls: ast.ClassDef):
        self.cls = cls
        self.dict_attrs: set = set()
        self.lock_attrs: set = set()
        self.thread_targets: set = set()
        self.methods: dict = {
            n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        for m in self.methods.values():
            self._scan(m)

    def _scan(self, method: ast.AST) -> None:
        for node in ast.walk(method):
            target = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                target = node.targets[0]
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                target = node.target  # self._workers: dict[...] = {}
            if target is not None and self._self_attr(target):
                attr = target.attr
                v = node.value
                if isinstance(v, (ast.Dict, ast.DictComp)) or (
                        isinstance(v, ast.Call)
                        and call_name(v) in ("dict", "defaultdict",
                                             "collections.defaultdict",
                                             "OrderedDict",
                                             "collections.OrderedDict")):
                    self.dict_attrs.add(attr)
                elif isinstance(v, ast.Call) and _lock_ctor(call_name(v)):
                    self.lock_attrs.add(attr)
            elif isinstance(node, ast.Call):
                name = call_name(node)
                if name.rsplit(".", 1)[-1] in ("Thread",) or \
                        (isinstance(node.func, ast.Attribute)
                         and node.func.attr == "submit"):
                    for kw in node.keywords:
                        if kw.arg == "target" and self._self_attr(kw.value):
                            self.thread_targets.add(kw.value.attr)
                    for a in node.args:
                        if self._self_attr(a):
                            self.thread_targets.add(a.attr)

    @staticmethod
    def _self_attr(node: ast.AST) -> bool:
        return (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self")

    def reachable_from_threads(self) -> set:
        """Thread entry methods plus self-methods they call (one fixed
        point, intra-class)."""
        seen = set(t for t in self.thread_targets if t in self.methods)
        frontier = list(seen)
        while frontier:
            m = self.methods.get(frontier.pop())
            if m is None:
                continue
            for node in ast.walk(m):
                if isinstance(node, ast.Call) and \
                        self._self_attr(node.func) and \
                        node.func.attr in self.methods and \
                        node.func.attr not in seen:
                    seen.add(node.func.attr)
                    frontier.append(node.func.attr)
        return seen


def _lock_ctor(name: str) -> bool:
    last = name.rsplit(".", 1)[-1]
    return last in ("Lock", "RLock", "Condition", "Semaphore",
                    "BoundedSemaphore")


_DICT_MUTATORS = {"pop", "update", "setdefault", "clear", "popitem"}


def check_unlocked_shared_dict(ctx: FileContext) -> Iterable[Finding]:
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        facts = _ClassThreads(cls)
        if not facts.thread_targets or not facts.dict_attrs:
            continue
        threaded = facts.reachable_from_threads()
        for mname in sorted(threaded):
            method = facts.methods[mname]
            for node in ast.walk(method):
                attr = _dict_mutation(node, facts.dict_attrs)
                if attr is None:
                    continue
                if _under_lock(method, node):
                    continue
                yield ctx.finding(
                    "ASY204", node,
                    f"`self.{attr}` (a dict shared with spawned "
                    f"threads) mutated in `{cls.name}.{mname}` outside "
                    f"any lock: individual dict ops are GIL-atomic but "
                    f"check-then-act sequences interleave across "
                    f"threads — hold the class lock around the mutation",
                )


def _dict_mutation(node: ast.AST, dict_attrs: set) -> Optional[str]:
    """The mutated attr name if ``node`` mutates ``self.<dict_attr>``."""
    if isinstance(node, ast.Assign):
        for t in node.targets:
            if isinstance(t, ast.Subscript) and \
                    _ClassThreads._self_attr(t.value) and \
                    t.value.attr in dict_attrs:
                return t.value.attr
    elif isinstance(node, ast.Delete):
        for t in node.targets:
            if isinstance(t, ast.Subscript) and \
                    _ClassThreads._self_attr(t.value) and \
                    t.value.attr in dict_attrs:
                return t.value.attr
    elif isinstance(node, ast.Call) and \
            isinstance(node.func, ast.Attribute) and \
            node.func.attr in _DICT_MUTATORS and \
            _ClassThreads._self_attr(node.func.value) and \
            node.func.value.attr in dict_attrs:
        return node.func.value.attr
    return None


def _under_lock(method: ast.AST, target: ast.AST) -> bool:
    """Is ``target`` lexically inside a ``with <lock>:`` in ``method``?"""
    for node in ast.walk(method):
        if isinstance(node, ast.With) and any(
                _lockish(item.context_expr)
                or (isinstance(item.context_expr, ast.Call)
                    and _lockish(item.context_expr.func))
                for item in node.items):
            for sub in ast.walk(node):
                if sub is target:
                    return True
    return False


def _imports_jax(tree: ast.AST) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name == "jax" or a.name.startswith("jax.")
                   for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "jax" or mod.startswith("jax."):
                return True
    return False


def check_fork_multiprocessing(ctx: FileContext) -> Iterable[Finding]:
    uses_jax = _imports_jax(ctx.tree)
    # contexts known to be spawn: X = mp.get_context("spawn") makes
    # X.Process safe; track those names
    spawn_ctxs: set = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and call_name(node.value).endswith("get_context"):
            args = node.value.args
            method = const_str(args[0]) if args else None
            for t in node.targets:
                names = [n for n in ast.walk(t) if isinstance(n, ast.Name)]
                attrs = [n.attr for n in ast.walk(t)
                         if isinstance(n, ast.Attribute)]
                if method in (None, "fork", "forkserver"):
                    continue
                spawn_ctxs.update(n.id for n in names)
                spawn_ctxs.update(attrs)
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        last = name.rsplit(".", 1)[-1]
        if last in ("get_context", "set_start_method"):
            method = const_str(node.args[0]) if node.args else None
            if method in ("fork", "forkserver") and uses_jax:
                yield ctx.finding(
                    "ASY205", node,
                    f"`{name}({method!r})` in a JAX-importing module: "
                    f"forked XLA runtime state deadlocks or corrupts "
                    f"silently — use the spawn start method",
                )
        elif last == "Process" and uses_jax:
            base = name.rsplit(".", 1)[0] if "." in name else ""
            base_last = base.rsplit(".", 1)[-1]
            if base_last in ("multiprocessing", "mp") or base == "":
                yield ctx.finding(
                    "ASY205", node,
                    f"`{name}(...)` uses the ambient start method "
                    f"(fork, on Linux) in a JAX-importing module — "
                    f"build processes from mp.get_context('spawn')",
                )


FILE_RULES = [
    Rule("ASY201", "blocking call inside async def",
         check_blocking_in_async, _TARGETS),
    Rule("ASY202", "sync lock held across await",
         check_lock_across_await, _TARGETS),
    Rule("ASY203", "create_task result dropped",
         check_dropped_task, _TARGETS),
    Rule("ASY204", "thread-shared dict mutated without a lock",
         check_unlocked_shared_dict, _TARGETS),
    Rule("ASY205", "fork-method multiprocessing with JAX",
         check_fork_multiprocessing, _TARGETS),
]

"""JAX trace-safety rules (JAX1xx).

The hazards this pack catches compile fine and pass a green test run:
a Python ``if`` on a tracer raises only on the shapes that reach it, a
``print`` inside a jitted body fires once at trace time and never
again, ``np.`` on a tracer silently falls back to host transfers, an
unhashable static arg or an f-string/``id()`` cache key recompiles per
call.  The PR 1 ``core/temporal.py`` shard_map miscompile hid behind
exactly this opacity — the program *ran*, it just didn't run the code
everyone read.

Scope: functions *reachable from a jit/shard_map/pallas_call wrap
site within the same file* — decorated functions, functions passed to
``jax.jit(...)`` / ``shard_map(...)`` / ``pallas_call(...)``, their
nested ``def``s, and local functions they call (fixed point).  Data
params are the wrapped function's params minus its declared
``static_argnames``/``static_argnums``; a light forward taint pass
follows assignments so derived values count too.

Rules::

  JAX101  Python branch (`if`/`while`/`assert`) on a traced value
  JAX102  Python side effect inside a traced body (print / global /
          mutation of closure or module state)
  JAX103  np.* called on a traced value (host round-trip per call)
  JAX104  static arg with an unhashable (list/dict/set) default
  JAX105  f-string or id() used as a cache key (silent recompiles:
          id() is reused after GC, f-strings hash by text, dicts by
          insertion order)
  JAX106  host callback (jax.debug.print / io_callback / pure_callback)
          inside a traced hot path
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.engine import (
    FileContext, Finding, Rule, call_name, const_str, dotted_name,
)

_TARGETS = (
    "src/repro/engine/**",
    "src/repro/kernels/**",
    "src/repro/core/**",
)

_JIT_WRAPPERS = {
    "jax.jit", "jit", "jax.pjit", "pjit",
}
_TRACE_WRAPPERS = _JIT_WRAPPERS | {
    "shard_map", "jax.experimental.shard_map.shard_map",
    "pallas_call", "pl.pallas_call", "jax.experimental.pallas.pallas_call",
    "jax.vmap", "vmap", "jax.grad", "grad", "jax.value_and_grad",
    "jax.lax.scan", "lax.scan", "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.while_loop", "lax.while_loop",
}

# attribute reads that are static under tracing (shape metadata, config)
_SAFE_ATTRS = {
    "shape", "ndim", "dtype", "size", "sharding", "aval", "weak_type",
}
_SAFE_CALLS = {
    "len", "isinstance", "hasattr", "getattr", "type", "issubclass",
    "callable", "repr", "str",
}
_TRACED_PRODUCERS = ("jnp.", "jax.numpy.", "jax.lax.", "lax.", "jax.nn.",
                     "jnn.")

_HOST_CALLBACKS = {
    "jax.debug.print", "jax.debug.callback", "jax.debug.breakpoint",
    "jax.experimental.io_callback", "io_callback",
    "jax.pure_callback", "pure_callback",
    "jax.experimental.host_callback.call", "host_callback.call",
    "jax.experimental.host_callback.id_tap", "host_callback.id_tap",
}

_MUTATORS = {"append", "extend", "insert", "add", "update", "setdefault",
             "pop", "popitem", "clear", "remove", "discard"}

# annotations that mark a param as trace-time Python config, never a tracer
_STATIC_ANNOTATIONS = {"bool", "str", "bytes", "int"}


# ---------------------------------------------------------------------------
# traced-function discovery
# ---------------------------------------------------------------------------


def _static_names_from_call(call: ast.Call,
                            fn: Optional[ast.FunctionDef]) -> set:
    """Param names declared static at a wrap site."""
    static: set = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                s = const_str(n)
                if s:
                    static.add(s)
        elif kw.arg == "static_argnums" and fn is not None:
            params = [a.arg for a in fn.args.args]
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    if 0 <= n.value < len(params):
                        static.add(params[n.value])
    return static


class _TracedSet:
    """Functions reachable from a trace-wrap site, with their data params."""

    def __init__(self, tree: ast.AST):
        # every def in the file, by name (best effort on shadowing: last
        # definition wins, which matches runtime for module-level defs)
        self.defs: dict[str, ast.AST] = {}
        self.parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs[node.name] = node
        # name -> static param names (from wrap sites / decorators)
        self.static: dict[str, set] = {}
        roots: set = set()

        def mark(name: Optional[str], static: set) -> None:
            if name and name in self.defs:
                roots.add(name)
                self.static.setdefault(name, set()).update(static)

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    dn = dotted_name(dec)
                    if dn in _TRACE_WRAPPERS:
                        mark(node.name, set())
                    elif isinstance(dec, ast.Call):
                        cn = call_name(dec)
                        if cn in _TRACE_WRAPPERS:
                            mark(node.name,
                                 _static_names_from_call(dec, node))
                        elif cn in ("partial", "functools.partial") and \
                                dec.args and \
                                dotted_name(dec.args[0]) in _TRACE_WRAPPERS:
                            mark(node.name,
                                 _static_names_from_call(dec, node))
            elif isinstance(node, ast.Call):
                if call_name(node) in _TRACE_WRAPPERS and node.args:
                    target = node.args[0]
                    if isinstance(target, ast.Name):
                        fn = self.defs.get(target.id)
                        mark(target.id, _static_names_from_call(
                            node, fn if isinstance(
                                fn, ast.FunctionDef) else None))
        # fixed point: local functions *called from* a traced function are
        # traced too (their bodies inline into the trace)
        self.traced: set = set(roots)
        changed = True
        while changed:
            changed = False
            for name in list(self.traced):
                fn = self.defs[name]
                for sub in ast.walk(fn):
                    if isinstance(sub, ast.Call) and \
                            isinstance(sub.func, ast.Name) and \
                            sub.func.id in self.defs and \
                            sub.func.id not in self.traced:
                        self.traced.add(sub.func.id)
                        changed = True
        # nested defs inside a traced function are traced (closures the
        # trace runs); record them as AST nodes rather than names
        self.traced_nodes: list = []
        for name in self.traced:
            fn = self.defs[name]
            self.traced_nodes.append(fn)

    def data_params(self, fn: ast.AST) -> set:
        static = self.static.get(getattr(fn, "name", ""), set())
        args = fn.args
        params = (list(args.posonlyargs) + list(args.args)
                  + list(args.kwonlyargs))
        # a param annotated with a plain-Python static type is trace-time
        # config, not a tracer (e.g. `def _acts(pwl: bool)`)
        names = [a.arg for a in params
                 if a.annotation is None
                 or dotted_name(a.annotation) not in _STATIC_ANNOTATIONS]
        if args.vararg:
            names.append(args.vararg.arg)
        return {n for n in names if n not in static and n != "self"}


# ---------------------------------------------------------------------------
# taint within one traced function
# ---------------------------------------------------------------------------


def _taint(fn: ast.AST, seeds: set) -> set:
    """Names carrying traced values: the data params plus anything
    assigned from a tainted expression or a jnp/lax producer call.  Two
    passes are enough for the straight-line bodies this repo writes."""
    tainted = set(seeds)
    for _ in range(2):
        for node in ast.walk(fn):
            value = None
            targets: list = []
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AugAssign):
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                value, targets = node.value, [node.target]
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                value, targets = node.iter, [node.target]
            if value is None or not _expr_traced(value, tainted):
                continue
            for t in targets:
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        tainted.add(n.id)
    return tainted


def _expr_traced(expr: ast.AST, tainted: set) -> bool:
    """Does ``expr`` (likely) evaluate to a traced value?"""
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    if isinstance(expr, ast.Constant):
        return False
    if isinstance(expr, ast.Attribute):
        if expr.attr in _SAFE_ATTRS:
            return False
        return _expr_traced(expr.value, tainted)
    if isinstance(expr, ast.Call):
        name = call_name(expr)
        if name in _SAFE_CALLS:
            return False
        if any(name.startswith(p) for p in _TRACED_PRODUCERS):
            return True
        return any(_expr_traced(a, tainted) for a in expr.args) or any(
            _expr_traced(kw.value, tainted) for kw in expr.keywords)
    if isinstance(expr, ast.Compare):
        comparators = [expr.left] + list(expr.comparators)
        if all(isinstance(c, ast.Constant) and c.value is None
               for c in comparators[1:]):
            return False  # `x is None` is a static (weak-type) check
        return any(_expr_traced(c, tainted) for c in comparators)
    if isinstance(expr, (ast.BoolOp, ast.BinOp, ast.UnaryOp, ast.IfExp,
                         ast.Subscript, ast.Tuple, ast.List, ast.Starred)):
        return any(_expr_traced(c, tainted)
                   for c in ast.iter_child_nodes(expr)
                   if isinstance(c, ast.expr))
    return False


def _local_bindings(fn: ast.AST) -> set:
    """Names bound inside ``fn``: params, assignments, loop vars, withitems,
    comprehension vars, nested defs — mutation of anything else leaks a
    side effect (and possibly a tracer) out of the trace."""
    bound: set = set()
    args = fn.args
    for a in (list(args.posonlyargs) + list(args.args)
              + list(args.kwonlyargs)
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        bound.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)) and node is not fn:
            bound.add(node.name)
        elif isinstance(node, ast.Name) and isinstance(
                node.ctx, (ast.Store, ast.Del)):
            bound.add(node.id)
        elif isinstance(node, ast.comprehension):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    bound.add(n.id)
    return bound


# ---------------------------------------------------------------------------
# the rules
# ---------------------------------------------------------------------------


def _iter_traced(ctx: FileContext):
    ts = _TracedSet(ctx.tree)
    for fn in ts.traced_nodes:
        yield ts, fn


def check_tracer_branch(ctx: FileContext) -> Iterable[Finding]:
    for ts, fn in _iter_traced(ctx):
        tainted = _taint(fn, ts.data_params(fn))
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
            elif isinstance(node, ast.Assert):
                test = node.test
            else:
                continue
            if _expr_traced(test, tainted):
                kind = type(node).__name__.lower()
                yield ctx.finding(
                    "JAX101", node,
                    f"`{kind}` on a traced value inside `{fn.name}` "
                    f"(reachable from a jit/shard_map wrap site): "
                    f"concrete boolean on a tracer raises "
                    f"TracerBoolConversionError on some inputs and "
                    f"silently specializes on others — use jnp.where/"
                    f"lax.cond, or declare the arg static",
                )


def check_side_effect(ctx: FileContext) -> Iterable[Finding]:
    for ts, fn in _iter_traced(ctx):
        local = _local_bindings(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and call_name(node) == "print":
                yield ctx.finding(
                    "JAX102", node,
                    f"print() inside traced `{fn.name}`: fires once at "
                    f"trace time, never per call — use jax.debug.print "
                    f"deliberately or hoist it out of the jitted body",
                )
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                yield ctx.finding(
                    "JAX102", node,
                    f"`{type(node).__name__.lower()}` inside traced "
                    f"`{fn.name}`: rebinding outer state from a jitted "
                    f"body runs at trace time only and can leak tracers",
                )
            elif isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _MUTATORS and \
                    isinstance(node.func.value, ast.Name) and \
                    node.func.value.id not in local:
                yield ctx.finding(
                    "JAX102", node,
                    f"`{node.func.value.id}.{node.func.attr}(...)` "
                    f"mutates non-local state inside traced `{fn.name}`: "
                    f"runs once at trace time and leaks tracers into "
                    f"`{node.func.value.id}`",
                )


def check_np_on_tracer(ctx: FileContext) -> Iterable[Finding]:
    for ts, fn in _iter_traced(ctx):
        tainted = _taint(fn, ts.data_params(fn))
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if not (name.startswith("np.") or name.startswith("numpy.")):
                continue
            if any(_expr_traced(a, tainted) for a in node.args) or any(
                    _expr_traced(kw.value, tainted)
                    for kw in node.keywords):
                yield ctx.finding(
                    "JAX103", node,
                    f"`{name}` called on a traced value inside "
                    f"`{fn.name}`: forces a host round-trip per call "
                    f"(or a ConcretizationTypeError) — use the jnp "
                    f"equivalent",
                )


def check_unhashable_static(ctx: FileContext) -> Iterable[Finding]:
    defs = {n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef)}

    def bad_default(expr: ast.AST) -> bool:
        if isinstance(expr, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp)):
            return True
        return (isinstance(expr, ast.Call)
                and call_name(expr) in ("list", "dict", "set"))

    def check_wrap(call: ast.Call, fn: Optional[ast.FunctionDef]):
        if fn is None:
            return
        static = _static_names_from_call(call, fn)
        if not static:
            return
        args = fn.args
        positional = [a.arg for a in
                      list(args.posonlyargs) + list(args.args)]
        pairs = list(zip(positional[len(positional) - len(args.defaults):],
                         args.defaults))
        pairs += [(a.arg, d) for a, d in
                  zip(args.kwonlyargs, args.kw_defaults) if d is not None]
        for pname, d in pairs:
            if pname in static and bad_default(d):
                yield ctx.finding(
                    "JAX104", d,
                    f"static arg `{pname}` of `{fn.name}` defaults to an "
                    f"unhashable {type(d).__name__.lower()}: jit static "
                    f"args key the compile cache by hash (dicts also by "
                    f"insertion order) — use a tuple/frozen value",
                )

    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and call_name(node) in _JIT_WRAPPERS:
            target = node.args[0] if node.args else None
            fn = (defs.get(target.id)
                  if isinstance(target, ast.Name) else None)
            yield from check_wrap(node, fn)
        elif isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    cn = call_name(dec)
                    if cn in _JIT_WRAPPERS or (
                            cn in ("partial", "functools.partial")
                            and dec.args
                            and dotted_name(dec.args[0]) in _JIT_WRAPPERS):
                        yield from check_wrap(dec, node)


def _is_cachey(expr: ast.AST) -> bool:
    name = dotted_name(expr)
    last = name.rsplit(".", 1)[-1].lower()
    return "cache" in last


def _unstable_key(expr: ast.AST) -> Optional[str]:
    for node in ast.walk(expr):
        if isinstance(node, ast.JoinedStr):
            return "an f-string"
        if isinstance(node, ast.Call) and call_name(node) == "id":
            return "id(...)"
    return None


def check_cache_key(ctx: FileContext) -> Iterable[Finding]:
    for node in ast.walk(ctx.tree):
        key_expr = None
        base = None
        if isinstance(node, ast.Subscript) and _is_cachey(node.value):
            key_expr, base = node.slice, node.value
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("get", "setdefault", "pop") and \
                _is_cachey(node.func.value) and node.args:
            key_expr, base = node.args[0], node.func.value
        if key_expr is None:
            continue
        what = _unstable_key(key_expr)
        if what:
            yield ctx.finding(
                "JAX105", node,
                f"{what} used as a key into `{dotted_name(base)}`: "
                f"id() values are recycled after GC and f-strings hash "
                f"by rendered text — both silently miss (and recompile) "
                f"where a structural tuple key would hit",
            )


def check_host_callback(ctx: FileContext) -> Iterable[Finding]:
    for ts, fn in _iter_traced(ctx):
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    call_name(node) in _HOST_CALLBACKS:
                yield ctx.finding(
                    "JAX106", node,
                    f"host callback `{call_name(node)}` inside traced "
                    f"`{fn.name}`: synchronizes device->host every call "
                    f"— keep it out of serving hot paths (or gate it "
                    f"behind a debug flag)",
                )


FILE_RULES = [
    Rule("JAX101", "Python branch on a traced value",
         check_tracer_branch, _TARGETS),
    Rule("JAX102", "Python side effect inside a traced body",
         check_side_effect, _TARGETS),
    Rule("JAX103", "np.* on a traced value", check_np_on_tracer, _TARGETS),
    Rule("JAX104", "unhashable static arg default",
         check_unhashable_static, _TARGETS),
    Rule("JAX105", "f-string / id() cache key", check_cache_key, _TARGETS),
    Rule("JAX106", "host callback in a traced hot path",
         check_host_callback, _TARGETS),
]

"""AST rule engine for the repo-aware static-analysis gate.

The repo's core guarantee — every temporal schedule and every serving
layer above it is bit-equivalent to the solo oracle — is enforced
dynamically by the tier-1 suite, but whole hazard classes compile fine,
pass the suite, and still bite later: a tracer leaked into a Python
branch recompiles per value, a lock held across an ``await`` stalls the
event loop, a wire field produced by the client that the server never
reads ships dead bytes forever.  This module is the mechanical checker
for those classes (the PR 1 ``core/temporal.py`` shard_map miscompile
and the PR 3 ticket depth-leak were both statically visible).

Structure mirrors ``benchmarks/check.py``'s committed-baseline pattern:

* :class:`Finding` — one diagnostic with a stable *fingerprint*
  (rule id + path + normalized source line + occurrence index, hashed)
  so baseline entries survive unrelated line drift.
* :class:`Rule` — a per-file check (``check(FileContext)``) targeted at
  path globs; :class:`RepoRule` — a cross-file check
  (``check_repo(RepoContext)``) for contracts that live between modules
  (wire protocol, telemetry rendering).
* :class:`AnalysisEngine` — parses each target file once, dispatches
  every matching rule, and splits the findings against a committed
  baseline (``analysis/baseline.json``): legacy findings carry a
  reviewed *reason* and don't block; anything new fails the run.

The engine is stdlib-only (``ast``) so the CI lint job needs no JAX
install and runs in seconds, before the test matrix.
"""
from __future__ import annotations

import ast
import fnmatch
import hashlib
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Iterable, Optional

BASELINE_VERSION = 1

# default analysis roots, relative to the repo root
DEFAULT_TARGETS = ("src/repro",)


# ---------------------------------------------------------------------------
# findings
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Finding:
    """One diagnostic: a rule id anchored to a file:line span."""

    rule_id: str
    path: str  # repo-relative, posix separators
    line: int
    col: int
    message: str
    snippet: str = ""
    # occurrence index among findings with identical (rule, path, snippet):
    # keeps fingerprints distinct when one line-shape repeats in a file
    occurrence: int = 0

    @property
    def fingerprint(self) -> str:
        """Stable id for baseline matching: hashes the rule, the path and
        the *normalized source line* (not the line number), so moving
        code within a file does not invalidate its baseline entry."""
        basis = "\x1f".join(
            (self.rule_id, self.path, " ".join(self.snippet.split()),
             str(self.occurrence))
        )
        return hashlib.sha1(basis.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        return {
            "rule": self.rule_id,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "snippet": self.snippet,
            "fingerprint": self.fingerprint,
        }

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule_id} "
                f"{self.message}")


# ---------------------------------------------------------------------------
# parsed-file contexts
# ---------------------------------------------------------------------------


class FileContext:
    """One parsed target file handed to per-file rules."""

    def __init__(self, root: Path, path: Path, source: str, tree: ast.AST):
        self.root = root
        self.abspath = path
        try:
            self.path = path.relative_to(root).as_posix()
        except ValueError:  # explicit out-of-tree file (smoke gate tmp)
            self.path = path.as_posix()
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule_id: str, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(rule_id, self.path, line, col, message,
                       snippet=self.line_text(line))


class RepoContext:
    """Every parsed target file, for cross-file contract rules."""

    def __init__(self, root: Path, files: list[FileContext]):
        self.root = root
        self.files = files

    def by_basename(self, *names: str) -> list[FileContext]:
        return [f for f in self.files
                if Path(f.path).name in names
                or any(Path(f.path).name.endswith("_" + n) for n in names)]


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


@dataclass
class Rule:
    """A per-file check.  ``targets`` are repo-relative glob patterns the
    default walk applies; explicit file arguments bypass targeting so
    fixtures exercise every rule."""

    id: str
    title: str
    check: Callable[[FileContext], Iterable[Finding]]
    targets: tuple = ("src/repro/**",)

    def matches(self, relpath: str) -> bool:
        return any(fnmatch.fnmatch(relpath, pat) for pat in self.targets)


@dataclass
class RepoRule:
    """A cross-file check over the whole parsed file set."""

    id: str
    title: str
    check_repo: Callable[[RepoContext], Iterable[Finding]]


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------


@dataclass
class Baseline:
    """Committed suppression set: fingerprint -> reviewed reason.

    Mirrors the benchmark gate's committed-baseline pattern: legacy
    findings are admitted explicitly (with a human reason — never
    silently) while anything new fails the run until fixed or reviewed.
    """

    entries: dict = field(default_factory=dict)  # fingerprint -> entry dict

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        if not path.exists():
            return cls()
        data = json.loads(path.read_text())
        if data.get("version") != BASELINE_VERSION:
            raise ValueError(
                f"unsupported baseline version {data.get('version')!r} "
                f"in {path} (expected {BASELINE_VERSION})"
            )
        entries = {}
        for e in data.get("entries", []):
            if not e.get("reason"):
                # the zero-silent-suppressions rule is structural: an
                # entry with no reason is invalid, not quietly honoured
                raise ValueError(
                    f"baseline entry {e.get('fingerprint')!r} in {path} "
                    f"has no reason; every suppression must say why"
                )
            entries[e["fingerprint"]] = e
        return cls(entries)

    def save(self, path: Path) -> None:
        payload = {
            "version": BASELINE_VERSION,
            "entries": sorted(self.entries.values(),
                              key=lambda e: (e["rule"], e["path"],
                                             e["fingerprint"])),
        }
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(payload, indent=2) + "\n")

    def split(self, findings: list) -> tuple[list, list, list]:
        """Partition ``findings`` into ``(new, suppressed, stale_entries)``:
        ``new`` fail the run, ``suppressed`` match a baseline entry,
        ``stale_entries`` are baseline entries whose finding no longer
        exists (fixed — prune them with ``--update-baseline``)."""
        new, suppressed = [], []
        seen = set()
        for f in findings:
            fp = f.fingerprint
            if fp in self.entries:
                suppressed.append(f)
                seen.add(fp)
            else:
                new.append(f)
        stale = [e for fp, e in sorted(self.entries.items())
                 if fp not in seen]
        return new, suppressed, stale

    def update(self, findings: list,
               default_reason: str = "unreviewed (added by "
                                     "--update-baseline; replace with a "
                                     "real reason before committing)") -> None:
        """Re-baseline: keep reviewed reasons for findings that persist,
        add new entries with a placeholder reason, prune fixed ones."""
        fresh: dict = {}
        for f in findings:
            fp = f.fingerprint
            old = self.entries.get(fp)
            fresh[fp] = {
                "fingerprint": fp,
                "rule": f.rule_id,
                "path": f.path,
                "line": f.line,
                "snippet": f.snippet,
                "reason": old["reason"] if old else default_reason,
            }
        self.entries = fresh


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def default_rules() -> tuple[list, list]:
    """The shipped rule packs ``(file_rules, repo_rules)``."""
    from repro.analysis import rules_async, rules_contract, rules_jax

    file_rules = (list(rules_jax.FILE_RULES)
                  + list(rules_async.FILE_RULES)
                  + list(rules_contract.FILE_RULES))
    repo_rules = list(rules_contract.REPO_RULES)
    return file_rules, repo_rules


class AnalysisEngine:
    """Parse once, dispatch every rule, report findings.

    >>> eng = AnalysisEngine(repo_root)
    >>> findings = eng.run()                       # default targeted walk
    >>> findings = eng.run([Path("bad.py")])       # explicit files: every
    ...                                            # rule runs, no targeting
    """

    def __init__(self, root, file_rules: Optional[list] = None,
                 repo_rules: Optional[list] = None):
        self.root = Path(root).resolve()
        if file_rules is None and repo_rules is None:
            file_rules, repo_rules = default_rules()
        self.file_rules = list(file_rules or [])
        self.repo_rules = list(repo_rules or [])
        self.parse_errors: list[Finding] = []

    def rule_ids(self) -> list[str]:
        return sorted([r.id for r in self.file_rules]
                      + [r.id for r in self.repo_rules])

    def _iter_default_files(self) -> list[Path]:
        out = []
        for target in DEFAULT_TARGETS:
            base = self.root / target
            if base.is_file():
                out.append(base)
            elif base.is_dir():
                out.extend(sorted(base.rglob("*.py")))
        return out

    def _parse(self, path: Path) -> Optional[FileContext]:
        try:
            source = path.read_text()
            tree = ast.parse(source, filename=str(path))
        except (SyntaxError, UnicodeDecodeError, OSError) as exc:
            rel = (path.relative_to(self.root).as_posix()
                   if path.is_relative_to(self.root) else str(path))
            self.parse_errors.append(Finding(
                "ENGINE000", rel, getattr(exc, "lineno", 1) or 1, 0,
                f"file could not be analysed: {type(exc).__name__}: {exc}",
                snippet=f"<parse error: {type(exc).__name__}>",
            ))
            return None
        return FileContext(self.root, path, source, tree)

    def run(self, paths: Optional[Iterable] = None) -> list[Finding]:
        """Analyse ``paths`` (default: the targeted repo walk).  With
        explicit paths every rule runs on every file — that is how the
        fixture tests and the smoke gate exercise single rules — while
        the default walk applies each rule's ``targets`` globs."""
        self.parse_errors = []
        explicit = paths is not None
        files = ([Path(p).resolve() for p in paths] if explicit
                 else self._iter_default_files())
        contexts = [ctx for ctx in (self._parse(p) for p in files)
                    if ctx is not None]
        findings: list[Finding] = list(self.parse_errors)
        for ctx in contexts:
            for rule in self.file_rules:
                if explicit or rule.matches(ctx.path):
                    findings.extend(rule.check(ctx))
        repo_ctx = RepoContext(self.root, contexts)
        for rule in self.repo_rules:
            findings.extend(rule.check_repo(repo_ctx))
        return _number_occurrences(
            sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule_id))
        )


def _number_occurrences(findings: list) -> list:
    """Assign occurrence indices so findings with identical
    (rule, path, snippet) keep distinct fingerprints in file order."""
    seen: dict = {}
    out = []
    for f in findings:
        key = (f.rule_id, f.path, " ".join(f.snippet.split()))
        n = seen.get(key, 0)
        seen[key] = n + 1
        out.append(Finding(f.rule_id, f.path, f.line, f.col, f.message,
                           f.snippet, occurrence=n) if n != f.occurrence
                   else f)
    return out


# ---------------------------------------------------------------------------
# shared AST helpers (used by the rule packs)
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str:
    """``jax.debug.print`` for the matching Attribute/Name chain, else ''."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def call_name(call: ast.Call) -> str:
    return dotted_name(call.func)


def walk_scoped(node: ast.AST, *, into_functions: bool = True):
    """``ast.walk`` that can stop at nested function/class boundaries."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if not into_functions and isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                        ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(child))


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None

"""Durable sessions: snapshot/restore for pool streams + token resumption.

The serving stack's contract so far was "a dead worker's streams are
dead" — PR 5 merely counted them (``sessions_lost``).  This subsystem
closes the gap with the same bar :mod:`repro.distributed.fault` sets for
training: a recovered trajectory must equal the no-failure trajectory.

Three pieces:

* :class:`SessionStore` — one on-disk store shared by every worker of a
  front.  Each worker writes periodic snapshots of its ENTIRE pool slot
  block (stacked per-layer ``(h, c)`` rows + running error sums + steps,
  plus per-session metadata: durable id, seq position, recalibration
  epoch) into its own shard subdirectory through the atomic
  ``checkpoint/manager.py`` path.  Snapshots read a host copy and
  serialize on a background thread — the compiled masked step is never
  blocked, and a pump tick that finds the writer busy SKIPS instead of
  waiting.  Restores scan ALL shards, so any worker can revive any
  worker's streams.
* :class:`DurableSessions` — the per-gateway coordinator: mints durable
  session ids, tracks seq positions, parks exact state on graceful
  disconnects, snapshots on a cadence from the server pump, performs the
  drain-time handoff snapshot, and serves ``resume`` (park fast path,
  else cross-shard snapshot lookup + :meth:`SessionPool.restore`).
* signed resumption tokens (:mod:`repro.gateway.tokens`) — every
  ``step`` response carries one; presenting it to ANY worker of the
  front proves ownership and names the session to revive.

Loss semantics (documented in README §Durability): a parked/handed-off
session resumes EXACTLY where it stopped (zero replay); a SIGKILLed
worker's sessions resume from the latest snapshot, and the client
replays its buffered steps since that snapshot — bit-equal to an
uninterrupted run because the masked step is deterministic.  Steps that
are neither snapshotted nor inside the client's replay window are lost;
choose ``snapshot_interval_ms`` ≤ the client's replay-window span.
"""
from __future__ import annotations

import json
import time
import uuid
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Optional

import numpy as np

from repro.checkpoint.manager import AsyncCheckpointer, latest_checkpoint
from repro.gateway.tokens import (
    SessionClaim,
    TokenError,
    TokenSigner,
    UnknownSessionError,
    load_or_create_secret,
)

DEFAULT_SHARD = "worker-0"


@dataclass
class SessionRecord:
    """One session's restorable state, as read from a snapshot or parked
    in memory: per-leaf state rows (tree-leaves order), error counters,
    and the seq position the state corresponds to."""

    rows: list
    sq_sum: float
    steps: int
    seq: int
    epoch: int = 0
    parked_at: float = field(default=0.0)


class SessionActiveError(TokenError):
    """Resume refused: the session is currently being served (a token is
    a bearer credential for a DISCONNECTED stream, not a way to fork a
    live one)."""


class SessionStore:
    """Disk layout::

        <directory>/token.secret          shared HMAC secret (0600)
        <directory>/shards/<shard>/step_00000007/{leaves.npz, meta.json}

    Writes go through :class:`AsyncCheckpointer` (atomic tmp+rename,
    background thread, keep-N GC); reads scan every shard's latest
    snapshot.  Snapshot ids continue across respawns so a reborn worker
    never overwrites its predecessor's latest snapshot."""

    def __init__(self, directory: str | Path, *, shard: str = DEFAULT_SHARD,
                 keep: int = 2, token_ttl_s: Optional[float] = 3600.0,
                 clock: Callable[[], float] = time.time):
        self.directory = Path(directory)
        self.shard = shard
        self.shards_root = self.directory / "shards"
        self.shard_dir = self.shards_root / shard
        self.shard_dir.mkdir(parents=True, exist_ok=True)
        self.signer = TokenSigner(
            load_or_create_secret(self.directory), ttl_s=token_ttl_s, clock=clock
        )
        self._ckpt = AsyncCheckpointer(self.shard_dir, keep=keep)
        last = latest_checkpoint(self.shard_dir)
        self._next_id = 0 if last is None else int(last.name.split("_")[1]) + 1

    # -- writes ------------------------------------------------------------

    @property
    def busy(self) -> bool:
        return self._ckpt.busy

    def write(self, flat: dict, meta: dict, *, wait: bool = False) -> dict:
        """Persist one snapshot (``flat``: {key: host ndarray}) through the
        atomic checkpoint path.  ``wait=False`` returns after the host-side
        handoff; serialization runs on the checkpointer's thread."""
        snapshot_id = self._next_id
        self._next_id += 1
        self._ckpt.save(snapshot_id, flat, extra_meta=meta)
        if wait:
            self._ckpt.wait()
        nbytes = int(sum(np.asarray(v).nbytes for v in flat.values()))
        return {"snapshot_id": snapshot_id, "bytes": nbytes}

    def wait(self) -> None:
        self._ckpt.wait()

    # -- reads -------------------------------------------------------------

    @staticmethod
    def _read_meta(path: Path) -> Optional[dict]:
        try:
            return json.loads((path / "meta.json").read_text())
        except (OSError, ValueError):
            return None

    @staticmethod
    def _record_from(path: Path, sid: str, entry: dict,
                     meta: dict) -> Optional[SessionRecord]:
        n = int(meta.get("num_state_leaves", 0))
        try:
            with np.load(path / "leaves.npz") as data:
                if entry.get("kind") == "parked":
                    rows = [data[f"parked/{sid}/state{i}"] for i in range(n)]
                    sq = float(data[f"parked/{sid}/sq"])
                    steps = int(data[f"parked/{sid}/steps"])
                else:
                    slot = int(entry["slot"])
                    rows = [data[f"pool/state{i}"][slot] for i in range(n)]
                    sq = float(data["pool/sq_sum"][slot])
                    steps = int(data["pool/steps"][slot])
        except (OSError, KeyError, ValueError):
            return None
        return SessionRecord(rows=rows, sq_sum=sq, steps=steps,
                             seq=int(entry.get("seq", 0)),
                             epoch=int(entry.get("epoch", 0)))

    def lookup(self, sid: str) -> Optional[SessionRecord]:
        """The freshest restorable state for ``sid`` across ALL shards
        (highest seq wins — after a migration several shards may carry
        stale copies).  None when no reachable snapshot knows the id."""
        best = None
        if self.shards_root.exists():
            for shard_dir in sorted(self.shards_root.iterdir()):
                path = latest_checkpoint(shard_dir)
                if path is None:
                    continue
                meta = self._read_meta(path)
                if meta is None:
                    continue
                entry = meta.get("sessions", {}).get(sid)
                if entry is None:
                    continue
                if best is None or int(entry.get("seq", 0)) > best[0]:
                    best = (int(entry.get("seq", 0)), path, entry, meta)
        if best is None:
            return None
        _, path, entry, meta = best
        return self._record_from(path, sid, entry, meta)

    def adopt_shard(self) -> dict[str, SessionRecord]:
        """Everything the PREVIOUS incarnation of this shard's worker had
        snapshotted — called at worker boot so a respawn keeps carrying
        the crashed worker's sessions forward in its own new snapshots
        (otherwise keep-N GC would age them out)."""
        path = latest_checkpoint(self.shard_dir)
        if path is None:
            return {}
        meta = self._read_meta(path)
        if meta is None:
            return {}
        out = {}
        for sid, entry in meta.get("sessions", {}).items():
            rec = self._record_from(path, sid, entry, meta)
            if rec is not None:
                out[sid] = rec
        return out


class DurableSessions:
    """Per-gateway durability coordinator (attach via
    :func:`enable_durability`; the transport reads ``gateway.durability``).

    All methods run on the gateway's single serving thread (the server
    event loop): seq bookkeeping needs no locks, and the only blocking
    work — the device->host block copy — is bounded by pool size, not by
    disk."""

    def __init__(self, gateway, store: SessionStore, *,
                 snapshot_interval_ms: float = 1000.0,
                 park_ttl_s: float = 900.0,
                 clock: Callable[[], float] = time.monotonic):
        self.gateway = gateway
        self.store = store
        self.interval_s = snapshot_interval_ms / 1e3
        self.park_ttl_s = park_ttl_s
        self._clock = clock
        self.epoch = 0  # bumped by AnomalyGateway.recalibrate
        self.token_refresh_steps = 16  # re-mint cadence (see _mint)
        self._seq: dict[str, int] = {}       # live durable sessions -> seq
        self._tok_cache: dict[str, tuple[int, str]] = {}  # sid -> (epoch, tok)
        self._parked: dict[str, SessionRecord] = {}
        self._snapshots = 0
        self._resumes = 0
        self._replayed_from_park = 0
        self._last_snapshot_t: Optional[float] = None
        self._last_bytes = 0
        self._last_sessions = 0
        self._was_empty = False
        self.last_handoff: Optional[dict] = None
        # a respawned worker rises with its predecessor's sessions parked
        for sid, rec in store.adopt_shard().items():
            rec.parked_at = self._clock()
            self._parked[sid] = rec
        if self._parked:
            gateway.telemetry.count("durability.adopted", len(self._parked))
            gateway.events.emit("adopt", shard=store.shard,
                                sessions=len(self._parked))
        gateway.telemetry.gauge(
            "durability.snapshot_interval_ms", snapshot_interval_ms
        )

    # -- session lifecycle -------------------------------------------------

    def new_session_id(self) -> str:
        return f"s-{uuid.uuid4().hex[:16]}"

    def _mint(self, sid: str, seq: int) -> str:
        """Issue-and-cache a token for ``sid``.  A token's embedded seq
        is informational — ``resume`` restores position from the
        snapshot and the client replays from its own buffer — so steps
        in between refreshes hand out the cached token instead of paying
        json+HMAC (measured ~50us cache-cold between compiled steps,
        i.e. ~10% of a small-model step) on every response."""
        tok = self.store.signer.issue(sid, seq, self.epoch)
        self._tok_cache[sid] = (self.epoch, tok)
        return tok

    def admit(self) -> tuple[str, str]:
        """Admit a fresh durable stream; returns ``(sid, token)``."""
        sid = self.new_session_id()
        self.gateway.admit(sid)
        self._seq[sid] = 0
        self.gateway.telemetry.count("durability.admitted")
        return sid, self._mint(sid, 0)

    def step(self, sid: str, x) -> tuple[float, int, str]:
        """One pool step for ``sid``; returns ``(running_error, seq,
        token)``.  The token is re-minted every ``token_refresh_steps``
        steps (and on epoch change); in between the previous one is
        returned — equally resumable, since replay position comes from
        the client's buffer, not the token."""
        running = self.gateway.step({sid: x})[sid]
        seq = self._seq[sid] = self._seq.get(sid, 0) + 1
        cached = self._tok_cache.get(sid)
        if cached is not None and cached[0] == self.epoch \
                and seq % self.token_refresh_steps:
            return running, seq, cached[1]
        return running, seq, self._mint(sid, seq)

    def close(self, sid: str) -> float:
        """Explicit close: evict AND forget — the session leaves the next
        snapshot, so once old snapshots age out its tokens answer
        ``UnknownSessionError``."""
        final = self.gateway.evict(sid)
        self._seq.pop(sid, None)
        self._tok_cache.pop(sid, None)
        self._parked.pop(sid, None)
        return final

    def suspend(self, sid: str) -> None:
        """Abrupt disconnect: park the EXACT current state host-side and
        free the slot.  A later resume (any worker after the next
        snapshot; this worker immediately) continues with zero loss."""
        if sid not in self._seq:
            return
        try:
            rows, sq, steps = self.gateway.pool.export_slot(sid)
        except KeyError:
            self._seq.pop(sid, None)
            return
        self.gateway.evict(sid)
        self._tok_cache.pop(sid, None)
        self._parked[sid] = SessionRecord(
            rows=rows, sq_sum=sq, steps=steps, seq=self._seq.pop(sid, 0),
            epoch=self.epoch, parked_at=self._clock(),
        )
        self.gateway.telemetry.count("durability.parked")

    def resume(self, token: str) -> dict:
        """Verify ``token`` and revive its session into this worker's
        pool.  Raises TamperedTokenError / ExpiredTokenError /
        UnknownSessionError / SessionActiveError (the class name is the
        wire error code)."""
        claim: SessionClaim = self.store.signer.verify(token)
        sid = claim.sid
        if sid in self._seq:
            raise SessionActiveError(
                f"session {sid!r} is still being served on this worker; "
                f"close or drop its connection before resuming"
            )
        # the locally parked copy is usually freshest (exact state at
        # disconnect), but an ADOPTED park can be stale: the predecessor
        # snapshotted it parked, the session then lived on (and was
        # re-snapshotted by) ANOTHER worker.  Always check the store and
        # take whichever copy is further along.
        rec = self._parked.pop(sid, None)
        disk = self.store.lookup(sid)
        if disk is not None and (rec is None or disk.seq > rec.seq):
            rec = disk
        elif rec is not None:
            self._replayed_from_park += 1
        if rec is None:
            raise UnknownSessionError(
                f"session {sid!r} exists in no reachable snapshot "
                f"(closed, never durable, or aged out of the store)"
            )
        self.gateway.pool.restore(sid, rec.rows, rec.sq_sum, rec.steps)
        self._seq[sid] = rec.seq
        running = float(self.gateway.pool.error_of(sid))
        self._resumes += 1
        self.gateway.telemetry.count("durability.resumed")
        self.gateway.events.emit("resume", sid=sid, seq=rec.seq,
                                 shard=self.store.shard)
        return {
            "sid": sid,
            "seq": rec.seq,
            "running_error": running,
            "token": self._mint(sid, rec.seq),
        }

    # -- snapshotting ------------------------------------------------------

    def _expire_parked(self, now: float) -> None:
        dead = [sid for sid, rec in self._parked.items()
                if now - rec.parked_at > self.park_ttl_s]
        for sid in dead:
            del self._parked[sid]
        if dead:
            self.gateway.telemetry.count("durability.park_expired", len(dead))

    def snapshot_now(self, *, wait: bool = False) -> dict:
        """One full snapshot: the pool block (host copy), live-session
        metadata, and every parked session's rows.  The write itself is
        async unless ``wait``."""
        pool = self.gateway.pool
        leaves, sq_sum, steps = pool.export_block()
        flat = {"pool/sq_sum": sq_sum, "pool/steps": steps}
        for i, leaf in enumerate(leaves):
            flat[f"pool/state{i}"] = leaf
        sessions: dict[str, dict] = {}
        for sid, seq in self._seq.items():
            sessions[sid] = {"kind": "live", "slot": pool.slot_of(sid),
                             "seq": seq, "epoch": self.epoch}
        for sid, rec in self._parked.items():
            for i, row in enumerate(rec.rows):
                flat[f"parked/{sid}/state{i}"] = np.asarray(row)
            flat[f"parked/{sid}/sq"] = np.float32(rec.sq_sum)
            flat[f"parked/{sid}/steps"] = np.int32(rec.steps)
            sessions[sid] = {"kind": "parked", "seq": rec.seq,
                             "epoch": rec.epoch}
        meta = {
            "sessions": sessions,
            "num_state_leaves": len(leaves),
            "epoch": self.epoch,
            "shard": self.store.shard,
        }
        out = self.store.write(flat, meta, wait=wait)
        self._snapshots += 1
        self._last_snapshot_t = self._clock()
        self._last_bytes = out["bytes"]
        self._last_sessions = len(sessions)
        self._was_empty = not sessions
        t = self.gateway.telemetry
        t.count("durability.snapshots")
        t.gauge("durability.snapshot_bytes", out["bytes"])
        t.gauge("durability.snapshot_sessions", len(sessions))
        t.gauge("durability.snapshot_age_s", 0.0)
        self.gateway.events.emit(
            "snapshot", shard=self.store.shard,
            snapshot_id=out["snapshot_id"], sessions=len(sessions),
            bytes=out["bytes"],
        )
        return {"sessions": len(sessions), **out}

    def maybe_snapshot(self, now: Optional[float] = None) -> bool:
        """Cadence tick, called from the server's background pump.  Skips
        (never blocks) while the previous write is in flight; skips
        back-to-back empty snapshots so an idle worker stops writing."""
        now = self._clock() if now is None else now
        self._expire_parked(now)
        if self._last_snapshot_t is not None:
            age = now - self._last_snapshot_t
            self.gateway.telemetry.gauge("durability.snapshot_age_s", age)
            if age < self.interval_s:
                return False
        if self.store.busy:
            self.gateway.telemetry.count("durability.snapshot_skipped")
            return False
        if self._was_empty and not self._seq and not self._parked:
            return False
        self.snapshot_now()
        return True

    def handoff(self) -> dict:
        """Drain-time migration: ONE synchronous snapshot carrying every
        resident durable session, taken before the transport evicts them.
        Returns ``{"sessions_migrated": <live residents>, ...}`` — the
        number the front's drain summary must equal."""
        migrated = len(self._seq)
        out = self.snapshot_now(wait=True)
        self.last_handoff = {
            "sessions_migrated": migrated,
            "parked_carried": len(self._parked),
            **out,
        }
        self.gateway.telemetry.count("durability.migrated", migrated)
        self.gateway.events.emit(
            "migration", shard=self.store.shard,
            sessions_migrated=migrated, parked_carried=len(self._parked),
        )
        return self.last_handoff

    # -- observability -----------------------------------------------------

    def describe(self) -> dict:
        age = (None if self._last_snapshot_t is None
               else self._clock() - self._last_snapshot_t)
        return {
            "store": str(self.store.directory),
            "shard": self.store.shard,
            "snapshot_interval_ms": self.interval_s * 1e3,
            "snapshots": self._snapshots,
            "snapshot_age_s": age,
            "snapshot_bytes": self._last_bytes,
            "snapshot_sessions": self._last_sessions,
            "durable_live": len(self._seq),
            "parked": len(self._parked),
            "resumes": self._resumes,
            "epoch": self.epoch,
        }


def enable_durability(
    gateway,
    directory: str | Path,
    *,
    shard: str = DEFAULT_SHARD,
    snapshot_interval_ms: float = 1000.0,
    park_ttl_s: float = 900.0,
    token_ttl_s: Optional[float] = 3600.0,
    keep: int = 2,
) -> DurableSessions:
    """Attach a :class:`DurableSessions` coordinator to ``gateway`` (sets
    ``gateway.durability``; the transport and stats pick it up from
    there).  One call per worker, each with its own ``shard`` name over
    one shared ``directory``."""
    store = SessionStore(directory, shard=shard, keep=keep,
                         token_ttl_s=token_ttl_s)
    dur = DurableSessions(
        gateway, store, snapshot_interval_ms=snapshot_interval_ms,
        park_ttl_s=park_ttl_s,
    )
    gateway.durability = dur
    return dur


__all__ = [
    "DurableSessions",
    "SessionActiveError",
    "SessionRecord",
    "SessionStore",
    "enable_durability",
]

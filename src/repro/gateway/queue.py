"""Micro-batching request queue for one-shot scoring.

One-shot ``score`` requests (a single (T, F) window each) are coalesced
into padded, shape-bucketed micro-batches — the serving-layer analogue of
the paper's inter-module FIFOs keeping the datapath fed.  Requests bucket
by sequence length (next power-of-two ladder), pad to the bucket
boundary, and flush when a bucket reaches ``max_batch`` or its oldest
request has waited ``max_wait_ms``.  Every flush runs the engine's
masked-score program on a FIXED (lanes, bucket_T, F) shape — ``lanes`` is
``max_batch`` rounded up to a per-device multiple of the engine's
placement, so under a sharded placement each flush scores data-parallel
over the mesh — and each bucket compiles exactly once; padding lanes are
masked out of the scores (LSTM causality makes end-padding exact, see
``Engine.score_masked``).

Backpressure: ``submit`` raises :class:`GatewayOverloadedError` once
``max_queue`` requests are pending (admission control, not silent
buffering) and ValueError past ``max_seq_len`` (each power-of-two bucket
beyond the ladder would mint a fresh compiled program — oversized windows
are a caller error, not a compile request).  The queue is caller-driven
(call :meth:`pump` from the serve loop, or let a transport's background
pump task do it) and single-threaded by design; ``clock`` is injectable
for tests.

Tickets complete future-style: a flush either resolves every taken
ticket with its score or *fails* them all with the engine's exception —
requests never sit unresolved after leaving the queue, which is what
lets an async transport await tickets instead of polling.
"""
from __future__ import annotations

import logging
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.engine.base import Engine
from repro.gateway.telemetry import Telemetry
from repro.obs.histogram import Histogram

logger = logging.getLogger(__name__)

# bucket ladder for sequence lengths; lengths beyond the last rung double
_BUCKET_LADDER = (8, 16, 32, 64, 128, 256, 512, 1024)


class GatewayOverloadedError(RuntimeError):
    """The request queue is full (``max_queue`` pending) — shed or retry."""


class Ticket:
    """Future-style handle for one submitted request.

    A ticket is *resolved* (score available) or *failed* (the flush's
    engine exception stored) exactly once, at flush time.  Completion
    callbacks registered via :meth:`add_done_callback` fire synchronously
    on whichever path finishes the ticket — success AND error — so a
    transport can write the response from the callback without polling.
    """

    __slots__ = ("t_submit", "stage_ms", "_score", "_error", "_callbacks")

    def __init__(self, t_submit: float):
        self.t_submit = t_submit
        # stage timing breakdown stamped at flush time (queue_wait /
        # assemble / compute, in ms) — folded into the request's span when
        # the caller traced it; None until the ticket's flush runs
        self.stage_ms: Optional[dict] = None
        self._score: Optional[float] = None
        self._error: Optional[BaseException] = None
        self._callbacks: list = []

    @property
    def done(self) -> bool:
        """True once the ticket is resolved or failed."""
        return self._score is not None or self._error is not None

    @property
    def failed(self) -> bool:
        return self._error is not None

    def exception(self) -> Optional[BaseException]:
        """The flush failure that killed this request (None if none yet)."""
        return self._error

    @property
    def score(self) -> float:
        if self._error is not None:
            raise self._error
        if self._score is None:
            raise RuntimeError("request not scored yet; pump()/flush() the queue")
        return self._score

    def add_done_callback(self, fn: Callable[["Ticket"], None]) -> None:
        """Call ``fn(ticket)`` when the ticket completes (immediately if it
        already has).  Callback exceptions are logged, never propagated —
        one broken consumer must not wedge a flush for its batchmates."""
        if self.done:
            self._run_callback(fn)
        else:
            self._callbacks.append(fn)

    def _run_callback(self, fn) -> None:
        try:
            fn(self)
        except Exception:
            logger.exception("ticket completion callback raised")

    def _finish(self) -> None:
        callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            self._run_callback(fn)

    def _resolve(self, score: float) -> None:
        if not self.done:
            self._score = score
            self._finish()

    def _fail(self, exc: BaseException) -> None:
        if not self.done:
            self._error = exc
            self._finish()


def bucket_for(t: int, ladder: Sequence[int] = _BUCKET_LADDER) -> int:
    """Smallest bucket boundary >= t (doubling past the ladder's end)."""
    for b in ladder:
        if t <= b:
            return b
    b = ladder[-1]
    while b < t:
        b *= 2
    return b


class MicroBatcher:
    """Shape-bucketed micro-batching over ``Engine.score_masked``."""

    def __init__(
        self,
        engine: Engine,
        *,
        max_batch: int = 32,
        max_wait_ms: float = 5.0,
        max_queue: int = 1024,
        max_seq_len: Optional[int] = None,
        telemetry: Optional[Telemetry] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_seq_len is None:
            max_seq_len = _BUCKET_LADDER[-1]
        if max_seq_len < 1:
            raise ValueError(f"max_seq_len must be >= 1, got {max_seq_len}")
        self.engine = engine
        self.features = engine.cfg.lstm_ae.input_features
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.max_queue = max_queue
        self.max_seq_len = max_seq_len
        self.telemetry = telemetry or Telemetry()
        self._clock = clock
        # Sharded placements score flushes data-parallel: the fixed lane
        # count pads max_batch up to a per-device multiple so every flush
        # splits evenly over the mesh (the extra lanes are padding, masked
        # out of scores like any other padding lane).  Single placement:
        # lanes == max_batch, shapes unchanged.
        self.placement = engine.placement
        self.lanes = self.placement.pad_rows(max_batch)
        # bucket_T -> FIFO of (series (T,F) float32, ticket)
        self._buckets: dict[int, list[tuple[np.ndarray, Ticket]]] = {}
        self._depth = 0
        # bucket_T -> persistent (x, lengths) pad buffers: each bucket's
        # fixed (lanes, tb, F) assembly target is allocated once and
        # reused every flush, so assembling a batch is one copy per
        # window (wire payload view -> pad buffer) with zero allocation
        # on the hot path.  Safe to reuse across flushes because jax
        # copies inputs at dispatch and score_masked's result is
        # materialized (np.asarray blocks) before the next flush.
        self._pad: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    @property
    def queue_depth(self) -> int:
        return self._depth

    # -- control-plane actuation ------------------------------------------

    def set_knobs(
        self,
        max_batch: Optional[int] = None,
        max_wait_ms: Optional[float] = None,
    ) -> dict:
        """Adjust the batching knobs at runtime; returns the applied values.

        ``max_batch`` is clamped to ``[1, lanes]`` — the lane count (and
        with it every compiled (lanes, bucket_T, F) shape) was fixed at
        construction, so a controller can move the flush trigger freely
        without ever minting a new compiled program.  ``max_wait_ms`` is
        continuous and unconstrained (floored at 0).  Buckets already
        fuller than a lowered ``max_batch`` drain on the next pump.
        """
        if max_batch is not None:
            self.max_batch = min(max(1, int(max_batch)), self.lanes)
        if max_wait_ms is not None:
            self.max_wait_ms = max(0.0, float(max_wait_ms))
        return {"max_batch": self.max_batch, "max_wait_ms": self.max_wait_ms}

    # -- intake -----------------------------------------------------------

    def submit(self, series) -> Ticket:
        """Enqueue one (T, F) window for scoring; returns its ticket.

        Raises :class:`GatewayOverloadedError` when ``max_queue`` requests
        are already pending (backpressure) and ValueError on shape
        mismatch or when the window is longer than ``max_seq_len`` (the
        admission limit that keeps the bucket ladder — and therefore the
        set of compiled shapes — bounded).  A bucket reaching ``max_batch``
        flushes immediately.
        """
        arr = np.asarray(series, np.float32)
        if arr.ndim != 2 or arr.shape[1] != self.features:
            raise ValueError(
                f"expected a (T, {self.features}) window, got shape {arr.shape}"
            )
        if arr.shape[0] < 1:
            raise ValueError("empty window (T == 0)")
        if arr.shape[0] > self.max_seq_len:
            raise ValueError(
                f"window length {arr.shape[0]} exceeds max_seq_len="
                f"{self.max_seq_len}; longer windows would compile a fresh "
                f"bucket shape per power of two (raise max_seq_len to admit)"
            )
        if self._depth >= self.max_queue:
            self.telemetry.count("queue.rejected")
            raise GatewayOverloadedError(
                f"queue full ({self.max_queue} pending); pump() or shed load"
            )
        ticket = Ticket(self._clock())
        tb = bucket_for(arr.shape[0])
        self._buckets.setdefault(tb, []).append((arr, ticket))
        self._depth += 1
        self.telemetry.count("queue.submitted")
        self.telemetry.gauge("queue.depth", self._depth)
        if len(self._buckets[tb]) >= self.max_batch:
            self._flush_bucket(tb)
        return ticket

    # -- flushing ---------------------------------------------------------

    def pump(self, now: Optional[float] = None) -> int:
        """Flush every bucket that is full or whose oldest request has
        waited ``max_wait_ms``; returns the number of requests completed.
        The serve loop calls this between I/O events."""
        now = self._clock() if now is None else now
        completed = 0
        for tb in list(self._buckets):
            pending = self._buckets.get(tb)
            if not pending:
                continue
            waited_ms = (now - pending[0][1].t_submit) * 1e3
            if len(pending) >= self.max_batch or waited_ms >= self.max_wait_ms:
                completed += self._flush_bucket(tb)
        return completed

    def flush(self) -> int:
        """Flush everything pending regardless of age; returns count."""
        completed = 0
        for tb in list(self._buckets):
            while self._buckets.get(tb):
                completed += self._flush_bucket(tb)
        return completed

    def _flush_bucket(self, tb: int) -> int:
        """Flush up to ``max_batch`` requests from bucket ``tb``; returns the
        number *successfully scored*.  The taken requests leave the queue
        unconditionally — an engine failure mid-flush fails their tickets
        (error state + ``queue.failed``) instead of leaking queue depth and
        leaving them unresolved forever (the overload-wedge bug)."""
        pending = self._buckets[tb]
        take, self._buckets[tb] = pending[: self.max_batch], pending[self.max_batch:]
        if not take:
            return 0
        n = len(take)
        # the take is out of the queue from here on, success or failure
        self._depth -= n
        self.telemetry.gauge("queue.depth", self._depth)
        t_flush = self._clock()
        try:
            # fixed (lanes, tb, F) shape: one compile per bucket, ever
            # (lanes == max_batch rounded to a per-device multiple)
            pad = self._pad.get(tb)
            if pad is None:
                pad = self._pad[tb] = (
                    np.zeros((self.lanes, tb, self.features), np.float32),
                    np.ones((self.lanes,), np.int32),
                )
            x, lengths = pad
            for i, (arr, _) in enumerate(take):
                ti = arr.shape[0]
                x[i, :ti] = arr
                # zero only the tail this row exposes — rows >= n keep a
                # previous flush's data but their lengths are reset to 1
                # below, so they are padding lanes and masked regardless
                x[i, ti:] = 0.0
                lengths[i] = ti
            lengths[n:] = 1
            t_assembled = self._clock()
            scores = np.asarray(
                self.engine.score_masked({"series": x, "lengths": lengths})
            )
        except Exception as exc:
            self.telemetry.count("queue.failed", n)
            for _, ticket in take:
                ticket._fail(exc)
            return 0
        now = self._clock()
        assemble_ms = (t_assembled - t_flush) * 1e3
        compute_ms = (now - t_assembled) * 1e3
        oldest_wait_ms = (now - take[0][1].t_submit) * 1e3
        tel = self.telemetry
        tel.observe_stage("assemble_ms", assemble_ms)
        tel.observe_stage("compute_ms", compute_ms)
        # per-ticket stage records resolve their histograms once per
        # flush, not once per ticket — this loop is the score hot path
        wait_hist = tel.histograms.get("queue_wait_ms") if tel.detail else None
        if tel.detail and wait_hist is None:
            wait_hist = tel.histograms["queue_wait_ms"] = Histogram()
        req_record = tel.request_histogram.record
        for i, (_, ticket) in enumerate(take):
            queue_wait_ms = (t_flush - ticket.t_submit) * 1e3
            ticket.stage_ms = {
                "queue_wait": queue_wait_ms,
                "assemble": assemble_ms,
                "compute": compute_ms,
            }
            if wait_hist is not None:
                wait_hist.record(queue_wait_ms)
            req_record((now - ticket.t_submit) * 1e3)
            ticket._resolve(float(scores[i]))
        tel.count("queue.completed", n)
        tel.record_batch(n, self.lanes, oldest_wait_ms)
        if self.placement.is_sharded:
            # real rows pack from lane 0, so contiguous-block sharding puts
            # device d's fill at rows [d*lpd, (d+1)*lpd) — gauge it so
            # per-flush mesh imbalance is observable
            lpd = self.lanes // self.placement.data_shards
            self.telemetry.gauge_vec(
                "queue.device_fill",
                [min(max(n - d * lpd, 0), lpd) / lpd
                 for d in range(self.placement.data_shards)],
            )
        return n

    # -- convenience ------------------------------------------------------

    def score(self, windows: Sequence) -> np.ndarray:
        """Submit + flush a list of (T, F) windows synchronously; returns
        their scores in submission order (flushing mid-way under
        backpressure instead of failing)."""
        tickets = []
        for w in windows:
            try:
                tickets.append(self.submit(w))
            except GatewayOverloadedError:
                self.flush()
                tickets.append(self.submit(w))
        self.flush()
        return np.array([t.score for t in tickets], np.float32)

"""Micro-batching request queue for one-shot scoring.

One-shot ``score`` requests (a single (T, F) window each) are coalesced
into padded, shape-bucketed micro-batches — the serving-layer analogue of
the paper's inter-module FIFOs keeping the datapath fed.  Requests bucket
by sequence length (next power-of-two ladder), pad to the bucket
boundary, and flush when a bucket reaches ``max_batch`` or its oldest
request has waited ``max_wait_ms``.  Every flush runs the engine's
masked-score program on a FIXED (max_batch, bucket_T, F) shape, so each
bucket compiles exactly once; padding lanes are masked out of the scores
(LSTM causality makes end-padding exact, see ``Engine.score_masked``).

Backpressure: ``submit`` raises :class:`GatewayOverloadedError` once
``max_queue`` requests are pending — admission control, not silent
buffering.  The queue is caller-driven (call :meth:`pump` from the serve
loop) and single-threaded by design; ``clock`` is injectable for tests.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.engine.base import Engine
from repro.gateway.telemetry import Telemetry

# bucket ladder for sequence lengths; lengths beyond the last rung double
_BUCKET_LADDER = (8, 16, 32, 64, 128, 256, 512, 1024)


class GatewayOverloadedError(RuntimeError):
    """The request queue is full (``max_queue`` pending) — shed or retry."""


class Ticket:
    """Handle for one submitted request; resolved at flush time."""

    __slots__ = ("t_submit", "_score")

    def __init__(self, t_submit: float):
        self.t_submit = t_submit
        self._score: Optional[float] = None

    @property
    def done(self) -> bool:
        return self._score is not None

    @property
    def score(self) -> float:
        if self._score is None:
            raise RuntimeError("request not scored yet; pump()/flush() the queue")
        return self._score


def bucket_for(t: int, ladder: Sequence[int] = _BUCKET_LADDER) -> int:
    """Smallest bucket boundary >= t (doubling past the ladder's end)."""
    for b in ladder:
        if t <= b:
            return b
    b = ladder[-1]
    while b < t:
        b *= 2
    return b


class MicroBatcher:
    """Shape-bucketed micro-batching over ``Engine.score_masked``."""

    def __init__(
        self,
        engine: Engine,
        *,
        max_batch: int = 32,
        max_wait_ms: float = 5.0,
        max_queue: int = 1024,
        telemetry: Optional[Telemetry] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.engine = engine
        self.features = engine.cfg.lstm_ae.input_features
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.max_queue = max_queue
        self.telemetry = telemetry or Telemetry()
        self._clock = clock
        # bucket_T -> FIFO of (series (T,F) float32, ticket)
        self._buckets: dict[int, list[tuple[np.ndarray, Ticket]]] = {}
        self._depth = 0

    @property
    def queue_depth(self) -> int:
        return self._depth

    # -- intake -----------------------------------------------------------

    def submit(self, series) -> Ticket:
        """Enqueue one (T, F) window for scoring; returns its ticket.

        Raises :class:`GatewayOverloadedError` when ``max_queue`` requests
        are already pending (backpressure) and ValueError on shape
        mismatch.  A bucket reaching ``max_batch`` flushes immediately.
        """
        arr = np.asarray(series, np.float32)
        if arr.ndim != 2 or arr.shape[1] != self.features:
            raise ValueError(
                f"expected a (T, {self.features}) window, got shape {arr.shape}"
            )
        if arr.shape[0] < 1:
            raise ValueError("empty window (T == 0)")
        if self._depth >= self.max_queue:
            self.telemetry.count("queue.rejected")
            raise GatewayOverloadedError(
                f"queue full ({self.max_queue} pending); pump() or shed load"
            )
        ticket = Ticket(self._clock())
        tb = bucket_for(arr.shape[0])
        self._buckets.setdefault(tb, []).append((arr, ticket))
        self._depth += 1
        self.telemetry.count("queue.submitted")
        self.telemetry.gauge("queue.depth", self._depth)
        if len(self._buckets[tb]) >= self.max_batch:
            self._flush_bucket(tb)
        return ticket

    # -- flushing ---------------------------------------------------------

    def pump(self, now: Optional[float] = None) -> int:
        """Flush every bucket that is full or whose oldest request has
        waited ``max_wait_ms``; returns the number of requests completed.
        The serve loop calls this between I/O events."""
        now = self._clock() if now is None else now
        completed = 0
        for tb in list(self._buckets):
            pending = self._buckets.get(tb)
            if not pending:
                continue
            waited_ms = (now - pending[0][1].t_submit) * 1e3
            if len(pending) >= self.max_batch or waited_ms >= self.max_wait_ms:
                completed += self._flush_bucket(tb)
        return completed

    def flush(self) -> int:
        """Flush everything pending regardless of age; returns count."""
        completed = 0
        for tb in list(self._buckets):
            while self._buckets.get(tb):
                completed += self._flush_bucket(tb)
        return completed

    def _flush_bucket(self, tb: int) -> int:
        pending = self._buckets[tb]
        take, self._buckets[tb] = pending[: self.max_batch], pending[self.max_batch:]
        if not take:
            return 0
        n = len(take)
        # fixed (max_batch, tb, F) shape: one compile per bucket, ever
        x = np.zeros((self.max_batch, tb, self.features), np.float32)
        lengths = np.ones((self.max_batch,), np.int32)  # padding lanes: 1, masked anyway
        for i, (arr, _) in enumerate(take):
            x[i, : arr.shape[0]] = arr
            lengths[i] = arr.shape[0]
        scores = np.asarray(
            self.engine.score_masked({"series": x, "lengths": lengths})
        )
        now = self._clock()
        oldest_wait_ms = (now - take[0][1].t_submit) * 1e3
        for i, (_, ticket) in enumerate(take):
            ticket._score = float(scores[i])
            self.telemetry.observe_latency_ms((now - ticket.t_submit) * 1e3)
        self._depth -= n
        self.telemetry.count("queue.completed", n)
        self.telemetry.record_batch(n, self.max_batch, oldest_wait_ms)
        self.telemetry.gauge("queue.depth", self._depth)
        return n

    # -- convenience ------------------------------------------------------

    def score(self, windows: Sequence) -> np.ndarray:
        """Submit + flush a list of (T, F) windows synchronously; returns
        their scores in submission order (flushing mid-way under
        backpressure instead of failing)."""
        tickets = []
        for w in windows:
            try:
                tickets.append(self.submit(w))
            except GatewayOverloadedError:
                self.flush()
                tickets.append(self.submit(w))
        self.flush()
        return np.array([t.score for t in tickets], np.float32)

"""Streaming anomaly gateway: micro-batched serving over the execution
engine (ROADMAP follow-up "batched/async request queueing").

One :class:`AnomalyGateway` fronts an :class:`~repro.engine.AnomalyService`
(or a bare bound :class:`~repro.engine.Engine`) with the two serving
surfaces the paper's deployment needs:

* **streaming sessions** — ``admit / step / evict / reset`` on a
  fixed-capacity :class:`~repro.gateway.pool.SessionPool`: up to
  ``capacity`` concurrent streams share ONE compiled masked step over the
  pooled state block, so thousands of logical streams churn through
  without retracing (the software analogue of the paper's always-fed
  datapath).
* **one-shot scoring** — ``submit / pump / score`` on a
  :class:`~repro.gateway.queue.MicroBatcher`: requests are shape-bucketed
  by sequence length, padded to bucket boundaries, flushed on
  ``max_batch``/``max_wait_ms``, and rejected with
  :class:`GatewayOverloadedError` once ``max_queue`` are pending.

``gateway.stats()`` surfaces the shared :class:`Telemetry` (queue depth,
batch-fill ratio, p50/p95 latency, per-schedule throughput).

Both surfaces are placement-aware: under a sharded
:class:`~repro.engine.placement.Placement` (``open_gateway(placement=
Placement.data(N))`` or ``AnomalyGateway(..., placement=N)``) the pool's
slot block distributes over the data mesh (capacity scales to
``slots_per_device x mesh_size``), bucket flushes score data-parallel
padded to a per-device multiple, and ``stats()`` gains a ``placement``
section with per-device slot occupancy and flush fill.  The single
placement is a strict no-op.

A live deployment fronts the gateway with the asyncio JSON-lines
transport in :mod:`repro.gateway.server` (background pump, one pool
session per connection) and refreshes the detector in place via
:meth:`AnomalyGateway.recalibrate` — no drain required.
"""
from __future__ import annotations

import time
from typing import Callable, Hashable, Mapping, Optional, Sequence, Union

import numpy as np

from repro.engine.base import Engine
from repro.engine.placement import Placement
from repro.engine.schedules import schedule_cache_info
from repro.gateway.pool import PoolFullError, SessionPool, UnknownStreamError
from repro.gateway.queue import GatewayOverloadedError, MicroBatcher, Ticket, bucket_for
from repro.gateway.telemetry import Telemetry
from repro.obs import EventLog, Tracer

_UNSET = object()


class AnomalyGateway:
    """Session pool + micro-batching queue + telemetry over one engine."""

    def __init__(
        self,
        service_or_engine,
        *,
        capacity: int = 32,
        max_batch: int = 32,
        max_wait_ms: float = 5.0,
        max_queue: int = 1024,
        max_seq_len: Optional[int] = None,
        placement: Optional["object"] = None,
        clock: Callable[[], float] = time.monotonic,
        obs_detail: bool = True,
    ):
        engine = getattr(service_or_engine, "engine", service_or_engine)
        if not isinstance(engine, Engine):
            raise TypeError(
                f"expected AnomalyService or Engine, got {type(service_or_engine)!r}"
            )
        engine._require_params()  # fail fast: a gateway serves a bound model
        self.service = service_or_engine if service_or_engine is not engine else None
        if placement is not None:
            if isinstance(placement, int):  # shorthand: N -> Placement.data(N)
                placement = Placement.data(placement)
            if not isinstance(placement, Placement):
                raise TypeError(
                    f"placement must be a Placement or int, got {type(placement)!r}"
                )
            # re-lay the engine's programs out on the requested mesh; a
            # matching placement returns the engine itself (strict no-op).
            # The fronted service keeps its own engine — recalibrate()
            # rebinds both so the two views never diverge.
            engine = engine.with_placement(placement)
        self.engine = engine
        if self.service is not None:
            # let the service rebind this gateway's engine on fit /
            # recalibrate — a placement override gives the gateway its own
            # Engine, which must never serve stale params
            registry = getattr(self.service, "_gateways", None)
            if registry is not None:
                registry.add(self)
        self._threshold: Optional[float] = None  # used when fronting a bare Engine
        # session durability is opt-in: repro.gateway.durability's
        # enable_durability() attaches a DurableSessions coordinator here
        # and the transport/stats pick it up; None keeps PR-5 semantics
        self.durability = None
        # the control plane is opt-in the same way: repro.control's
        # enable_control() attaches a GatewayControl here (priority
        # admission gate on submit(), SLO batching ticks on the pump);
        # None keeps flat admission and static knobs
        self.control = None
        # observability plane: per-stage histograms gate on ``obs_detail``
        # (the obs_overhead benchmark's off arm), the tracer produces
        # spans for requests that opt in with a wire ``trace`` field, and
        # the event log is a no-op until attach_event_log() points it at
        # a JSONL file
        self.telemetry = Telemetry(clock=clock, detail=obs_detail)
        self.events = EventLog(None)
        self.tracer = Tracer(clock=clock, events=self.events)
        self.pool = SessionPool(engine, capacity, telemetry=self.telemetry)
        self.batcher = MicroBatcher(
            engine, max_batch=max_batch, max_wait_ms=max_wait_ms,
            max_queue=max_queue, max_seq_len=max_seq_len,
            telemetry=self.telemetry, clock=clock,
        )

    # -- streaming sessions (pool) ----------------------------------------

    def admit(self, stream_id: Hashable) -> int:
        return self.pool.admit(stream_id)

    def evict(self, stream_id: Hashable) -> float:
        return self.pool.evict(stream_id)

    def reset(self, stream_id: Hashable) -> None:
        self.pool.reset(stream_id)

    def step(self, inputs: Mapping[Hashable, "object"]) -> dict:
        return self.pool.step(inputs)

    # -- one-shot scoring (micro-batcher) ---------------------------------

    def submit(self, series, *, priority=None, tenant=None) -> Ticket:
        """Enqueue one (T, F) window.  ``priority`` (0 = highest) and
        ``tenant`` are consulted only when a control plane is attached —
        without one (or with ``priority=None``) this is exactly the flat
        PR-5 path: first come, first queued, shed at ``max_queue``."""
        if self.control is not None:
            self.control.admit(priority=priority, tenant=tenant)
        return self.batcher.submit(series)

    def pump(self, now: Optional[float] = None) -> int:
        return self.batcher.pump(now)

    def flush(self) -> int:
        return self.batcher.flush()

    def score(self, windows: Sequence) -> "object":
        return self.batcher.score(windows)

    # -- live recalibration ------------------------------------------------

    @property
    def threshold(self) -> Optional[float]:
        """The detector threshold alerts compare against (None before any
        calibration).  Lives on the fronted service when there is one."""
        if self.service is not None:
            return self.service.threshold
        return self._threshold

    def recalibrate(
        self, *, threshold=_UNSET, params: Optional["object"] = None
    ) -> dict:
        """Swap the detection threshold and/or model params in place.

        The swap is atomic from the serving paths' point of view: resident
        pool streams keep their slots, carried ``(h, c)`` state and running
        errors, and queued one-shot requests stay queued — each pool step /
        flush reads the engine's *current* params and each alert decision
        reads the *current* threshold, so new values simply apply from the
        next operation on.  No drain, no eviction (the ROADMAP's
        "threshold/calibration refresh without draining sessions").

        ``threshold`` may be a float or None (disable alerting); omit it to
        leave the threshold untouched.  ``params`` rebinds the engine (and
        the fronted service, keeping the two views consistent).  Returns
        ``{"threshold": ..., "params_swapped": ...}``.
        """
        if params is not None:
            # one swap path for every view: the service's _bind rebinds its
            # own engine AND every registered gateway engine (placement
            # overrides included), so no sibling gateway serves stale params
            binder = getattr(self.service, "_bind", None)
            if binder is not None:
                binder(params)
            else:  # fronting a bare Engine (or a duck-typed service)
                self.engine.bind(params)
                if self.service is not None:
                    self.service.params = params
        if threshold is not _UNSET:
            value = None if threshold is None else float(threshold)
            if self.service is not None:
                self.service.threshold = value
            else:
                self._threshold = value
        self.telemetry.count("gateway.recalibrated")
        self.events.emit(
            "recalibrate",
            threshold=self.threshold,
            params_swapped=params is not None,
        )
        if self.durability is not None:
            # resumption tokens carry the recalibration epoch so a client
            # can tell its scores straddled a swap (state itself is
            # carried through unchanged, same as for live sessions)
            self.durability.epoch += 1
        return {"threshold": self.threshold, "params_swapped": params is not None}

    # -- observability ----------------------------------------------------

    def attach_event_log(self, path) -> EventLog:
        """Point the gateway's JSONL event log (lifecycle events + sampled
        spans) at ``path``; the tracer follows automatically.  Passing
        None detaches (back to the no-op log)."""
        old = self.events
        self.events = EventLog(path)
        self.tracer.events = self.events
        old.close()
        return self.events

    @property
    def placement(self) -> Placement:
        """The device placement the gateway's serving programs run on."""
        return self.engine.placement

    def stats(self) -> dict:
        out = self.telemetry.stats()
        out.update(
            schedule=self.engine.schedule.tag,
            capacity=self.pool.capacity,
            active_streams=self.pool.active,
            queue_depth=self.batcher.queue_depth,
            max_batch=self.batcher.max_batch,
            max_seq_len=self.batcher.max_seq_len,
            features=self.batcher.features,
            threshold=self.threshold,
        )
        # compile visibility: per-program/per-shape compile counts + wall
        # time from the engine, resolve-cache hit/miss from the registry —
        # recompile storms on the bucket ladder show up here
        out["engine"] = {
            **self.engine.profile_info(),
            "schedule_cache": schedule_cache_info(),
        }
        if self.placement.is_sharded:
            # mesh-layout view: static layout + live per-device residency;
            # the matching per-flush fill history lives in the gauges
            # (queue.device_fill / pool.device_active).  Absent under the
            # single placement so single-device telemetry is unchanged.
            out["placement"] = {
                **self.placement.describe(),
                "slots_per_device": self.pool.slots_per_device,
                "score_lanes": self.batcher.lanes,
                "device_active": self.pool.per_device_active(),
            }
        if self.durability is not None:
            out["durability"] = self.durability.describe()
        if self.control is not None:
            out["control"] = self.control.describe()
        return out

    def __repr__(self) -> str:
        pl = (f", placement={self.placement!r}"
              if self.placement.is_sharded else "")
        return (f"AnomalyGateway(schedule={self.engine.schedule.tag}, "
                f"capacity={self.pool.capacity}, active={self.pool.active}, "
                f"queue_depth={self.batcher.queue_depth}{pl})")


def drive_stream_churn(
    gateway: AnomalyGateway, windows, churn_every: int = 8
) -> tuple[dict, list]:
    """Demo/benchmark driver: stream N logical series through the pool.

    ``windows`` is (N, T, F); up to ``capacity`` streams are admitted, all
    residents step each timestep, and every ``churn_every`` steps the
    oldest resident is evicted for a waiting stream (late admits score
    their series' tail — slot churn, the behaviour under test).  Returns
    ``(finals, unserved)``: {stream index: final running error} for every
    served stream, plus the indices still waiting when the driver ran out
    of timesteps (only capacity + (T-1)//churn_every streams can be
    served) — callers must report those, not drop them silently.  Shared
    by ``launch/serve --gateway`` and ``examples/serve_anomaly_stream.py``;
    a real deployment drives admit/step/evict from its transport instead.
    """
    windows = np.asarray(windows, np.float32)
    n, t_len, _ = windows.shape
    resident = list(range(min(gateway.pool.capacity, n)))
    waiting = list(range(len(resident), n))
    finals: dict = {}
    for sid in resident:
        gateway.admit(sid)
    for t in range(t_len):
        gateway.step({sid: windows[sid, t] for sid in resident})
        if waiting and t and t % churn_every == 0:
            old = resident.pop(0)
            finals[old] = gateway.evict(old)
            nxt = waiting.pop(0)
            gateway.admit(nxt)
            resident.append(nxt)
    for sid in resident:
        finals[sid] = gateway.evict(sid)
    return finals, waiting


__all__ = [
    "AnomalyGateway",
    "drive_stream_churn",
    "GatewayOverloadedError",
    "MicroBatcher",
    "Placement",
    "PoolFullError",
    "SessionPool",
    "Telemetry",
    "Ticket",
    "UnknownStreamError",
    "bucket_for",
]

"""Async JSON-lines transport in front of :class:`AnomalyGateway`.

The paper's accelerator wins because its datapath is always fed; the
in-process gateway reproduces that only while some caller keeps pumping
the micro-batch queue.  :class:`GatewayServer` closes that gap: an
asyncio socket server whose *background pump task* flushes age-triggered
micro-batches on its own clock, so one-shot latency is bounded by
``max_wait_ms`` — not by when the next request happens to arrive.

Wire protocol — one JSON object per line (UTF-8, ``\\n``-terminated) in
each direction.  Every request may carry an ``id``, echoed verbatim in
its response; responses to ``score`` arrive when the micro-batcher
flushes, i.e. possibly *after* responses to later requests — match on
``id``, not on order.

======================  ==================================================
request                 response
======================  ==================================================
``{"op": "step",        ``{"ok": true, "op": "step",
"x": [f_0 .. f_F-1]}``  "running_error": .., "alert": ..?}`` — advances
                        this connection's pool session one timestep
                        (admitted on first step; the connection IS the
                        stream).
``{"op": "close"}``     ``{"ok": true, "op": "close", "final": ..,
                        "alert": ..?}`` — evicts the session (final
                        running error); a later ``step`` starts a fresh
                        one.  Dropping the connection evicts too, the
                        final score is just unreported.
``{"op": "score",       ``{"ok": true, "op": "score", "score": ..,
"series": [[..] ..]}``  "alert": ..?}`` — one-shot (T, F) window through
                        the micro-batcher; the response is written when
                        the ticket's future completes (flush by size,
                        by the background pump, or at drain).  Optional
                        ``priority`` (int, 0 = highest class) and
                        ``tenant`` (string) fields feed the admission
                        controller when a control plane is attached;
                        both are ignored otherwise (backward compatible
                        like ``trace``) and omitting them is exactly
                        the pre-control wire protocol.
``{"op":                ``{"ok": true, "op": "recalibrate",
"recalibrate",          "threshold": .., "params_swapped": false}`` —
"threshold": ..}``      live threshold swap, resident sessions keep
                        serving (param swaps are in-process only).
``{"op": "stats"}``     ``{"ok": true, "op": "stats", "stats": {..}}`` —
                        under a sharded placement the snapshot includes a
                        ``placement`` section (mesh layout, per-device
                        slot occupancy) plus ``pool.device_active`` /
                        ``queue.device_fill`` gauges, so mesh imbalance
                        is observable over the wire.  Behind a
                        multi-worker front (:mod:`repro.gateway.workers`)
                        the snapshot is AGGREGATED over all workers:
                        counters/capacities sum, and a ``workers``
                        section carries per-worker detail plus
                        restart/session-loss accounting.
``{"op": "ping"}``      ``{"ok": true, "op": "ping"}``
``{"op": "resume",      ``{"ok": true, "op": "resume", "seq": ..,
"token": ..}``          "running_error": .., "token": ..}`` — revive a
                        durable session from its resumption token onto
                        THIS connection (any worker of a front); the
                        client then replays its buffered steps with
                        ``seq`` greater than the returned position.
``{"op": "snapshot"}``  ``{"ok": true, "op": "snapshot",
                        "sessions": .., "bytes": ..}`` — force one
                        synchronous durability snapshot (control op for
                        tests/ops; the background pump snapshots on its
                        own cadence).
======================  ==================================================

With durability enabled (``gateway.durability`` attached via
:func:`repro.gateway.durability.enable_durability`) every ``step``
response additionally carries ``seq`` (the session's timestep count) and
``token`` (a fresh signed resumption token); abrupt connection drops
PARK the session (resumable) instead of discarding it, and ``drain()``
takes a final handoff snapshot so rolling restarts lose zero sessions.
``resume``/``snapshot`` against a server without durability fail with
``ValueError``; token rejections answer with the token error class name
(``TamperedTokenError`` / ``ExpiredTokenError`` / ``UnknownSessionError``
/ ``SessionActiveError``) in the ``error`` field.

Failures answer ``{"ok": false, "op": .., "error": "<ExceptionName>",
"message": ..}`` on the same ``id`` — ``GatewayOverloadedError`` /
``PoolFullError`` for backpressure, ``ValueError`` for malformed or
oversized windows, and whatever the engine raised for tickets failed
mid-flush (future-style error completion, the queue keeps serving).

Binary transport (bp1) — the JSON-lines protocol above stays the
negotiated fallback, but the hot path is the length-prefixed binary
frame format of :mod:`repro.gateway.wire`.  A client that opens the
connection with the 4-byte ``bp1`` preamble switches the connection to
frame mode: the server answers a ``HELLO`` response frame and from then
on reads fixed 20-byte headers + raw payloads (``readexactly``, no line
scanning).  ``SCORE``/``STEP`` frames carry float32 payloads that land
in the micro-batcher via ``np.frombuffer`` views — no float lists — and
one ``SCORE`` frame may carry *n* same-shape windows (pipelined batched
submit; the response frame returns *n* float32 scores when the last
ticket completes).  Every other opcode is a generic meta frame whose
JSON ``meta`` is exactly the dict the JSON protocol would carry, so the
``_op_*`` handlers below serve both protocols unchanged (drain
semantics, resumption tokens, priority/tenant admission included).
Per-protocol traffic is visible in telemetry as ``wire.req_json`` /
``wire.req_bp1`` counters (and ``wire.conn_*`` per connection); the
``wire_ms`` stage histogram covers both dispatch paths.  Constructing
the server with ``enable_binary=False`` ignores the preamble and
behaves byte-for-byte like the PR 3 JSON-lines server (that is also
what proves client fallback in tests).

Concurrency model: everything touching the gateway (handlers + pump)
runs on ONE event loop, preserving the gateway's single-threaded
contract; JAX calls block the loop for one step/flush at a time, which
is the micro-batching granularity anyway.  ``drain()`` is the graceful
shutdown: stop accepting, flush the queue so every pending ticket
answers, then evict sessions and close connections.
"""
from __future__ import annotations

import asyncio
import json
import logging
import signal
import threading
from typing import Callable, Optional

import numpy as np

from repro.gateway import AnomalyGateway, wire

logger = logging.getLogger(__name__)

#: What the server's readline loop sees when a binary client opens with
#: ``wire.PREAMBLE`` (readline keeps the ``\n``; dispatch strips it).
_PREAMBLE_LINE = wire.PREAMBLE.rstrip(b"\n")


def _error_payload(op: str, exc: BaseException) -> dict:
    return {
        "ok": False,
        "op": op,
        "error": type(exc).__name__,
        "message": str(exc),
    }


class GatewayServer:
    """Serve an :class:`AnomalyGateway` over asyncio JSON-lines sockets.

    >>> server = GatewayServer(svc.open_gateway(capacity=32), port=0)
    >>> host, port = server.start_in_thread()     # tests/benchmarks
    >>> # ... or await server.start() inside a running loop
    >>> server.stop_in_thread()                   # drain + shut down

    ``port=0`` binds an ephemeral port (read it back from ``server.port``
    after start).  The background pump runs every ``pump_interval_ms``
    (default: half the batcher's ``max_wait_ms``) so age-triggered
    flushes never wait on request arrival.
    """

    def __init__(
        self,
        gateway: AnomalyGateway,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        pump_interval_ms: Optional[float] = None,
        max_line_bytes: int = 16 << 20,
        reuse_port: bool = False,
        stats_provider: Optional[Callable] = None,
        recalibrate_provider: Optional[Callable] = None,
        enable_binary: bool = True,
    ):
        if not isinstance(gateway, AnomalyGateway):
            raise TypeError(f"expected AnomalyGateway, got {type(gateway)!r}")
        self.gateway = gateway
        self.host = host
        self.port = port
        # multi-worker mode (repro.gateway.workers): several servers bind
        # the same port with SO_REUSEPORT and the kernel load-balances
        # connections; stats/recalibrate then answer for the whole front
        # via the providers (which may return an awaitable — the fan-out
        # crosses a control pipe) instead of this process's gateway alone
        self.reuse_port = reuse_port
        self.stats_provider = stats_provider
        self.recalibrate_provider = recalibrate_provider
        # generous line limit: a max_seq_len x F window as JSON text is
        # ~20 bytes/float; the gateway's own admission limits do the real
        # policing, this just keeps asyncio from resetting the connection
        self.max_line_bytes = max_line_bytes
        # enable_binary=False replays the PR 3 JSON-lines-only behaviour
        # (the bp1 preamble is then just an undecodable line) — used by
        # tests to prove client auto-negotiation falls back cleanly
        self.enable_binary = enable_binary
        if pump_interval_ms is None:
            pump_interval_ms = max(0.5, gateway.batcher.max_wait_ms / 2.0)
        self.pump_interval_s = pump_interval_ms / 1e3
        self._server: Optional[asyncio.AbstractServer] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._handlers: set = set()
        self._writers: set = set()
        self._conn_seq = 0
        self._draining = False
        # thread-mode bookkeeping (start_in_thread)
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> tuple:
        """Bind the socket and start the background pump; returns
        ``(host, port)`` actually bound."""
        if self._server is not None:
            raise RuntimeError("server already started")
        self._draining = False
        extra = {"reuse_port": True} if self.reuse_port else {}
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port, limit=self.max_line_bytes,
            **extra,
        )
        self.host, self.port = self._server.sockets[0].getsockname()[:2]
        self._pump_task = asyncio.get_running_loop().create_task(self._pump_loop())
        self.gateway.events.emit("serve_start", host=self.host, port=self.port)
        return self.host, self.port

    async def drain(self, timeout: float = 10.0) -> None:
        """Graceful shutdown: stop accepting connections, flush the
        micro-batch queue (every pending ticket completes — scored or
        failed — and its response is written), then evict remaining
        sessions and close the connections."""
        self._draining = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        if self._pump_task is not None:
            self._pump_task.cancel()
            await asyncio.gather(self._pump_task, return_exceptions=True)
            self._pump_task = None
        try:
            self.gateway.flush()  # completes pending tickets -> responses go out
        except Exception:
            logger.exception("drain: final flush failed")
        if self.gateway.durability is not None:
            # snapshot-handoff BEFORE sessions are evicted at connection
            # teardown: every resident durable stream lands on disk, so a
            # rolling restart migrates instead of losing them
            try:
                self.gateway.durability.handoff()
            except Exception:
                logger.exception("drain: durability handoff failed")
        for writer in list(self._writers):
            try:
                if writer.can_write_eof():
                    writer.write_eof()
                writer.close()
            except Exception:
                logger.debug("writer close failed during drain",
                             exc_info=True)
        if self._handlers:  # handlers evict their sessions on the way out
            await asyncio.wait(self._handlers, timeout=timeout)
        self.gateway.events.emit(
            "drain", active_streams=self.gateway.pool.active,
            queue_depth=self.gateway.batcher.queue_depth,
        )

    async def serve_forever(self) -> None:
        if self._server is None:
            raise RuntimeError("call start() first")
        await self._server.serve_forever()

    async def run_until_signal(
        self, on_ready: Optional[Callable[["GatewayServer"], None]] = None
    ) -> None:
        """start() -> wait for SIGINT/SIGTERM -> drain().  The launcher's
        serve loop; smoke/CI assert clean shutdown by sending SIGTERM and
        checking the exit code."""
        await self.start()
        if on_ready is not None:
            on_ready(self)
        stop = asyncio.Event()
        loop = asyncio.get_running_loop()
        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                loop.add_signal_handler(sig, stop.set)
            except NotImplementedError:  # non-unix event loops
                signal.signal(sig, lambda *_: stop.set())
        await stop.wait()
        await self.drain()

    # -- thread mode (tests / benchmarks / notebooks) ----------------------

    def start_in_thread(self, ready_timeout: float = 30.0) -> tuple:
        """Run the server on a private event loop in a daemon thread;
        returns ``(host, port)``.  All gateway access happens on that
        loop's thread, preserving the single-threaded gateway contract."""
        ready = threading.Event()
        startup_error: list = []

        def _run():
            self._loop = asyncio.new_event_loop()
            asyncio.set_event_loop(self._loop)
            try:
                try:
                    self._loop.run_until_complete(self.start())
                except BaseException as exc:  # surface EADDRINUSE etc. to the
                    startup_error.append(exc)  # caller, don't die silently
                    return
                finally:
                    ready.set()
                self._loop.run_forever()
            finally:
                self._loop.close()

        self._thread = threading.Thread(
            target=_run, name="gateway-server", daemon=True
        )
        self._thread.start()
        if not ready.wait(ready_timeout):
            raise RuntimeError("gateway server failed to start in time")
        if startup_error:
            self._thread.join(ready_timeout)
            self._loop = None
            self._thread = None
            raise startup_error[0]
        return self.host, self.port

    def stop_in_thread(self, timeout: float = 10.0) -> None:
        """Drain the threaded server and stop its loop/thread.  ``timeout``
        budgets the drain itself; the cross-thread wait gets headroom on
        top so a slow-but-progressing drain (e.g. a final flush that still
        has to compile its bucket) is not aborted midway."""
        if self._loop is None:
            return
        future = asyncio.run_coroutine_threadsafe(self.drain(timeout), self._loop)
        try:
            future.result(timeout + 30.0)
        finally:
            self._loop.call_soon_threadsafe(self._loop.stop)
            if self._thread is not None:
                self._thread.join(timeout)
            self._loop = None
            self._thread = None

    # -- the pump ----------------------------------------------------------

    async def _pump_loop(self) -> None:
        # THE point of the transport: micro-batches flush on age without
        # any caller in the loop.  Engine failures fail their tickets
        # inside pump(); this guard only covers bookkeeping bugs so the
        # pump itself can never die and wedge the queue.
        while True:
            try:
                self.gateway.pump()
                if self.gateway.durability is not None:
                    # cadence snapshots ride the pump: skip (never block)
                    # while the previous background write is in flight
                    self.gateway.durability.maybe_snapshot()
                if self.gateway.control is not None:
                    # control ticks ride the pump too: the controller
                    # rate-limits itself via its tick interval
                    self.gateway.control.maybe_tick()
            except Exception:
                logger.exception("background pump failed; queue state kept")
            await asyncio.sleep(self.pump_interval_s)

    # -- connection handling ----------------------------------------------

    async def _handle(self, reader, writer) -> None:
        task = asyncio.current_task()
        self._handlers.add(task)
        self._writers.add(writer)
        self._conn_seq += 1
        conn = _Connection(self, self._conn_seq, writer)
        try:
            while not self._draining:
                try:
                    line = await reader.readline()
                except ValueError as exc:  # line past max_line_bytes: framing
                    conn.send(_error_payload("?", exc))  # is lost, hang up
                    break
                if not line:
                    break
                line = line.strip()
                if not line:
                    continue
                if self.enable_binary and line == _PREAMBLE_LINE:
                    # negotiation: the peer speaks bp1 — switch this
                    # connection to frame mode for the rest of its life
                    await self._serve_binary(reader, writer, conn)
                    break
                conn.dispatch(line)
                await writer.drain()
        except (ConnectionResetError, BrokenPipeError, asyncio.IncompleteReadError):
            pass
        finally:
            conn.end_session()
            self._writers.discard(writer)
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:
                pass
            self._handlers.discard(task)

    async def _serve_binary(self, reader, writer, conn: "_Connection") -> None:
        """Frame loop for a connection that sent the bp1 preamble.

        Greets with a HELLO response frame (the client's confirmation
        that negotiation succeeded), then reads frames with
        ``readexactly``.  A framing-level violation (bad magic/version,
        oversize length field) means byte alignment is lost: best-effort
        error notice, then hang up.  Payload-level problems are answered
        per-frame inside ``dispatch_frame`` and keep the connection.
        """
        conn.binary = True
        self.gateway.telemetry.count("wire.conn_bp1")
        conn.send_frame(
            wire.OP_HELLO,
            wire.NO_REQUEST_ID,
            meta={
                "ok": True,
                "op": "hello",
                "protocol": "bp1",
                "version": wire.VERSION,
                "max_frame_bytes": self.max_line_bytes,
                "features": self.gateway.pool.features,
            },
        )
        await writer.drain()
        while not self._draining:
            try:
                frame = await wire.read_frame(reader, self.max_line_bytes)
            except asyncio.IncompleteReadError:
                return  # peer hung up (possibly mid-frame); _handle tears down
            except wire.WireProtocolError as exc:
                conn.send_frame(
                    0, wire.NO_REQUEST_ID, meta=_error_payload("?", exc),
                    flags=wire.FLAG_RESPONSE | wire.FLAG_ERROR,
                )
                return
            conn.dispatch_frame(frame)
            await writer.drain()


class _FrameScores:
    """Collects the *n* tickets of one pipelined SCORE frame and answers
    the frame — one response, ``n`` float32 scores — when the last ticket
    completes.  Tickets complete independently (a size-trigger flush can
    fire DURING the submit loop), so completion is counted, not awaited.
    If any submit raises mid-frame the whole frame answers one error via
    the dispatch error path and the collector is cancelled so callbacks
    from already-submitted tickets stay silent."""

    __slots__ = ("conn", "rid", "n", "span", "scores", "pending", "error",
                 "dead", "stage_ms")

    def __init__(self, conn: "_Connection", rid: int, n: int, span=None):
        self.conn = conn
        self.rid = rid
        self.n = n
        self.span = span
        self.scores = np.zeros(n, np.float32)
        self.pending = n
        self.error: Optional[BaseException] = None
        self.dead = False
        self.stage_ms = None

    def bind(self, i: int):
        def _completed(ticket) -> None:
            self.done(i, ticket)

        return _completed

    def done(self, i: int, ticket) -> None:
        if ticket.failed:
            if self.error is None:
                self.error = ticket.exception()
        else:
            self.scores[i] = ticket.score
            self.stage_ms = ticket.stage_ms
        self.pending -= 1
        if self.pending == 0 and not self.dead:
            self.finish()

    def cancel(self) -> None:
        self.dead = True

    def finish(self) -> None:
        if self.error is not None:
            self.conn.send(_error_payload("score", self.error), self.rid)
            return
        meta = {"ok": True, "op": "score", "n": self.n}
        threshold = self.conn.gateway.threshold
        if threshold is not None:
            meta["alert"] = [bool(s > threshold) for s in self.scores.tolist()]
        if self.span is not None:
            for stage, ms in (self.stage_ms or {}).items():
                self.span.stage(stage, ms)
            meta["trace"] = self.conn.gateway.tracer.finish(self.span).to_wire()
        self.conn.send_frame(
            wire.OP_SCORE, self.rid, meta=meta, data=self.scores.tobytes()
        )


class _Connection:
    """Per-connection protocol state: at most one pool session (the
    connection is the stream) plus response writing for in-flight
    one-shot tickets."""

    def __init__(self, server: GatewayServer, conn_id: int, writer):
        self.server = server
        self.gateway = server.gateway
        self.conn_id = conn_id
        self.writer = writer
        self.session_seq = 0
        self.stream_id = None  # ("conn", id, generation) when resident
        self.binary = False  # flipped when the bp1 preamble negotiates
        self._counted = False  # wire.conn_* counter emitted once per conn
        # strong refs to in-flight control tasks: the loop only keeps
        # weak ones, so an unreferenced task can be GC-cancelled mid-op
        self._control_tasks: set = set()

    # -- transport out -----------------------------------------------------

    def send(self, payload: dict, rid=None) -> None:
        """Protocol-aware response write: a JSON line, or — after bp1
        negotiation — the same dict as a response frame's meta (which is
        what lets every ``_op_*`` handler serve both protocols)."""
        if self.binary:
            opcode = wire.OPCODE_BY_NAME.get(payload.get("op"), 0)
            flags = wire.FLAG_RESPONSE
            if not payload.get("ok", True):
                flags |= wire.FLAG_ERROR
            if not isinstance(rid, int) or not 0 <= rid <= wire.NO_REQUEST_ID:
                rid = wire.NO_REQUEST_ID
            self.send_frame(opcode, rid, meta=payload, flags=flags)
            return
        if rid is not None:
            payload["id"] = rid
        if self.writer.is_closing():
            return
        try:
            self.writer.write((json.dumps(payload) + "\n").encode())
        except Exception:
            logger.exception("conn %d: response write failed", self.conn_id)

    def send_frame(
        self, opcode: int, rid: int, meta: Optional[dict] = None,
        data: bytes = b"", flags: int = wire.FLAG_RESPONSE,
    ) -> None:
        if self.writer.is_closing():
            return
        try:
            self.writer.write(
                wire.pack_frame(opcode, rid, meta=meta, data=data, flags=flags)
            )
        except Exception:
            logger.exception("conn %d: frame write failed", self.conn_id)

    # -- dispatch ----------------------------------------------------------

    def dispatch(self, line: bytes) -> None:
        # server-side wire cost per request: JSON decode + handler +
        # response encode/queue (the transport tax minus kernel + client
        # time) — the ``wire_ms`` stage histogram when detail is on
        tel = self.gateway.telemetry
        t_in = tel.now() if tel.detail else 0.0
        tel.count("wire.req_json")
        if not self._counted:
            self._counted = True
            tel.count("wire.conn_json")
        try:
            req = json.loads(line)
            op = req.get("op")
        except (ValueError, AttributeError) as exc:
            self.send(_error_payload("?", exc))
            return
        rid = req.get("id")
        handler = getattr(self, f"_op_{op}", None) if isinstance(op, str) else None
        if handler is None:
            self.send(
                _error_payload(str(op), ValueError(f"unknown op {op!r}")), rid
            )
            return
        try:
            handler(req, rid)
        except Exception as exc:  # per-request isolation: one bad request
            self.send(_error_payload(op, exc), rid)  # never drops the conn
        if tel.detail:
            tel.observe_stage("wire_ms", (tel.now() - t_in) * 1e3)

    def dispatch_frame(self, frame: wire.Frame) -> None:
        """Binary-mode request dispatch.  SCORE/STEP get dedicated
        raw-float32 handlers; every other opcode rebuilds the JSON-era
        request dict from the frame's meta and reuses ``_op_*``."""
        tel = self.gateway.telemetry
        t_in = tel.now() if tel.detail else 0.0
        tel.count("wire.req_bp1")
        rid = frame.req_id
        op = wire.NAME_BY_OPCODE.get(frame.opcode)
        if op is None or frame.opcode == wire.OP_HELLO:
            # hello is the server's greeting, never a request op
            exc = ValueError(f"unknown opcode 0x{frame.opcode:02x}")
            self.send_frame(
                frame.opcode, rid, meta=_error_payload("?", exc),
                flags=wire.FLAG_RESPONSE | wire.FLAG_ERROR,
            )
            return
        try:
            meta, data = wire.split_payload(frame.payload)
        except wire.WireProtocolError as exc:
            # the length field was honest (we read a complete frame), so
            # stream alignment holds: answer an error, keep the conn
            self.send_frame(
                frame.opcode, rid, meta=_error_payload(op, exc),
                flags=wire.FLAG_RESPONSE | wire.FLAG_ERROR,
            )
            return
        try:
            if frame.opcode == wire.OP_SCORE:
                self._frame_score(meta, data, rid)
            elif frame.opcode == wire.OP_STEP:
                self._frame_step(meta, data, rid)
            else:
                req = dict(meta)
                req["op"] = op
                getattr(self, f"_op_{op}")(req, rid)
        except Exception as exc:  # same per-request isolation as dispatch()
            self.send(_error_payload(op, exc), rid)
        if tel.detail:
            tel.observe_stage("wire_ms", (tel.now() - t_in) * 1e3)

    def _frame_score(self, meta: dict, data, rid: int) -> None:
        """A SCORE frame: ``n`` windows of shape ``(t, f)`` as one raw
        float32 block.  ``np.frombuffer`` makes ``windows`` a view of
        the recv payload; the only copy happens when the batcher packs
        its bucket pad buffer."""
        if "series" in meta and not len(data):
            # JSON-style request tunneled through a generic meta frame
            # (client.request("score", series=...)) — slow path, but it
            # keeps every JSON request (trace included) expressible over
            # bp1; the response frames through the protocol-aware send()
            req = dict(meta)
            req["op"] = "score"
            self._op_score(req, rid)
            return
        n = meta.get("n", 1)
        t = meta.get("t")
        f = meta.get("f", self.gateway.pool.features)
        if (not isinstance(n, int) or not isinstance(t, int)
                or not isinstance(f, int) or n < 0 or t < 1 or f < 1):
            raise ValueError(
                f"score frame needs integer meta n>=0, t>=1, f>=1; "
                f"got n={n!r} t={t!r} f={f!r}"
            )
        if n == 0:
            # an empty pipelined batch is legal and answers immediately
            self.send_frame(
                wire.OP_SCORE, rid, meta={"ok": True, "op": "score", "n": 0}
            )
            return
        windows = wire.decode_f32(data, (n, t, f))
        tid = meta.get("trace")
        span = (self.gateway.tracer.start("score", trace_id=str(tid))
                if tid is not None and n == 1 else None)
        if span is not None:
            span.mark("dispatch")
        collector = _FrameScores(self, rid, n, span)
        priority = meta.get("priority")
        tenant = meta.get("tenant")
        try:
            for i in range(n):
                ticket = self.gateway.submit(
                    windows[i], priority=priority, tenant=tenant
                )
                ticket.add_done_callback(collector.bind(i))
        except Exception:
            collector.cancel()  # one error answers the whole frame
            raise

    def _frame_step(self, meta: dict, data, rid: int) -> None:
        """A STEP frame: ``t`` consecutive samples for this connection's
        session in one frame (amortizes the round-trip; the response
        returns every intermediate running error).  Durable sessions get
        their ``seq``/``token`` from the LAST sample, which is exactly
        what a replaying client needs."""
        feats = self.gateway.pool.features
        if "x" in meta and not len(data):
            # JSON-style request tunneled through a generic meta frame
            req = dict(meta)
            req["op"] = "step"
            self._op_step(req, rid)
            return
        k = meta.get("t", 1)
        if not isinstance(k, int) or k < 1:
            raise ValueError(f"step frame needs integer meta t>=1, got {k!r}")
        count = len(data) // 4
        if k == 1 and count != feats:
            # same message the JSON protocol's shape check produces
            raise ValueError(
                f"expected sample shape ({feats},), got ({count},)"
            )
        xs = wire.decode_f32(data, (k, feats))
        if self.stream_id is None:
            dur = self.gateway.durability
            if dur is not None:
                self.stream_id, _ = dur.admit()
            else:
                self.session_seq += 1
                sid = ("conn", self.conn_id, self.session_seq)
                self.gateway.admit(sid)
                self.stream_id = sid
        errors = np.zeros(k, np.float32)
        seq = token = None
        dur = self._durable
        for i in range(k):
            if dur is not None:
                running, seq, token = dur.step(self.stream_id, xs[i])
            else:
                running = self.gateway.step({self.stream_id: xs[i]})[self.stream_id]
            errors[i] = running
        meta_out = {"ok": True, "op": "step", "t": k,
                    "running_error": float(errors[-1])}
        if token is not None:
            meta_out["seq"] = seq
            meta_out["token"] = token
        threshold = self.gateway.threshold
        if threshold is not None:
            meta_out["alert"] = [bool(e > threshold) for e in errors.tolist()]
        self.send_frame(wire.OP_STEP, rid, meta=meta_out, data=errors.tobytes())

    def _alert_field(self, payload: dict, value: float) -> dict:
        threshold = self.gateway.threshold
        if threshold is not None:
            payload["alert"] = bool(value > threshold)
        return payload

    # -- streaming session ops --------------------------------------------

    @property
    def _durable(self):
        """The DurableSessions coordinator IF this connection's session is
        a durable one (durable ids are strings; legacy per-connection ids
        are tuples, so a server whose durability was enabled mid-flight
        never mixes the two paths on one session)."""
        dur = self.gateway.durability
        if dur is not None and isinstance(self.stream_id, str):
            return dur
        return None

    def _op_step(self, req: dict, rid) -> None:
        # optional tracing: a "trace" field opts this request into a span
        # (unknown to PR-3 peers, ignored by them — backward compatible)
        tid = req.get("trace")
        span = (self.gateway.tracer.start("step", trace_id=str(tid))
                if tid is not None else None)
        # validate the payload BEFORE admitting: a malformed first step
        # must not pin a pool slot that never serves
        x = np.asarray(req["x"], np.float32)
        feats = self.gateway.pool.features
        if x.shape != (feats,):
            raise ValueError(f"expected sample shape ({feats},), got {x.shape}")
        dur = self.gateway.durability
        if self.stream_id is None:
            if dur is not None:
                self.stream_id, _ = dur.admit()  # PoolFullError -> error resp
            else:
                self.session_seq += 1
                sid = ("conn", self.conn_id, self.session_seq)
                self.gateway.admit(sid)
                self.stream_id = sid
        if span is not None:
            span.mark("dispatch")
        if self._durable is not None:
            running, seq, token = self._durable.step(self.stream_id, x)
            payload = {"ok": True, "op": "step", "running_error": running,
                       "seq": seq, "token": token}
        else:
            running = self.gateway.step({self.stream_id: x})[self.stream_id]
            payload = {"ok": True, "op": "step", "running_error": running}
        if span is not None:
            span.mark("compute")
            payload["trace"] = self.gateway.tracer.finish(span).to_wire()
        self.send(self._alert_field(payload, running), rid)

    def _op_close(self, req: dict, rid) -> None:
        if self.stream_id is None:
            raise ValueError("no open session on this connection (step first)")
        if self._durable is not None:
            final = self._durable.close(self.stream_id)  # forgotten: tokens die
        else:
            final = self.gateway.evict(self.stream_id)
        self.stream_id = None
        self.send(
            self._alert_field({"ok": True, "op": "close", "final": final}, final), rid
        )

    def _op_resume(self, req: dict, rid) -> None:
        dur = self.gateway.durability
        if dur is None:
            raise ValueError("durability is not enabled on this server")
        if self.stream_id is not None:
            raise ValueError(
                "this connection already carries a session; close it "
                "before resuming another"
            )
        out = dur.resume(req["token"])  # token errors -> dispatch error path
        self.stream_id = out["sid"]
        payload = {"ok": True, "op": "resume", "seq": out["seq"],
                   "running_error": out["running_error"],
                   "token": out["token"]}
        self.send(self._alert_field(payload, out["running_error"]), rid)

    def end_session(self) -> None:
        """Connection teardown: a durable session is PARKED (exact state,
        resumable by token); a legacy session is evicted (the final score
        is unreported on abrupt drops)."""
        if self.stream_id is None:
            return
        try:
            if self._durable is not None:
                self._durable.suspend(self.stream_id)
            else:
                self.gateway.evict(self.stream_id)
        except Exception:
            logger.exception("conn %d: eviction at teardown failed", self.conn_id)
        finally:
            self.stream_id = None

    # -- one-shot scoring --------------------------------------------------

    def _op_score(self, req: dict, rid) -> None:
        tid = req.get("trace")
        span = (self.gateway.tracer.start("score", trace_id=str(tid))
                if tid is not None else None)
        series = np.asarray(req["series"], np.float32)
        if span is not None:
            # decode + validation; marked BEFORE submit so an inline
            # size-trigger flush is attributed to the ticket's own
            # queue_wait/assemble/compute stages, never double-counted
            span.mark("dispatch")
        # optional admission fields (None for legacy clients -> flat path)
        ticket = self.gateway.submit(
            series, priority=req.get("priority"), tenant=req.get("tenant"),
        )  # overload/shape/shed errors -> dispatch error path

        def _completed(t) -> None:
            if t.failed:
                self.send(_error_payload("score", t.exception()), rid)
            else:
                payload = self._alert_field(
                    {"ok": True, "op": "score", "score": t.score}, t.score
                )
                if span is not None:
                    for stage, ms in (t.stage_ms or {}).items():
                        span.stage(stage, ms)
                    payload["trace"] = \
                        self.gateway.tracer.finish(span).to_wire()
                self.send(payload, rid)

        # fires now if submit's size-trigger already flushed the bucket,
        # later from the background pump / drain otherwise
        ticket.add_done_callback(_completed)

    # -- control ops -------------------------------------------------------

    def _complete_async(self, op: str, awaitable, rid, wrap) -> None:
        """Answer ``op`` from an awaitable (worker-front providers cross a
        control pipe).  The response is written when the task completes —
        like score tickets, possibly after later requests' responses."""

        async def run() -> None:
            try:
                result = await awaitable
            except Exception as exc:
                self.send(_error_payload(op, exc), rid)
            else:
                self.send(wrap(result), rid)

        task = asyncio.get_running_loop().create_task(run())
        self._control_tasks.add(task)
        task.add_done_callback(self._control_tasks.discard)

    def _op_recalibrate(self, req: dict, rid) -> None:
        kw = {}
        if "threshold" in req:
            kw["threshold"] = req["threshold"]
        provider = self.server.recalibrate_provider
        if provider is None:
            out = self.gateway.recalibrate(**kw)
            self.send({"ok": True, "op": "recalibrate", **out}, rid)
            return
        # worker-front mode: the swap must reach every worker process or
        # acceptors would disagree about alerts — fan out, then answer
        self._complete_async(
            "recalibrate", provider(**kw), rid,
            lambda out: {"ok": True, "op": "recalibrate", **out},
        )

    def _op_stats(self, req: dict, rid) -> None:
        provider = self.server.stats_provider
        if provider is None:
            self.send({"ok": True, "op": "stats",
                       "stats": self.gateway.stats()}, rid)
            return
        # worker-front mode: answer with the AGGREGATED front snapshot
        self._complete_async(
            "stats", provider(), rid,
            lambda stats: {"ok": True, "op": "stats", "stats": stats},
        )

    def _op_snapshot(self, req: dict, rid) -> None:
        dur = self.gateway.durability
        if dur is None:
            raise ValueError("durability is not enabled on this server")
        out = dur.snapshot_now(wait=True)  # synchronous: callers use this
        self.send({"ok": True, "op": "snapshot", **out}, rid)  # as a barrier

    def _op_ping(self, req: dict, rid) -> None:
        self.send({"ok": True, "op": "ping"}, rid)


__all__ = ["GatewayServer"]
